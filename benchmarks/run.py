"""Benchmark harness — one entry per paper table/figure + kernels + roofline.

  PYTHONPATH=src python -m benchmarks.run [--only substring] [--fast]
      [--json BENCH_core.json]

Prints ``name,us_per_call,derived`` CSV (one row per measurement); with
``--json`` additionally writes the whole suite as a machine-readable
artifact (name → {us_per_call, derived}) so the perf trajectory is tracked
across PRs (CI uploads it from the fast lane).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
import traceback


def _suites(fast: bool):
    from benchmarks import (bench_kernels, bench_mar, bench_roofline,
                            bench_sim, bench_tables)
    suites = [
        ("table2", bench_tables.bench_table2_clustering),
        ("mar", bench_mar.bench_mar),
        ("kernels/flash", bench_kernels.bench_flash),
        ("kernels/distill", bench_kernels.bench_distill),
        ("kernels/fedagg", bench_kernels.bench_fedagg),
        ("kernels/kd", bench_kernels.bench_kd_jnp_vs_kernel_math),
        ("roofline", bench_roofline.bench_roofline),
        ("sim/padding", bench_sim.bench_sim_padding),
        ("sim/dispatch", bench_sim.bench_sim_dispatch),
        ("sim/mesh", bench_sim.bench_sim_mesh),
        ("sim/mesh2d", bench_sim.bench_sim_mesh2d),
        ("sim/tp", bench_sim.bench_sim_tp),
        ("sim/fleet", bench_sim.bench_sim_fleet),
        ("sim/ckpt", bench_sim.bench_sim_ckpt),
        ("sim/async", bench_sim.bench_sim_async),
    ]
    if not fast:
        suites += [
            ("sim/cluster", bench_sim.bench_sim_cluster),
            ("table4", bench_tables.bench_table4_normalization),
            ("table5", bench_tables.bench_table5_compaction),
            ("fig2", bench_tables.bench_fig2_convergence),
            ("fig3", bench_tables.bench_fig3_masterslave),
            ("table6", bench_tables.bench_table6_rounds_to_reach),
            ("fig4", bench_tables.bench_fig4_leave_one_out),
            ("table7", bench_tables.bench_table7_learning_rate),
        ]
    return suites


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true",
                    help="skip the FL-training table benchmarks")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write all rows as JSON "
                         "(BENCH_core.json in CI)")
    args = ap.parse_args()

    rows = {}
    print("name,us_per_call,derived")
    t_start = time.time()
    for name, fn in _suites(args.fast):
        if args.only and args.only not in name:
            continue
        try:
            # rows are (name, us, derived[, phases]) — the optional 4th
            # element is a per-phase breakdown dict (compile_s, execute_s,
            # h2d/d2h bytes, psum count) embedded in the JSON artifact
            for out in fn():
                row, us, derived = out[0], out[1], out[2]
                print(f"{row},{us:.1f},{str(derived).replace(',', ';')}",
                      flush=True)
                rows[row] = {"us_per_call": round(float(us), 3),
                             "derived": str(derived)}
                if len(out) > 3 and out[3]:
                    rows[row]["phases"] = out[3]
        except Exception:
            err = traceback.format_exc().splitlines()[-1]
            print(f"{name},0.0,HARNESS_ERROR:{err}", flush=True)
            rows[name] = {"us_per_call": 0.0, "derived": f"HARNESS_ERROR:{err}"}
    wall = time.time() - t_start
    print(f"# total wall: {wall:.1f}s", file=sys.stderr)
    if args.json:
        pathlib.Path(args.json).write_text(json.dumps(
            {"rows": rows, "wall_s": round(wall, 1),
             "fast": args.fast}, indent=2))
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
