"""Benchmark harness — one entry per paper table/figure + kernels + roofline.

  PYTHONPATH=src python -m benchmarks.run [--only substring] [--fast]

Prints ``name,us_per_call,derived`` CSV (one row per measurement).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def _suites(fast: bool):
    from benchmarks import (bench_kernels, bench_mar, bench_roofline,
                            bench_sim, bench_tables)
    suites = [
        ("table2", bench_tables.bench_table2_clustering),
        ("mar", bench_mar.bench_mar),
        ("kernels/flash", bench_kernels.bench_flash),
        ("kernels/distill", bench_kernels.bench_distill),
        ("kernels/fedagg", bench_kernels.bench_fedagg),
        ("kernels/kd", bench_kernels.bench_kd_jnp_vs_kernel_math),
        ("roofline", bench_roofline.bench_roofline),
        ("sim/padding", bench_sim.bench_sim_padding),
    ]
    if not fast:
        suites += [
            ("sim/cluster", bench_sim.bench_sim_cluster),
            ("table4", bench_tables.bench_table4_normalization),
            ("table5", bench_tables.bench_table5_compaction),
            ("fig2", bench_tables.bench_fig2_convergence),
            ("fig3", bench_tables.bench_fig3_masterslave),
            ("table6", bench_tables.bench_table6_rounds_to_reach),
            ("fig4", bench_tables.bench_fig4_leave_one_out),
            ("table7", bench_tables.bench_table7_learning_rate),
        ]
    return suites


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true",
                    help="skip the FL-training table benchmarks")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    t_start = time.time()
    for name, fn in _suites(args.fast):
        if args.only and args.only not in name:
            continue
        try:
            for row, us, derived in fn():
                print(f"{row},{us:.1f},{str(derived).replace(',', ';')}",
                      flush=True)
        except Exception:
            print(f"{name},0.0,HARNESS_ERROR:"
                  f"{traceback.format_exc().splitlines()[-1]}", flush=True)
    print(f"# total wall: {time.time() - t_start:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
