"""MAR schedule analysis (Eq. 2 / 9 / 10): straggler cost of plain FedAvg vs
Fed-RAC's parallel master-slave schedule vs the sequential variant, on the
paper's 40 real resource vectors."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer
from repro.core import cost_model
from repro.core.resources import TABLE_III, participants_from_matrix


def bench_mar():
    parts = participants_from_matrix(TABLE_III, n_data=[60] * 40)
    model_bytes = 4e6          # 1M-param fp32 CNN
    flops = 2e6
    rows = []
    with Timer() as t:
        # Eq. 2: synchronous FedAvg — every round waits for the straggler
        times = np.array([cost_model.round_time(p, flops, model_bytes, E=2)
                          for p in parts])
        fedavg_total = cost_model.total_time_sync(times, rounds=100)
        # Fed-RAC: cluster C_m time is the slowest member's round on the
        # smallest model; masters run the full model fast
        t_small = np.array([cost_model.round_time(p, flops * 0.125,
                                                  model_bytes * 0.125, E=2)
                            for p in parts])
        T_m = float(np.max(t_small)) * 100
        for kappa in (0.5, 0.7):
            for m in (3, 4, 5):
                par = cost_model.mar_parallel(T_m, kappa, m)
                seq = cost_model.mar_sequential(T_m, kappa, m)
                rows.append((f"mar/k{kappa}/m{m}", 0.0,
                             f"parallel={par:.1f}s;sequential={seq:.1f}s;"
                             f"speedup={seq / par:.2f}x"))
    rows.append(("mar/fedavg_eq2_100r", t.us,
                 f"total={fedavg_total:.1f}s;straggler={float(times.max()):.2f}s;"
                 f"median={float(np.median(times)):.2f}s"))
    return rows
