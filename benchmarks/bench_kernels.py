"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode, so
us_per_call for them measures the *oracle jnp path* (the deployable number)
and `derived` carries the kernel-vs-oracle max error — the correctness
contract that transfers to TPU.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer
from repro.core import distill
from repro.kernels.distill import ops as dops
from repro.kernels.distill import ref as dref
from repro.kernels.fedagg import ops as aops
from repro.kernels.flash import ops as fops
from repro.kernels.flash import ref as fref


def _time(fn, *args, iters=5):
    fn(*args)                                   # compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def bench_flash():
    key = jax.random.PRNGKey(0)
    rows = []
    for (S, H, hd) in [(256, 4, 64), (512, 4, 128)]:
        q = jax.random.normal(key, (1, S, H, hd))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, S, H, hd))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, S, H, hd))
        qb = q.transpose(0, 2, 1, 3).reshape(H, S, hd)
        kb = k.transpose(0, 2, 1, 3).reshape(H, S, hd)
        vb = v.transpose(0, 2, 1, 3).reshape(H, S, hd)
        ref_fn = jax.jit(lambda a, b, c: fref.attention_bh(a, b, c, causal=True))
        us = _time(ref_fn, qb, kb, vb)
        out = fops.flash_attention(q, k, v, causal=True)
        ref = ref_fn(qb, kb, vb).reshape(1, H, S, hd).transpose(0, 2, 1, 3)
        err = float(jnp.max(jnp.abs(out - ref)))
        rows.append((f"kernel/flash/S{S}hd{hd}", us, f"max_err={err:.2e}"))
    return rows


def bench_distill():
    key = jax.random.PRNGKey(0)
    rows = []
    for (N, V) in [(256, 8192), (64, 32768)]:
        s = jax.random.normal(key, (N, V)) * 3
        t = jax.random.normal(jax.random.fold_in(key, 1), (N, V)) * 3
        y = jax.random.randint(key, (N,), 0, V)
        ref_fn = jax.jit(lambda a, b, c: jnp.mean(dref.kd_loss_rows(a, b, c)))
        us = _time(ref_fn, s, t, y)
        got = float(dops.kd_loss(s, y, t))
        want = float(ref_fn(s, t, y))
        rows.append((f"kernel/distill/N{N}V{V}", us,
                     f"rel_err={abs(got - want) / abs(want):.2e}"))
    return rows


def bench_fedagg():
    key = jax.random.PRNGKey(0)
    rows = []
    for (C, D) in [(16, 1 << 18), (40, 1 << 16)]:
        x = jax.random.normal(key, (C, D))
        w = jax.nn.softmax(jax.random.normal(key, (C,)))
        ref_fn = jax.jit(lambda a, b: jnp.einsum("c,cd->d", b, a))
        us = _time(ref_fn, x, w)
        got = aops.aggregate_tree({"x": x}, w)["x"]
        err = float(jnp.max(jnp.abs(got - ref_fn(x, w))))
        rows.append((f"kernel/fedagg/C{C}D{D}", us, f"max_err={err:.2e}"))
    return rows


def bench_kd_jnp_vs_kernel_math():
    """Fused-KD kernel agreement on a padded-vocab LM-shaped case."""
    key = jax.random.PRNGKey(1)
    s = jax.random.normal(key, (4, 8, 1000)) * 2
    t = jax.random.normal(jax.random.fold_in(key, 1), (4, 8, 1000)) * 2
    y = jax.random.randint(key, (4, 8), 0, 1000)
    with Timer() as tm:
        a = float(distill.kd_loss(s, y, t))
    b = float(distill.kd_loss(s, y, t, use_kernel=True))
    return [("kernel/kd_e2e", tm.us, f"jnp={a:.4f};kernel={b:.4f}")]
