"""Shared helpers for the paper-table benchmarks (CPU-budget scale: the
paper's 40 participants and cluster structure, a base_width-scaled CNN, and
synthetic stand-in datasets — see DESIGN.md §7)."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import server as srv
from repro.core.families import cnn_family
from repro.core.resources import (LAMBDA_EQUAL, LAMBDA_PAPER, TABLE_III,
                                  participants_from_matrix)
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import SPECS, make_classification, train_test_split

BASE_WIDTH = 0.125
ROUNDS = 8
STEPS = 3
LR = 0.08


def setup_fl(dataset: str = "synth-mnist", n_participants: int = 40,
             samples: int = 2000, seed: int = 3, dirichlet: float = 1.0):
    ds = make_classification(dataset, samples, seed=seed)
    train, test = train_test_split(ds)
    idx = dirichlet_partition(train.y, n_participants, alpha=dirichlet,
                              seed=seed)
    V = TABLE_III if n_participants == 40 else TABLE_III[:n_participants]
    parts = participants_from_matrix(V, n_data=[len(p) for p in idx])
    client_data = [{"x": train.x[p], "y": train.y[p]} for p in idx]
    testb = {"x": jnp.asarray(test.x), "y": jnp.asarray(test.y)}
    shape, classes = SPECS[dataset]
    fam = cnn_family(classes=classes, in_channels=shape[-1],
                     base_width=BASE_WIDTH, input_hw=shape[0])
    return parts, client_data, testb, fam, classes, train


def run_fedrac(parts, client_data, testb, fam, classes, *, rounds=ROUNDS,
               compact_to=4, lam=LAMBDA_PAPER, use_kd=True, seed=3,
               lr=LR, normalize=True, class_balanced=True,
               master_boost: int = 3):
    """master_boost: the master trains master_boost× the slave rounds before
    distilling (the paper trains M1 to convergence first — a weak teacher
    actively hurts KD, which Fig. 3's gains presuppose)."""
    # T=1, α=0.5: at CPU-scale round budgets higher temperatures make the
    # (T²-weighted) KL overpower CE and hurt early training; T=1 recovers
    # the paper's Fig-3 gains for the smallest cluster (see EXPERIMENTS.md)
    cfg = srv.FLConfig(rounds=rounds, steps_per_round=STEPS, lr=lr, lam=lam,
                       compact_to=compact_to, seed=seed, use_kd=use_kd,
                       kd_T=1.0, kd_alpha=0.5, class_balanced=class_balanced)
    eng = srv.FedRAC(parts, client_data, fam, cfg, classes=classes)
    if not normalize:
        # unnormalized clustering variant (Table IV row 1)
        import repro.core.clustering as C
        orig = C.optimal_clusters

        def no_norm(V, lam_, **kw):
            kw["normalize"] = False
            return orig(V, lam_, **kw)
        C_opt, srv.clustering.optimal_clusters = srv.clustering.optimal_clusters, no_norm
        try:
            eng.setup()
        finally:
            srv.clustering.optimal_clusters = C_opt
    else:
        eng.setup()
    res = eng.train(testb, rounds_per_cluster={0: rounds * master_boost})
    return eng, res


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0

    @property
    def us(self):
        return self.dt * 1e6
