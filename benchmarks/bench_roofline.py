"""Roofline table from the dry-run artifacts (§Roofline deliverable).

Reads benchmarks/results/dryrun/*.json (produced by
``python -m repro.launch.dryrun --all``) and emits one row per
(arch × shape × mesh): the three roofline terms, the dominant bottleneck,
and the useful-flops ratio.  Also writes a markdown table next to the JSONs
for EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_results(d=DRYRUN_DIR):
    out = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def bench_roofline():
    rows = []
    results = load_results()
    if not results:
        return [("roofline/missing", 0.0,
                 "run `python -m repro.launch.dryrun --all` first")]
    n_ok = n_skip = n_err = 0
    for r in results:
        tag = f"roofline/{r['arch']}/{r['shape']}/{r.get('mesh', '?')}"
        if r.get("variant"):
            tag += f"/{r['variant']}"
        if "skipped" in r:
            n_skip += 1
            rows.append((tag, 0.0, "SKIP:" + r["skipped"][:60]))
            continue
        if "error" in r:
            n_err += 1
            rows.append((tag, 0.0, "ERROR"))
            continue
        n_ok += 1
        roof = r["roofline"]
        dom_s = max(roof["compute_s"], roof["memory_s"], roof["collective_s"])
        rows.append((tag, dom_s * 1e6,
                     f"dom={roof['dominant']};c={roof['compute_s']:.3g}s;"
                     f"m={roof['memory_s']:.3g}s;n={roof['collective_s']:.3g}s;"
                     f"useful={roof['useful_flops_ratio']:.2f}"))
    rows.append(("roofline/summary", 0.0,
                 f"ok={n_ok};skip={n_skip};error={n_err}"))
    return rows


def write_markdown(out_path=os.path.join(DRYRUN_DIR, "roofline.md")):
    results = [r for r in load_results() if "roofline" in r]
    lines = ["| arch | shape | mesh | variant | compute s | memory s | "
             "collective s | dominant | useful FLOPs | fits 16G |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(results, key=lambda x: (x["arch"], x["shape"],
                                            x.get("mesh", ""),
                                            x.get("variant", ""))):
        roof, mem = r["roofline"], r.get("memory", {})
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} "
            f"| {r.get('variant','') or 'baseline'} "
            f"| {roof['compute_s']:.4g} | {roof['memory_s']:.4g} "
            f"| {roof['collective_s']:.4g} | **{roof['dominant']}** "
            f"| {roof['useful_flops_ratio']:.2f} "
            f"| {'yes' if mem.get('fits_16g') else 'NO'} |")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        f.write("\n".join(lines) + "\n")
    return out_path
