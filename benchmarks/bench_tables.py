"""Paper-table benchmarks (Tables II, IV, V, VI, VII; Figs 2, 3, 4).

Each function returns rows (name, us_per_call, derived).  us_per_call is the
wall time per FL communication round (or per clustering call); derived packs
the table's headline numbers.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (LAMBDA_EQUAL, LAMBDA_PAPER, ROUNDS, Timer,
                               run_fedrac, setup_fl)
from repro.core import baselines as bl
from repro.core import clustering as C
from repro.core import resources as R
from repro.core.server import rounds_to_reach
from repro.models import cnn

import jax
import jax.numpy as jnp


# ----------------------------------------------------------- Table II
def bench_table2_clustering():
    """DI values at k=2..6 for k-means / DBSCAN / OPTICS on Table III."""
    rows = []
    Vb = R.unit_normalize(R.TABLE_III)
    lam = LAMBDA_PAPER
    S = R.similarity_matrix(Vb, lam)
    X = Vb * np.sqrt(np.asarray(lam))
    for method in ("kmeans", "dbscan", "optics"):
        with Timer() as t:
            dis = {}
            for k in range(2, 7):
                if method == "kmeans":
                    lab, _ = C.kmeans(X, k, seed=3, restarts=1)
                elif method == "dbscan":
                    lab = C.dbscan_at_k(X, k)
                else:
                    lab = C.optics_at_k(X, k)
                dis[k] = round(C.dunn_index(S, lab), 4) if lab is not None else None
        best = max((v, k) for k, v in dis.items() if v is not None)[1]
        rows.append((f"table2/{method}", t.us / 5,
                     f"best_k={best};DI={dis}"))
    return rows


# ----------------------------------------------------------- Table IV
def bench_table4_normalization():
    """Resource-vector types → optimal k + global accuracy."""
    rows = []
    for tag, lam, norm in [("unnormalized", LAMBDA_EQUAL, False),
                           ("norm_equal", LAMBDA_EQUAL, True),
                           ("norm_paper", LAMBDA_PAPER, True)]:
        parts, cdata, testb, fam, classes, _ = setup_fl()
        with Timer() as t:
            eng, res = run_fedrac(parts, cdata, testb, fam, classes,
                                  lam=lam, normalize=norm, compact_to=4)
        rows.append((f"table4/{tag}", t.us / ROUNDS,
                     f"k={eng.k_optimal};m={eng.m};"
                     f"global_acc={res.global_acc:.4f}"))
    return rows


# ----------------------------------------------------------- Table V
def bench_table5_compaction():
    rows = []
    for m in (5, 4, 3):
        parts, cdata, testb, fam, classes, _ = setup_fl()
        with Timer() as t:
            eng, res = run_fedrac(parts, cdata, testb, fam, classes,
                                  compact_to=m)
        accs = ";".join(f"C{l + 1}={res.final_acc.get(l, float('nan')):.3f}"
                        for l in range(eng.m))
        rows.append((f"table5/m={m}", t.us / ROUNDS,
                     f"global={res.global_acc:.4f};{accs}"))
    return rows


# ----------------------------------------------------------- Fig 2 (+ A1-A4)
def _loss_fn(params, batch):
    logits = cnn.forward(params, batch["x"])
    lse = jax.nn.logsumexp(logits, -1)
    picked = jnp.take_along_axis(logits, batch["y"][:, None], -1)[:, 0]
    return jnp.mean(lse - picked), logits


def bench_fig2_convergence(datasets=("synth-mnist", "synth-har")):
    rows = []
    for dsname in datasets:
        parts, cdata, testb, fam, classes, _ = setup_fl(dsname)
        with Timer() as t:
            eng, res = run_fedrac(parts, cdata, testb, fam, classes)
        curve0 = [round(a, 3) for a in res.history[0]]
        rows.append((f"fig2/{dsname}/fedrac", t.us / ROUNDS,
                     f"global={res.global_acc:.4f};master_curve={curve0}"))
        cfg = bl.BaselineConfig(rounds=ROUNDS, steps_per_round=3, lr=0.08,
                                seed=3)
        # baselines use the smallest slave model so all 40 participate
        init = cnn.init_params(jax.random.PRNGKey(0), in_channels=1,
                               classes=classes, base_width=0.125 * 0.25)
        for name, fn in [("fedavg", bl.fedavg), ("fedprox", bl.fedprox)]:
            with Timer() as t:
                _, hist = fn(_loss_fn, init, parts, cdata, testb, cfg)
            rows.append((f"fig2/{dsname}/{name}", t.us / ROUNDS,
                         f"final={hist[-1]:.4f};curve={[round(a,3) for a in hist]}"))
        with Timer() as t:
            _, hist = bl.oort(_loss_fn, init, parts, cdata, testb, cfg,
                              flops_per_sample=1e6, model_bytes=2e5)
        rows.append((f"fig2/{dsname}/oort", t.us / ROUNDS,
                     f"final={hist[-1]:.4f}"))
        levels = {p.pid: min(2, int(3 * i / len(parts)))
                  for i, p in enumerate(parts)}
        with Timer() as t:
            _, hist = bl.heterofl(parts, cdata, levels, testb, cfg,
                                  in_channels=1, classes=classes, levels=3,
                                  base_width=0.125)
        rows.append((f"fig2/{dsname}/heterofl", t.us / ROUNDS,
                     f"final={hist[-1]:.4f}"))
    return rows


# ----------------------------------------------------------- Fig 3
def bench_fig3_masterslave():
    rows = []
    for use_kd in (True, False):
        parts, cdata, testb, fam, classes, _ = setup_fl()
        with Timer() as t:
            eng, res = run_fedrac(parts, cdata, testb, fam, classes,
                                  compact_to=4, use_kd=use_kd)
        accs = ";".join(f"C{l + 1}={res.final_acc.get(l, float('nan')):.3f}"
                        for l in range(eng.m))
        rows.append((f"fig3/{'kd' if use_kd else 'no_kd'}", t.us / ROUNDS,
                     accs))
    return rows


# ----------------------------------------------------------- Table VI
def bench_table6_rounds_to_reach(target=0.55):
    rows = []
    for use_kd in (True, False):
        parts, cdata, testb, fam, classes, _ = setup_fl()
        with Timer() as t:
            eng, res = run_fedrac(parts, cdata, testb, fam, classes,
                                  rounds=12, compact_to=4, use_kd=use_kd)
        per = {f"C{l + 1}": rounds_to_reach(res.history.get(l, []), target)
               for l in range(eng.m)}
        r1 = per.get("C1")
        slaves = [v for k, v in per.items() if k != "C1" and v]
        trr = (r1 or 12) + (max(slaves) if slaves else 12)
        rows.append((f"table6/{'kd' if use_kd else 'no_kd'}", t.us / 12,
                     f"target={target};TRR={trr};per_cluster={per}"))
    return rows


# ----------------------------------------------------------- Fig 4
def bench_fig4_leave_one_out():
    from repro.data.sampler import leave_one_out
    rows = []
    for use_kd in (True, False):
        parts, cdata, testb, fam, classes, train = setup_fl()
        # drop the most frequent class from every client's training data
        drop = int(np.bincount(train.y).argmax())
        cdata2 = []
        for d in cdata:
            x, y = leave_one_out(d["x"], d["y"], drop)
            if len(y) < 8:
                x, y = d["x"], d["y"]
            cdata2.append({"x": x, "y": y})
        with Timer() as t:
            eng, res = run_fedrac(parts, cdata2, testb, fam, classes,
                                  compact_to=4, use_kd=use_kd)
        rows.append((f"fig4/{'kd' if use_kd else 'no_kd'}", t.us / ROUNDS,
                     f"dropped={drop};global={res.global_acc:.4f}"))
    return rows


# ----------------------------------------------------------- Table VII
def bench_table7_learning_rate():
    rows = []
    for lr in (0.002, 0.02, 0.08, 0.2):
        parts, cdata, testb, fam, classes, _ = setup_fl()
        with Timer() as t:
            eng, res = run_fedrac(parts, cdata, testb, fam, classes,
                                  rounds=5, compact_to=4, lr=lr)
        rows.append((f"table7/lr={lr}", t.us / 5,
                     f"master_acc={res.final_acc.get(0, float('nan')):.4f}"))
    return rows
