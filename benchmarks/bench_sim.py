"""Looped vs batched-vmap cluster execution throughput.

  PYTHONPATH=src python benchmarks/bench_sim.py [--family lm|cnn]
      [--members 12] [--rounds 20]

Times ``FedRAC._train_cluster`` on one cluster of C members both ways:
the legacy per-pid Python loop (C jitted calls + host round-trips per round)
and the batched path (one ``make_cluster_update`` vmap call per round).
Reports each path's best-of-``--reps`` client-steps/sec (C × steps_per_round
× rounds / wall time), synced via ``block_until_ready`` and excluding
compile; reps are interleaved so transient host load hits both paths
equally.

Two regimes:
* ``--family lm`` (default) — an edge-scale transformer (matmul-dominated,
  ~µs-scale steps): the per-member dispatch overhead the vmap removes is a
  real fraction of the round, and the batched path wins (~1.1-1.25× for
  C=16-24 on this container's CPU; margins at C<12 sit inside host noise).
* ``--family cnn`` — the paper's CNN: XLA CPU lowers a conv vmapped over
  *per-member weights* poorly, so the loop is at parity or ahead on CPU.
  On accelerators the batched path is additionally one pjit program
  instead of C dispatches.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

import jax                           # noqa: E402
import numpy as np                   # noqa: E402

from common import Timer             # noqa: E402
from repro.configs.base import ModelConfig                 # noqa: E402
from repro.core import server as srv                       # noqa: E402
from repro.core.families import cnn_family, lm_family      # noqa: E402
from repro.core.resources import participants_from_matrix  # noqa: E402
from repro.data.partition import dirichlet_partition       # noqa: E402
from repro.data.synthetic import (lm_batches, make_classification,  # noqa: E402
                                  make_lm_corpus, train_test_split)
from repro.sim.traces import sample_profiles               # noqa: E402


def build_cnn(n_members: int, steps: int, seed: int, base_width: float):
    ds = make_classification("synth-mnist", 120 * n_members, seed=seed)
    train, _ = train_test_split(ds)
    idx = dirichlet_partition(train.y, n_members, alpha=10.0, seed=seed)
    parts = participants_from_matrix(sample_profiles(n_members, seed=seed),
                                     n_data=[len(p) for p in idx])
    cd = [{"x": train.x[p], "y": train.y[p]} for p in idx]
    fam = cnn_family(classes=10, in_channels=1, base_width=base_width)
    cfg = srv.FLConfig(steps_per_round=steps, lr=0.08, seed=seed,
                       compact_to=1, mar=1e9)   # one cluster, nobody demoted
    return srv.FedRAC(parts, cd, fam, cfg, classes=10).setup()


def build_lm(n_members: int, steps: int, seed: int):
    base = ModelConfig(name="edge-lm", family="dense", n_layers=1,
                       d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
                       d_ff=128, vocab_size=64, rope_theta=1e4)
    fam = lm_family(base, alpha=0.5)
    corpus = make_lm_corpus(64, 20_000, seed=seed)
    parts = participants_from_matrix(sample_profiles(n_members, seed=seed),
                                     n_data=[64] * n_members)
    chunks = np.array_split(corpus, n_members)
    cd = [{"tokens": lm_batches(ch, 32, 17, 1, seed=i)[0]}
          for i, ch in enumerate(chunks)]

    class LMFedRAC(srv.FedRAC):
        def _client_batches(self, pid, r, balanced):
            d = self.client_data[pid]
            rng = np.random.default_rng(pid * 31 + r)
            idx = rng.integers(0, d["tokens"].shape[0],
                               (self.cfg.steps_per_round, 8))
            t = d["tokens"][idx]
            return {"tokens": t, "y": t[:, :, -1]}

    cfg = srv.FLConfig(steps_per_round=steps, lr=0.1, seed=seed,
                       compact_to=1, mar=1e9, class_balanced=False)
    return LMFedRAC(parts, cd, fam, cfg, classes=64).setup()


def time_path(eng, members, rounds, steps, vmap: bool) -> float:
    eng.cfg.vmap_clusters = vmap
    eng._train_cluster(0, members, 1, None, record_every=10**9)  # compile
    with Timer() as t:
        params, _ = eng._train_cluster(0, members, rounds, None,
                                       record_every=10**9)
        jax.block_until_ready(jax.tree.leaves(params))
    return len(members) * steps * rounds / t.dt


def best_of(reps, eng, members, rounds, steps):
    """Interleave the two paths and keep each one's best rep, so transient
    host load hits both equally."""
    best = {False: 0.0, True: 0.0}
    for _ in range(reps):
        for vmap in (False, True):
            best[vmap] = max(best[vmap],
                             time_path(eng, members, rounds, steps, vmap))
    return best


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--family", default="lm", choices=["lm", "cnn"])
    ap.add_argument("--members", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--base-width", type=float, default=0.125,
                    help="CNN family only")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.family == "lm":
        eng = build_lm(args.members, args.steps, args.seed)
    else:
        eng = build_cnn(args.members, args.steps, args.seed, args.base_width)
    members = list(eng.assignment.members[0])
    assert len(members) == args.members, "expected a single full cluster"

    best = best_of(args.reps, eng, members, args.rounds, args.steps)
    looped, vmapped = best[False], best[True]
    print(f"{args.family} cluster of C={len(members)} members, "
          f"{args.steps} local steps × {args.rounds} rounds")
    print(f"  per-pid loop : {looped:10.1f} client-steps/s")
    print(f"  batched vmap : {vmapped:10.1f} client-steps/s "
          f"({vmapped / looped:.2f}× speedup)")
    return {"looped": looped, "vmapped": vmapped}


if __name__ == "__main__":
    main()
