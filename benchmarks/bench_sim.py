"""Simulator benchmarks: cluster-execution throughput, compile-stable
padding, and the device-resident multi-round dispatch pipeline.

  PYTHONPATH=src python benchmarks/bench_sim.py
      [--mode cluster|padding|dispatch|all]
      [--family lm|cnn] [--members 12] [--rounds 20] [--json out.json]

``--mode cluster`` times ``FedRAC._train_cluster`` on one cluster of C
members both ways: the legacy per-pid Python loop (C jitted calls + host
round-trips per round) and the batched path (one ``make_cluster_update``
vmap call per round).  Reports each path's best-of-``--reps``
client-steps/sec (C × steps_per_round × rounds / wall time), synced via
``block_until_ready`` and excluding compile; reps are interleaved so
transient host load hits both paths equally.

Two regimes:
* ``--family lm`` (default) — an edge-scale transformer (matmul-dominated,
  ~µs-scale steps): the per-member dispatch overhead the vmap removes is a
  real fraction of the round, and the batched path wins (~1.1-1.25× for
  C=16-24 on this container's CPU; margins at C<12 sit inside host noise).
* ``--family cnn`` — the paper's CNN: XLA CPU lowers a conv vmapped over
  *per-member weights* poorly, so the loop is at parity or ahead on CPU.
  On accelerators the batched path is additionally one pjit program
  instead of C dispatches.

``--mode padding`` runs a drift-heavy ``repro.sim`` trace (a master member
bounced across the cluster boundary every round → ≥5 Procedure-2
reassignments) with capacity padding on vs off and reports wall-clock and
XLA compile counts: the unpadded path retraces its round program on every
cluster-cardinality change, the padded path compiles once per capacity
bucket.

``--mode dispatch`` times the device-resident round pipeline on a
dispatch-bound micro-LM cluster (per-round XLA compute of a few ms, so the
per-round host work — numpy sampling, stacking, transfer, program dispatch —
is a real fraction of the round): ``rounds_per_dispatch=R`` fuses R rounds
into one lax.scan program with in-program batch sampling and flat-plane
aggregation.  Reports each path's median-of-``--reps`` client-steps/s
(interleaved reps, medians rather than best-of: container load is the
dominant noise source).  Target on this container's CPU: ≥1.5× at R=8.

``--mode mesh`` re-executes this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and times the
plane-SHARDED fused dispatch (member axis split over an 8-way ``data``
mesh; per-round aggregation = local fedagg contraction + one psum) against
the legacy one-round path and the unsharded fused path on the MLP family.
Headline: sharded-R=8 vs legacy ≥1.2× on this container (the 8 virtual
host devices share 2 physical cores, so the sharding itself is ~neutral
here; the row pins the scaling machinery, real meshes supply the compute).

``--mode fleet`` benchmarks the vectorized fleet-scale stack (no model
training): columnar trace generation (legacy scalar loops vs batched draws
at n=10⁵ — same seeds, bit-identical events, ≥50× target) and the full
trace + sampled-Dunn Procedure 1 + 3-round ``FleetSim`` pipeline at
10⁴/10⁵/10⁶ participants.  No O(n²) arrays anywhere, so 10⁶ runs in
container memory.

``--mode mesh2d`` is the same comparison on a ``4x2`` (data × model) mesh:
member rows split 4-way AND every plane-shaped buffer (global plane,
buffered bank, teacher/history stacks) splits its COLUMNS 2-way along
``model`` — the layout for member models too large to replicate per
device.  Parameters all-gather transiently per round; aggregation stays
one local (rows × columns) contraction + one psum over ``data``.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import statistics
import subprocess
import sys
import tempfile
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

import jax                           # noqa: E402
import jax.numpy as jnp              # noqa: E402
import numpy as np                   # noqa: E402

from common import Timer             # noqa: E402
from repro.configs.base import ModelConfig                 # noqa: E402
from repro.core import server as srv                       # noqa: E402
from repro.core.families import (cnn_family, lm_family,    # noqa: E402
                                 mlp_family)
from repro.core.resources import participants_from_matrix  # noqa: E402
from repro.data.partition import dirichlet_partition       # noqa: E402
from repro.data.synthetic import (lm_batches, make_classification,  # noqa: E402
                                  make_lm_corpus, train_test_split)
from repro.sim import (HeterogeneitySim, ResourceDrift, SimConfig,  # noqa: E402
                       make_trace)
from repro.sim.traces import sample_profiles               # noqa: E402


def build_cnn(n_members: int, steps: int, seed: int, base_width: float, *,
              samples: int | None = None, dirichlet: float = 10.0,
              with_test: bool = False, **cfg_kw):
    """CNN engine builder shared by the cluster and padding benches.
    Defaults: one cluster, nobody demoted, exact-C tracing so the
    loop-vs-vmap comparison is not skewed by padded capacity rows;
    the padding bench overrides via cfg_kw."""
    ds = make_classification("synth-mnist", samples or 120 * n_members,
                             seed=seed)
    train, test = train_test_split(ds)
    idx = dirichlet_partition(train.y, n_members, alpha=dirichlet, seed=seed)
    parts = participants_from_matrix(sample_profiles(n_members, seed=seed),
                                     n_data=[len(p) for p in idx])
    cd = [{"x": train.x[p], "y": train.y[p]} for p in idx]
    fam = cnn_family(classes=10, in_channels=1, base_width=base_width)
    cfg = srv.FLConfig(steps_per_round=steps, lr=0.08, seed=seed,
                       **({"compact_to": 1, "mar": 1e9,
                           "pad_clusters": False} | cfg_kw))
    eng = srv.FedRAC(parts, cd, fam, cfg, classes=10).setup()
    if with_test:
        return eng, {"x": jnp.asarray(test.x), "y": jnp.asarray(test.y)}
    return eng


def build_lm(n_members: int, steps: int, seed: int):
    base = ModelConfig(name="edge-lm", family="dense", n_layers=1,
                       d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
                       d_ff=128, vocab_size=64, rope_theta=1e4)
    fam = lm_family(base, alpha=0.5)
    corpus = make_lm_corpus(64, 20_000, seed=seed)
    parts = participants_from_matrix(sample_profiles(n_members, seed=seed),
                                     n_data=[64] * n_members)
    chunks = np.array_split(corpus, n_members)
    cd = [{"tokens": lm_batches(ch, 32, 17, 1, seed=i)[0]}
          for i, ch in enumerate(chunks)]

    class LMFedRAC(srv.FedRAC):
        def _client_batches(self, pid, r, balanced):
            d = self.client_data[pid]
            rng = np.random.default_rng(pid * 31 + r)
            idx = rng.integers(0, d["tokens"].shape[0],
                               (self.cfg.steps_per_round, 8))
            t = d["tokens"][idx]
            return {"tokens": t, "y": t[:, :, -1]}

    cfg = srv.FLConfig(steps_per_round=steps, lr=0.1, seed=seed,
                       compact_to=1, mar=1e9, class_balanced=False,
                       pad_clusters=False)
    return LMFedRAC(parts, cd, fam, cfg, classes=64).setup()


# ------------------------------------------------------------ dispatch bench
class TokenShardFedRAC(srv.FedRAC):
    """FedRAC over {"tokens"} shards: host batches via numpy (legacy path),
    device batches via the ``_batch_from_gathered`` hook (dispatch path)."""

    def _client_batches(self, pid, r, balanced):
        d = self.client_data[pid]
        rng = np.random.default_rng(pid * 31 + r)
        idx = rng.integers(0, d["tokens"].shape[0],
                           (self.cfg.steps_per_round, self.cfg.local_batch))
        t = d["tokens"][idx]
        return {"tokens": t, "y": t[:, :, -1]}

    def _batch_from_gathered(self, g):
        return {"tokens": g["tokens"], "y": g["tokens"][:, :, -1]}


def build_micro_lm(n_members: int, steps: int, seed: int, R: int,
                   batch: int = 4, d_model: int = 16, seq: int = 9,
                   vocab: int = 16, n_heads: int = 1, n_layers: int = 1,
                   mesh=None, **cfg_kw):
    """Dispatch-bound cluster: a micro LM whose per-round XLA program runs in
    a few ms, so per-round host overhead dominates the legacy path.  The TP
    bench widens it (``n_heads``/``d_model`` divisible by the model axis)
    and puts it on a 2D ``mesh``."""
    base = ModelConfig(name="micro-lm", family="dense", n_layers=n_layers,
                       d_model=d_model, n_heads=n_heads, n_kv_heads=n_heads,
                       head_dim=d_model // n_heads, d_ff=2 * d_model,
                       vocab_size=vocab, rope_theta=1e4)
    fam = lm_family(base, alpha=0.5)
    corpus = make_lm_corpus(vocab, 4000, seed=seed)
    parts = participants_from_matrix(sample_profiles(n_members, seed=seed),
                                     n_data=[64] * n_members)
    chunks = np.array_split(corpus, n_members)
    cd = [{"tokens": lm_batches(ch, batch, seq, 1, seed=i)[0]}
          for i, ch in enumerate(chunks)]
    cfg = srv.FLConfig(steps_per_round=steps, lr=0.1, seed=seed,
                       compact_to=1, mar=1e9, class_balanced=False,
                       pad_clusters=False, local_batch=batch,
                       rounds_per_dispatch=R, **cfg_kw)
    return TokenShardFedRAC(parts, cd, fam, cfg, classes=vocab,
                            mesh=mesh).setup()


def build_micro_mlp(n_members: int, steps: int, seed: int, R: int,
                    batch: int = 8, mesh=None):
    """The headline dispatch-bound cluster: a two-layer MLP whose per-round
    XLA program is a handful of ops, so the legacy path's per-round host
    work dominates.  ``mesh`` shards the member axis of the dispatch
    program (``--mode mesh``)."""
    ds = make_classification("synth-mnist", 60 * n_members, seed=seed)
    train, _ = train_test_split(ds)
    idx = dirichlet_partition(train.y, n_members, alpha=10.0, seed=seed)
    parts = participants_from_matrix(sample_profiles(n_members, seed=seed),
                                     n_data=[len(p) for p in idx])
    cd = [{"x": train.x[p], "y": train.y[p]} for p in idx]
    cfg = srv.FLConfig(steps_per_round=steps, lr=0.08, seed=seed,
                       compact_to=1, mar=1e9, pad_clusters=False,
                       local_batch=batch, class_balanced=False,
                       rounds_per_dispatch=R)
    return srv.FedRAC(parts, cd, mlp_family(), cfg, classes=10,
                      mesh=mesh).setup()


def _phase_breakdown(eng, members, rounds: int, *, fresh: bool = True
                     ) -> dict:
    """Per-phase breakdown of an instrumented dispatch run: attach a fenced
    observability bundle to ``eng`` and re-run ``rounds`` rounds, reading
    compile wall-time, fenced block execution time, h2d/d2h bytes and psum
    count from the registry/tracer.  With ``fresh=True`` the engine must not
    have compiled its dispatch programs yet (compile_s lands in the
    breakdown); pass ``fresh=False`` for an already-warm engine (compile_s
    reads 0 — the counters are call-site accounting and still fill in).
    The HEADLINE timings above never run instrumented: fencing serializes
    the pipeline, so phases come from this separate pass."""
    from repro.obs import make_observability
    obs = make_observability(fence=True)
    eng.obs = obs
    p, _ = eng._train_cluster(0, members, rounds, None, record_every=10 ** 9)
    jax.block_until_ready(jax.tree.leaves(p))
    reg = obs.registry
    compile_s = (reg.histograms["fl/compile_s"].total
                 if "fl/compile_s" in reg.histograms else 0.0)
    exec_s = sum(e["dur"] for e in obs.tracer.events()
                 if e["name"] == "block_exec") / 1e6
    return {"compile_s": round(compile_s, 4),
            # block_exec spans include the first call's compile; subtract
            "execute_s": round(max(exec_s - compile_s, 0.0), 4),
            "h2d_bytes": int(reg.counter("fl/h2d_bytes").value),
            "d2h_bytes": int(reg.counter("fl/d2h_bytes").value),
            "psum_count": int(reg.counter("fl/psum_count").value),
            "dispatch_blocks": int(reg.counter("fl/dispatch_blocks").value)}


def _time_dispatch_pair(build, n: int, steps: int, seed: int, R: int,
                        rounds: int, reps: int) -> dict:
    engs = {1: build(n, steps, seed, 1), R: build(n, steps, seed, R)}
    members = {k: list(e.assignment.members[0]) for k, e in engs.items()}
    for k, eng in engs.items():                      # compile both paths
        eng._train_cluster(0, members[k], max(k, 2), None,
                           record_every=10 ** 9)
    sps = {1: [], R: []}
    for _ in range(reps):                            # interleaved medians
        for k, eng in engs.items():
            with Timer() as t:
                p, _ = eng._train_cluster(0, members[k], rounds, None,
                                          record_every=10 ** 9)
                jax.block_until_ready(jax.tree.leaves(p))
            sps[k].append(n * steps * rounds / t.dt)
    r1 = statistics.median(sps[1])
    rR = statistics.median(sps[R])
    return {"members": n, "rounds": rounds, "R": R, "steps": steps,
            "legacy_steps_per_s": round(r1, 1),
            "dispatch_steps_per_s": round(rR, 1),
            "speedup": round(rR / r1, 3)}


def run_dispatch_bench(n: int = 12, R: int = 8, reps: int = 4,
                       seed: int = 0, with_lm: bool = True) -> dict:
    """R-round fused dispatch vs the legacy one-round-per-dispatch path on
    the dispatch-bound MLP cluster (headline, ≥1.5× target) and — for
    context — the micro-LM, whose larger per-round op count leaves less
    host overhead to remove (~1.3× on this container)."""
    out = {"mlp": _time_dispatch_pair(build_micro_mlp, n, 2, seed, R,
                                      rounds=64, reps=reps)}
    if with_lm:
        out["lm"] = _time_dispatch_pair(build_micro_lm, n, 1, seed, R,
                                        rounds=32, reps=reps)
    return out


# ------------------------------------------------------------ mesh bench
def run_mesh_bench(n: int = 24, R: int = 8, reps: int = 3, seed: int = 0,
                   mesh_shape: str = "8", rounds: int = 64,
                   steps: int = 2) -> dict:
    """Plane-sharded multi-device dispatch on the dispatch-bound MLP family:
    the member axis of the fused R-round program splits over the mesh
    ``data`` axis (per-round aggregation = local fedagg contraction + one
    psum over ``data``), and a 2D ``mesh_shape`` like ``"4x2"``
    additionally column-shards the plane/bank/teacher buffers along
    ``model`` (each device stores D/model_size plane columns; parameters
    all-gather transiently per round — the ``--mode mesh2d`` row).  Reports
    median client-steps/s for the legacy one-round path, the unsharded
    fused path, and the mesh-sharded fused path — the headline is mesh vs
    legacy (≥1.2× on this container's 2-core CPU, where the virtual devices
    add no compute; on real multi-host meshes the sharding itself scales
    the fleet and the 2D split divides per-device plane memory).  Requires
    ≥ prod(mesh_shape) devices: run via ``--mode mesh``/``--mode mesh2d``
    (subprocess sets XLA_FLAGS) or force host devices yourself."""
    from repro.launch.mesh import make_sim_mesh, parse_sim_mesh_shape
    shape = parse_sim_mesh_shape(mesh_shape)
    n_dev = int(np.prod(shape))
    if jax.device_count() < n_dev:
        raise RuntimeError(
            f"mesh bench needs ≥{n_dev} devices (have {jax.device_count()});"
            " use --mode mesh/mesh2d, which re-execute under XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_dev}")
    engs = {"legacy_r1": build_micro_mlp(n, steps, seed, 1),
            "fused_r8": build_micro_mlp(n, steps, seed, R),
            "mesh_r8": build_micro_mlp(n, steps, seed, R,
                                       mesh=make_sim_mesh(shape))}
    members = {k: list(e.assignment.members[0]) for k, e in engs.items()}
    for k, e in engs.items():                        # compile all paths
        e._train_cluster(0, members[k], max(R, 2), None, record_every=10**9)
    sps = {k: [] for k in engs}
    for _ in range(reps):                            # interleaved medians
        for k, e in engs.items():
            with Timer() as t:
                p, _ = e._train_cluster(0, members[k], rounds, None,
                                        record_every=10**9)
                jax.block_until_ready(jax.tree.leaves(p))
            sps[k].append(n * steps * rounds / t.dt)
    med = {k: statistics.median(v) for k, v in sps.items()}
    # warm-engine instrumented pass: psum/h2d/d2h counters fill in (compile
    # already happened, so compile_s reads 0 here by design)
    phases = _phase_breakdown(engs["mesh_r8"], members["mesh_r8"], rounds,
                              fresh=False)
    return {"members": n, "rounds": rounds, "R": R, "steps": steps,
            "devices": n_dev, "mesh_shape": "x".join(map(str, shape)),
            "legacy_steps_per_s": round(med["legacy_r1"], 1),
            "fused_steps_per_s": round(med["fused_r8"], 1),
            "mesh_steps_per_s": round(med["mesh_r8"], 1),
            "speedup_vs_legacy": round(med["mesh_r8"] / med["legacy_r1"], 3),
            "sharding_overhead": round(med["mesh_r8"] / med["fused_r8"], 3),
            "phases": phases}


def run_mesh_bench_subprocess(n: int = 24, R: int = 8, reps: int = 3,
                              seed: int = 0, mesh_shape: str = "8") -> dict:
    """Re-execute this file with forced host devices (XLA_FLAGS must be set
    BEFORE jax initializes its backend, which importing this module already
    did in the calling process) and collect the mesh-bench JSON."""
    from repro.launch.mesh import parse_sim_mesh_shape
    n_dev = int(np.prod(parse_sim_mesh_shape(mesh_shape)))
    fd, out = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    out = pathlib.Path(out)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_dev} "
                        + env.get("XLA_FLAGS", "")).strip()
    env["JAX_PLATFORMS"] = "cpu"
    try:
        r = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--mode", "mesh-inner",
             "--members", str(n), "--dispatch-r", str(R), "--reps", str(reps),
             "--seed", str(seed), "--mesh-shape", str(mesh_shape),
             "--json", str(out)],
            capture_output=True, text=True, timeout=560, env=env)
        if r.returncode != 0:
            raise RuntimeError(
                f"mesh bench subprocess failed:\n{r.stderr[-2000:]}")
        return json.loads(out.read_text())["mesh"]
    finally:
        out.unlink(missing_ok=True)


# ------------------------------------------------------------ tp bench
def run_tp_bench(n: int = 8, R: int = 8, reps: int = 3, seed: int = 0,
                 mesh_shape: str = "2x4", rounds: int = 24,
                 steps: int = 2) -> dict:
    """GSPMD tensor-parallel member forward vs the legacy gather path on a
    2D (data × model) mesh, over a TP-able micro LM (heads/d_ff/vocab all
    divide the model axis).  Three rows: the unsharded fused dispatch
    (1 device), the legacy ``tp_forward=False`` path (plane columns sharded
    at rest, but each round all-gathers the full plane and replicates the
    forward), and the TP path (member forward partitioned over ``model`` —
    per-layer activation collectives only).  On this container's virtual
    CPU devices TP buys no wall-clock (same cores, more collectives); the
    headline is the memory column: per-device parameter bytes for the
    forward drop from the full plane to plane/model_size.  Requires
    ≥ prod(mesh_shape) devices — run via ``--mode tp`` (subprocess sets
    XLA_FLAGS)."""
    from repro.launch.mesh import make_sim_mesh, parse_sim_mesh_shape
    shape = parse_sim_mesh_shape(mesh_shape)
    n_dev = int(np.prod(shape))
    if jax.device_count() < n_dev:
        raise RuntimeError(
            f"tp bench needs ≥{n_dev} devices (have {jax.device_count()});"
            " use --mode tp, which re-executes under XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_dev}")

    def build(mesh=None, tp=True):
        return build_micro_lm(n, steps, seed, R, d_model=32, n_heads=4,
                              vocab=64, seq=17, mesh=mesh, tp_forward=tp)

    engs = {"fused_r8": build(),
            "gather_r8": build(make_sim_mesh(shape), tp=False),
            "tp_r8": build(make_sim_mesh(shape), tp=True)}
    assert engs["tp_r8"]._tp and not engs["gather_r8"]._tp
    members = {k: list(e.assignment.members[0]) for k, e in engs.items()}
    for k, e in engs.items():                        # compile all paths
        e._train_cluster(0, members[k], max(R, 2), None, record_every=10**9)
    sps = {k: [] for k in engs}
    for _ in range(reps):                            # interleaved medians
        for k, e in engs.items():
            with Timer() as t:
                p, _ = e._train_cluster(0, members[k], rounds, None,
                                        record_every=10**9)
                jax.block_until_ready(jax.tree.leaves(p))
            sps[k].append(n * steps * rounds / t.dt)
    med = {k: statistics.median(v) for k, v in sps.items()}
    msize = shape[1]
    tp_spec = engs["tp_r8"].plane_spec(0)
    legacy_bytes = engs["gather_r8"].plane_spec(0).d_pad * 4
    return {"members": n, "rounds": rounds, "R": R, "steps": steps,
            "devices": n_dev, "mesh_shape": "x".join(map(str, shape)),
            "fused_steps_per_s": round(med["fused_r8"], 1),
            "gather_steps_per_s": round(med["gather_r8"], 1),
            "tp_steps_per_s": round(med["tp_r8"], 1),
            "tp_vs_gather": round(med["tp_r8"] / med["gather_r8"], 3),
            # forward-path parameter bytes per device: the gather path
            # re-materializes the full plane, TP touches only its column
            "fwd_bytes_per_device": tp_spec.d_pad // tp_spec.msize * 4,
            "fwd_bytes_legacy": legacy_bytes,
            "fwd_bytes_ratio": round(
                (tp_spec.d_pad // tp_spec.msize * 4) / legacy_bytes, 3),
            "model_size": msize}


def run_tp_bench_subprocess(n: int = 8, R: int = 8, reps: int = 3,
                            seed: int = 0, mesh_shape: str = "2x4") -> dict:
    """Re-execute this file with forced host devices and collect the
    tp-bench JSON (same contract as ``run_mesh_bench_subprocess``)."""
    from repro.launch.mesh import parse_sim_mesh_shape
    n_dev = int(np.prod(parse_sim_mesh_shape(mesh_shape)))
    fd, out = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    out = pathlib.Path(out)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_dev} "
                        + env.get("XLA_FLAGS", "")).strip()
    env["JAX_PLATFORMS"] = "cpu"
    try:
        r = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--mode", "tp-inner",
             "--members", str(n), "--dispatch-r", str(R), "--reps", str(reps),
             "--seed", str(seed), "--mesh-shape", str(mesh_shape),
             "--json", str(out)],
            capture_output=True, text=True, timeout=560, env=env)
        if r.returncode != 0:
            raise RuntimeError(
                f"tp bench subprocess failed:\n{r.stderr[-2000:]}")
        return json.loads(out.read_text())["tp"]
    finally:
        out.unlink(missing_ok=True)


def time_path(eng, members, rounds, steps, vmap: bool) -> float:
    eng.cfg.vmap_clusters = vmap
    eng._train_cluster(0, members, 1, None, record_every=10**9)  # compile
    with Timer() as t:
        params, _ = eng._train_cluster(0, members, rounds, None,
                                       record_every=10**9)
        jax.block_until_ready(jax.tree.leaves(params))
    return len(members) * steps * rounds / t.dt


def best_of(reps, eng, members, rounds, steps):
    """Interleave the two paths and keep each one's best rep, so transient
    host load hits both equally."""
    best = {False: 0.0, True: 0.0}
    for _ in range(reps):
        for vmap in (False, True):
            best[vmap] = max(best[vmap],
                             time_path(eng, members, rounds, steps, vmap))
    return best


# ------------------------------------------------------------ padding bench
def _build_sim_engine(n: int, samples: int, steps: int, seed: int,
                      base_width: float, pad: bool):
    return build_cnn(n, steps, seed, base_width, samples=samples,
                     dirichlet=2.0, with_test=True, local_batch=8,
                     compact_to=2, mar=None, pad_clusters=pad)


def _drift_trace(eng, n: int, rounds: int):
    """Bounce three master members across the cluster boundary on staggered
    phases: every extreme drift is a Procedure-2 reassignment, and the
    staggering walks each cluster through several distinct cardinalities —
    the unpadded path retraces at every new C, the padded one reuses its
    capacity-bucket programs."""
    trace = make_trace("stable", n, rounds)
    pids = list(eng.assignment.members[0][:3])
    state = {pid: 1.0 for pid in pids}               # cumulative multiplier
    for r in range(rounds - 1):
        pid = pids[r % len(pids)]
        mult = 0.02 if state[pid] >= 1.0 else 50.0   # flip direction
        state[pid] *= mult
        trace.events.append((float(r), ResourceDrift(
            pid, s_mult=mult, r_mult=mult, a_mult=1.0)))
    return trace


def run_padding_bench(n: int = 10, samples: int = 600, rounds: int = 8,
                      steps: int = 3, seed: int = 0,
                      base_width: float = 0.125) -> dict:
    out = {"participants": n, "rounds": rounds}
    for pad in (True, False):
        eng, testb = _build_sim_engine(n, samples, steps, seed, base_width,
                                       pad)
        trace = _drift_trace(eng, n, rounds)
        sim = HeterogeneitySim(eng, trace, SimConfig(rounds=rounds))
        t0 = time.perf_counter()
        rep = sim.run(testb)
        dt = time.perf_counter() - t0
        try:
            stats = eng.compile_stats()
        except RuntimeError:        # jax build without jit _cache_size
            stats = {}
        out["padded" if pad else "unpadded"] = {
            "wall_s": round(dt, 3),
            "xla_compiles": sum(stats.values()) if stats else None,
            "round_programs": len(stats) if stats else None,
            "migrations": sum(ev.count("→") for r in rep.rows
                              for ev in r.events),
        }
    return out


def run_cluster_bench(args) -> dict:
    if args.family == "lm":
        eng = build_lm(args.members, args.steps, args.seed)
    else:
        eng = build_cnn(args.members, args.steps, args.seed, args.base_width)
    members = list(eng.assignment.members[0])
    assert len(members) == args.members, "expected a single full cluster"

    best = best_of(args.reps, eng, members, args.rounds, args.steps)
    looped, vmapped = best[False], best[True]
    print(f"{args.family} cluster of C={len(members)} members, "
          f"{args.steps} local steps × {args.rounds} rounds")
    print(f"  per-pid loop : {looped:10.1f} client-steps/s")
    print(f"  batched vmap : {vmapped:10.1f} client-steps/s "
          f"({vmapped / looped:.2f}× speedup)")
    return {"looped": looped, "vmapped": vmapped}


# ------------------------------------------------------------ fleet bench
def run_fleet_bench(sizes=(10_000, 100_000, 1_000_000), rounds: int = 3,
                    seed: int = 0, legacy_n: int = 100_000) -> dict:
    """Vectorized fleet stack end-to-end: columnar trace build + sampled-Dunn
    Procedure 1 + ``rounds`` FleetSim rounds at each fleet size, plus the
    trace-generation speedup row (scalar legacy loops vs batched draws on the
    mixed scenario's three generators, identical seeds → identical events).
    No step ever materializes an O(n²) array, so 10⁶ fits CPU memory."""
    from repro.core.resources import Fleet
    from repro.sim import FleetSim, FleetSimConfig, make_fleet_trace
    from repro.sim.traces import (legacy_drift_events, legacy_dropout_events,
                                  legacy_straggler_events)
    out = {}
    legacy_s, vec_s = 1e9, 1e9
    for _ in range(2):                       # mixed-scenario defaults/seeds;
        with Timer() as t:                   # min-of-reps beats 1-core noise
            legacy_dropout_events(legacy_n, rounds, 0.08, seed)
            legacy_drift_events(legacy_n, rounds, 0.05, seed + 1)
            legacy_straggler_events(legacy_n, rounds, 0.08, seed + 2)
        legacy_s = min(legacy_s, t.dt)
    for _ in range(5):
        with Timer() as t:
            make_fleet_trace("mixed", legacy_n, rounds, seed=seed)
        vec_s = min(vec_s, t.dt)
    out["trace"] = {"n": legacy_n, "rounds": rounds,
                    "legacy_s": round(legacy_s, 4),
                    "vectorized_s": round(vec_s, 5),
                    "speedup": round(legacy_s / vec_s, 1)}
    for n in sizes:
        fleet = Fleet.from_matrix(sample_profiles(n, seed=seed))
        with Timer() as t:
            trace = make_fleet_trace("mixed", n, rounds, seed=seed)
        trace_s = t.dt
        with Timer() as t:                   # Procedure 1 + MAR calibration
            sim = FleetSim(fleet, trace, FleetSimConfig(
                rounds=rounds, select="fedcs", seed=seed))
        cluster_s = t.dt
        with Timer() as t:
            rep = sim.run()
        sim_s = t.dt
        s = rep.summary()
        out[f"fleet_{n}"] = {
            "n": n, "rounds": rounds, "k": rep.k,
            "events": sum(r.events for r in rep.rows),
            "trace_s": round(trace_s, 4), "cluster_s": round(cluster_s, 4),
            "sim_s": round(sim_s, 4),
            "rounds_per_s": round(rounds / sim_s, 2),
            "participation": s["participation_rate"]}
    return out


# ------------------------------------------------------------ ckpt bench
def run_ckpt_bench(sizes=(10_000, 100_000), rounds: int = 2, seed: int = 0,
                   reps: int = 3) -> dict:
    """Crash-safety overhead: full run-state snapshot save (manifest +
    CRC32 + atomic rename) and validated restore on a FleetSim at each
    fleet size — wall time (min-of-``reps``) and payload bytes.  The
    snapshot is the engine's own ``_capture_state`` (fleet arrays, levels,
    per-round row columns, bank/selection counters), i.e. exactly what
    ``sim_run --ckpt-dir`` writes each boundary."""
    from repro.ckpt.manifest import CheckpointManager
    from repro.ckpt.run_state import RUN_STATE_VERSION
    from repro.core.resources import Fleet
    from repro.sim import FleetSim, FleetSimConfig, make_fleet_trace
    out = {}
    for n in sizes:
        fleet = Fleet.from_matrix(sample_profiles(n, seed=seed))
        trace = make_fleet_trace("mixed", n, rounds, seed=seed)
        sim = FleetSim(fleet, trace, FleetSimConfig(rounds=rounds, seed=seed))
        sim.run()
        meta, arrays = sim._capture_state(rounds, sim.report.rows)
        meta["run_state"] = {"version": RUN_STATE_VERSION,
                             "kind": "fleet-sim"}
        nbytes = int(sum(a.nbytes for a in arrays.values()))
        save_s, load_s = 1e9, 1e9
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2)
            for i in range(reps):
                with Timer() as t:
                    mgr.save(i + 1, meta, arrays)
                save_s = min(save_s, t.dt)
            for _ in range(reps):
                with Timer() as t:
                    got = mgr.load_latest()
                load_s = min(load_s, t.dt)
            assert got is not None
        out[f"ckpt_{n}"] = {
            "n": n, "rounds": rounds, "arrays": len(arrays), "bytes": nbytes,
            "save_s": round(save_s, 5), "restore_s": round(load_s, 5),
            "save_mb_per_s": round(nbytes / save_s / 1e6, 1),
            "restore_mb_per_s": round(nbytes / load_s / 1e6, 1)}
    return out


# ------------------------------------------------------------ async bench
def run_async_bench(n: int = 12, rounds: int = 10, R: int = 4,
                    seed: int = 0, spike_rate: float = 0.5) -> dict:
    """Continuous-time async parameter server vs the global round barrier on
    a straggler-heavy trace (Table-III profiles, transient ×4 compute
    spikes): the same two-cluster buffered engine runs once with the sync
    barrier and once in ``mode="async"`` (unbounded staleness), and the
    headline is SIMULATED wall-clock to the target loss — the barrier
    charges every round at the slowest cluster's pace (Σ_r max_l t),
    independent clocks only ever charge each cluster its own time
    (max_l Σ_r t ≤ Σ_r max_l, strict under straggling), so the async run
    must reach the sync run's final master loss no later than the barrier
    does."""
    def one(mode):
        eng, testb = build_cnn(n, 3, seed, 0.125, samples=60 * n,
                               dirichlet=2.0, with_test=True, local_batch=8,
                               compact_to=2, mar=None, pad_clusters=True,
                               aggregation="buffered", rounds_per_dispatch=R)
        trace = make_trace("straggler", n, rounds, seed=seed,
                           spike_rate=spike_rate)
        kw = ({"mode": "async", "max_staleness": None}
              if mode == "async" else {})
        sim = HeterogeneitySim(eng, trace, SimConfig(
            rounds=rounds, mar_policy="buffer", eval_every=10 ** 9, **kw))
        with Timer() as t:
            rep = sim.run(testb)
        # master (level 0) per-round loss against that CLUSTER's own clock:
        # barrier time under sync (t_end — every cluster waits), the
        # master's own cumulative clock under async
        loss, t_cluster, t_barrier = [], [], []
        acc = 0.0
        for r in rep.rows:
            c0 = next(c for c in r.clusters if c.level == 0)
            acc += c0.time
            loss.append(c0.mean_loss)
            t_cluster.append(acc)
            t_barrier.append(r.t_end)
        wall = (rep.registry.gauge("async/wall_clock_s").value
                if mode == "async" else rep.summary()["wall_clock_s"])
        return {"loss": loss,
                "t": t_cluster if mode == "async" else t_barrier,
                "wall_clock_s": float(wall),
                "banked": rep.summary()["banked_total"],
                "host_s": t.dt}

    res = {m: one(m) for m in ("sync", "async")}
    target = max(res["sync"]["loss"][-1], res["async"]["loss"][-1])

    def t_to_target(r):
        return next(t for t, l in zip(r["t"], r["loss"]) if l <= target)
    out = {"members": n, "rounds": rounds, "R": R,
           "spike_rate": spike_rate, "target_loss": round(target, 4)}
    for m in ("sync", "async"):
        out[m] = {"t_to_target_s": round(t_to_target(res[m]), 4),
                  "wall_clock_s": round(res[m]["wall_clock_s"], 4),
                  "final_loss": round(res[m]["loss"][-1], 4),
                  "banked": res[m]["banked"],
                  "host_s": round(res[m]["host_s"], 3)}
    out["speedup_to_target"] = round(
        out["sync"]["t_to_target_s"]
        / max(out["async"]["t_to_target_s"], 1e-9), 3)
    return out


# ------------------------------------------------------------ run.py hooks
def bench_sim_async():
    """benchmarks/run.py suite: async server vs barrier on the straggler
    trace — simulated seconds to the sync run's final master loss (the row
    time) plus total simulated wall-clock per mode."""
    res = run_async_bench()
    for m in ("sync", "async"):
        r = res[m]
        yield (f"sim/async_{m if m == 'async' else 'barrier'}",
               r["t_to_target_s"] * 1e6,
               f"t_to_target_s={r['t_to_target_s']};"
               f"wall_clock_s={r['wall_clock_s']};"
               f"final_loss={r['final_loss']};banked={r['banked']};"
               f"target_loss={res['target_loss']};"
               f"speedup_to_target={res['speedup_to_target']}")


def bench_sim_ckpt():
    """benchmarks/run.py suite: run-state checkpoint save/validated-restore
    wall time and payload bytes at fleet sizes 10⁴/10⁵."""
    res = run_ckpt_bench()
    for n in (10_000, 100_000):
        r = res[f"ckpt_{n}"]
        yield (f"sim/ckpt_{n}", (r["save_s"] + r["restore_s"]) * 1e6,
               f"save_s={r['save_s']};restore_s={r['restore_s']};"
               f"bytes={r['bytes']};arrays={r['arrays']};"
               f"save_mb_per_s={r['save_mb_per_s']};"
               f"restore_mb_per_s={r['restore_mb_per_s']}")
def bench_sim_mesh():
    """benchmarks/run.py suite: plane-sharded dispatch at 8 forced host
    devices (subprocess — XLA_FLAGS must precede jax backend init) vs the
    legacy one-round path and the unsharded fused path."""
    res = run_mesh_bench_subprocess(n=24, R=8, reps=3)
    for tag, key in (("legacy_r1", "legacy_steps_per_s"),
                     ("fused_r8", "fused_steps_per_s"),
                     ("sharded_r8", "mesh_steps_per_s")):
        sps = res[key]
        row = (f"sim/mesh_{tag}", 1e6 / max(sps, 1e-9),
               f"client_steps_per_s={sps};devices={res['devices']};"
               f"speedup_vs_legacy={res['speedup_vs_legacy']};"
               f"sharding_overhead={res['sharding_overhead']}")
        yield row + ((res["phases"],) if tag == "sharded_r8"
                     and res.get("phases") else ())


def bench_sim_mesh2d():
    """benchmarks/run.py suite: 2D (data × model) plane-sharded dispatch on
    a forced-host-device ``4x2`` mesh — member rows split 4-way, plane/bank/
    teacher columns split 2-way (each device stores half the plane)."""
    res = run_mesh_bench_subprocess(n=24, R=8, reps=3, mesh_shape="4x2")
    sps = res["mesh_steps_per_s"]
    yield ("sim/mesh2d_sharded_r8", 1e6 / max(sps, 1e-9),
           f"client_steps_per_s={sps};devices={res['devices']};"
           f"mesh_shape={res['mesh_shape']};"
           f"speedup_vs_legacy={res['speedup_vs_legacy']};"
           f"sharding_overhead={res['sharding_overhead']}"
           ) + ((res["phases"],) if res.get("phases") else ())


def bench_sim_tp():
    """benchmarks/run.py suite: GSPMD tensor-parallel member forward on a
    forced-host-device ``2x4`` mesh vs the legacy gather path — wall-clock
    rows plus the per-device forward-parameter-bytes ratio (the reason the
    TP path exists: D/model_size instead of the full plane)."""
    res = run_tp_bench_subprocess(n=8, R=8, reps=3)
    for tag, key in (("fused_r8", "fused_steps_per_s"),
                     ("gather_r8", "gather_steps_per_s"),
                     ("tp_r8", "tp_steps_per_s")):
        sps = res[key]
        yield (f"sim/tp_{tag}", 1e6 / max(sps, 1e-9),
               f"client_steps_per_s={sps};devices={res['devices']};"
               f"mesh_shape={res['mesh_shape']};"
               f"tp_vs_gather={res['tp_vs_gather']};"
               f"fwd_bytes_per_device={res['fwd_bytes_per_device']};"
               f"fwd_bytes_legacy={res['fwd_bytes_legacy']};"
               f"fwd_bytes_ratio={res['fwd_bytes_ratio']}")


def bench_sim_dispatch():
    """benchmarks/run.py suite: fused multi-round dispatch vs legacy rounds
    on the dispatch-bound MLP cluster (CPU-budget scale; the micro-LM
    context row stays CLI-only)."""
    res = run_dispatch_bench(n=12, R=8, reps=3, with_lm=False)["mlp"]
    # fresh instrumented engine so compile_s lands in the breakdown; the
    # headline medians above stay un-instrumented (fencing serializes)
    eng = build_micro_mlp(12, 2, 0, 8)
    phases = _phase_breakdown(eng, list(eng.assignment.members[0]),
                              rounds=64)
    for tag, key in (("r1", "legacy_steps_per_s"),
                     ("r8", "dispatch_steps_per_s")):
        sps = res[key]
        row = (f"sim/dispatch_{tag}", 1e6 / max(sps, 1e-9),
               f"client_steps_per_s={sps};speedup={res['speedup']}")
        yield row + ((phases,) if tag == "r8" else ())


def bench_sim_padding():
    """benchmarks/run.py suite: padded vs unpadded drift-heavy sim rows."""
    res = run_padding_bench()
    for tag in ("padded", "unpadded"):
        r = res[tag]
        yield (f"sim/{tag}", r["wall_s"] * 1e6 / res["rounds"],
               f"compiles={r['xla_compiles']};programs={r['round_programs']};"
               f"migrations={r['migrations']}")


def bench_sim_fleet():
    """benchmarks/run.py suite: million-participant vectorized fleet rows —
    trace-generation speedup at 10⁵ (legacy scalar loops vs batched draws)
    and trace+Procedure-1+3-round FleetSim wall time at 10⁴/10⁵/10⁶."""
    res = run_fleet_bench()
    tr = res["trace"]
    yield ("sim/fleet_trace", tr["vectorized_s"] * 1e6,
           f"n={tr['n']};legacy_s={tr['legacy_s']};"
           f"vectorized_s={tr['vectorized_s']};speedup={tr['speedup']}")
    for n in (10_000, 100_000, 1_000_000):
        r = res[f"fleet_{n}"]
        total = r["trace_s"] + r["cluster_s"] + r["sim_s"]
        yield (f"sim/fleet_{n}", total * 1e6,
               f"rounds_per_s={r['rounds_per_s']};k={r['k']};"
               f"events={r['events']};trace_s={r['trace_s']};"
               f"cluster_s={r['cluster_s']};sim_s={r['sim_s']};"
               f"participation={r['participation']}")


def bench_sim_cluster():
    """benchmarks/run.py suite: looped vs vmapped cluster execution (CNN at
    CPU-budget scale; the lm regime stays CLI-only)."""
    eng = build_cnn(8, 3, 0, 0.125)
    members = list(eng.assignment.members[0])
    best = best_of(1, eng, members, 8, 3)
    for tag, key in (("loop", False), ("vmap", True)):
        sps = best[key]
        yield (f"sim/cluster_{tag}", 1e6 / max(sps, 1e-9),
               f"client_steps_per_s={sps:.1f}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="cluster",
                    choices=["cluster", "padding", "dispatch", "mesh",
                             "mesh2d", "mesh-inner", "tp", "tp-inner",
                             "fleet", "ckpt", "async", "all"],
                    help="'mesh' re-executes itself under forced host "
                         "devices and times the plane-sharded dispatch; "
                         "'mesh2d' is the same on a 4x2 (data × model) "
                         "mesh with plane columns sharded 2-way "
                         "('mesh-inner' is their subprocess entry); 'tp' "
                         "times the GSPMD tensor-parallel member forward "
                         "vs the legacy gather path on a 2x4 mesh "
                         "('tp-inner' is its subprocess entry)")
    ap.add_argument("--dispatch-r", type=int, default=8,
                    help="dispatch mode: rounds fused per program")
    ap.add_argument("--mesh-shape", default=None, metavar="DATA[xMODEL]",
                    help="mesh modes: mesh shape, e.g. '8' or '4x2' "
                         "(forced host devices = their product; defaults "
                         "to '8' for --mode mesh, '4x2' for --mode mesh2d)")
    ap.add_argument("--family", default="lm", choices=["lm", "cnn"])
    ap.add_argument("--members", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--base-width", type=float, default=0.125,
                    help="CNN family only")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sim-rounds", type=int, default=8,
                    help="padding mode: simulated rounds per path")
    ap.add_argument("--fleet-rounds", type=int, default=3,
                    help="fleet mode: FleetSim rounds per size")
    ap.add_argument("--participants", type=int, default=10,
                    help="padding mode: fleet size")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as JSON (CI tracks the suite "
                         "via benchmarks/run.py --json BENCH_core.json)")
    args = ap.parse_args(argv)
    if (args.mode in ("dispatch", "mesh", "mesh2d", "mesh-inner", "tp",
                      "tp-inner", "all")
            and args.dispatch_r < 2):
        ap.error("--dispatch-r must be ≥ 2 (R=1 IS the legacy baseline)")
    if args.mesh_shape is None:
        args.mesh_shape = ("4x2" if args.mode == "mesh2d"
                           else "2x4" if args.mode in ("tp", "tp-inner")
                           else "8")

    results = {}
    if args.mode in ("tp", "tp-inner"):
        if args.mode == "tp":
            res = run_tp_bench_subprocess(n=args.members, R=args.dispatch_r,
                                          reps=args.reps, seed=args.seed,
                                          mesh_shape=args.mesh_shape)
        else:
            res = run_tp_bench(n=args.members, R=args.dispatch_r,
                               reps=args.reps, seed=args.seed,
                               mesh_shape=args.mesh_shape)
        results["tp"] = res
        print(f"micro-lm cluster of C={res['members']} members, "
              f"{res['steps']} local steps × {res['rounds']} rounds, "
              f"{res['mesh_shape']} (data × model) mesh")
        print(f"  fused  (R={res['R']}, 1 dev)  : "
              f"{res['fused_steps_per_s']:10.1f} client-steps/s")
        print(f"  gather (R={res['R']}, {res['devices']} dev) : "
              f"{res['gather_steps_per_s']:10.1f} client-steps/s "
              f"(full plane per device: {res['fwd_bytes_legacy']} B)")
        print(f"  tp     (R={res['R']}, {res['devices']} dev) : "
              f"{res['tp_steps_per_s']:10.1f} client-steps/s "
              f"({res['tp_vs_gather']:.2f}× vs gather; forward params "
              f"{res['fwd_bytes_per_device']} B/device = "
              f"{res['fwd_bytes_ratio']:.2f}× the full plane)")
    if args.mode in ("mesh", "mesh2d", "mesh-inner"):
        if args.mode in ("mesh", "mesh2d"):
            res = run_mesh_bench_subprocess(n=args.members, R=args.dispatch_r,
                                            reps=args.reps, seed=args.seed,
                                            mesh_shape=args.mesh_shape)
        else:
            res = run_mesh_bench(n=args.members, R=args.dispatch_r,
                                 reps=args.reps, seed=args.seed,
                                 mesh_shape=args.mesh_shape)
        results["mesh"] = res
        print(f"mlp cluster of C={res['members']} members, "
              f"{res['steps']} local steps × {res['rounds']} rounds, "
              f"{res['mesh_shape']} (data × model) mesh")
        print(f"  legacy (R=1, 1 dev) : {res['legacy_steps_per_s']:10.1f} "
              f"client-steps/s")
        print(f"  fused  (R={res['R']}, 1 dev) : "
              f"{res['fused_steps_per_s']:10.1f} client-steps/s")
        print(f"  sharded(R={res['R']}, {res['devices']} dev) : "
              f"{res['mesh_steps_per_s']:10.1f} client-steps/s "
              f"({res['speedup_vs_legacy']:.2f}× vs legacy, "
              f"{res['sharding_overhead']:.2f}× vs unsharded fused)")
    if args.mode in ("cluster", "all"):
        results["cluster"] = run_cluster_bench(args)
    if args.mode in ("dispatch", "all"):
        res = run_dispatch_bench(n=args.members, R=args.dispatch_r,
                                 reps=args.reps, seed=args.seed)
        results["dispatch"] = res
        for fam, d in res.items():
            print(f"{fam} cluster of C={d['members']} members, "
                  f"{d['steps']} local steps × {d['rounds']} rounds")
            print(f"  legacy (R=1)  : {d['legacy_steps_per_s']:10.1f} "
                  f"client-steps/s")
            print(f"  fused  (R={d['R']})  : "
                  f"{d['dispatch_steps_per_s']:10.1f} client-steps/s "
                  f"({d['speedup']:.2f}× speedup)")
    if args.mode in ("fleet", "all"):
        res = run_fleet_bench(rounds=args.fleet_rounds, seed=args.seed)
        results["fleet"] = res
        tr = res["trace"]
        print(f"trace generation, mixed scenario, n={tr['n']} × "
              f"{tr['rounds']} rounds")
        print(f"  legacy loops : {tr['legacy_s']:8.3f}s")
        print(f"  vectorized   : {tr['vectorized_s']:8.4f}s "
              f"({tr['speedup']:.0f}× speedup)")
        for key, r in res.items():
            if key == "trace":
                continue
            print(f"fleet n={r['n']:>9}  k={r['k']}  "
                  f"trace={r['trace_s']:7.3f}s  "
                  f"cluster={r['cluster_s']:7.3f}s  "
                  f"sim={r['sim_s']:7.3f}s  "
                  f"({r['rounds_per_s']:.2f} rounds/s, "
                  f"{r['events']} events)")
    if args.mode in ("async", "all"):
        res = run_async_bench(seed=args.seed)
        results["async"] = res
        print(f"async server vs barrier, {res['members']} participants × "
              f"{res['rounds']} rounds (R={res['R']}, straggler trace, "
              f"spike_rate={res['spike_rate']}), "
              f"target_loss={res['target_loss']}")
        for m in ("sync", "async"):
            r = res[m]
            print(f"  {m:5s} : t_to_target={r['t_to_target_s']:8.3f}s  "
                  f"wall={r['wall_clock_s']:8.3f}s  "
                  f"final_loss={r['final_loss']:.4f}  "
                  f"banked={r['banked']}")
        print(f"  async reaches target in "
              f"{1 / max(res['speedup_to_target'], 1e-9):.2f}× the barrier "
              f"time ({res['speedup_to_target']:.2f}× speedup)")
    if args.mode in ("ckpt", "all"):
        res = run_ckpt_bench(seed=args.seed, reps=args.reps)
        results["ckpt"] = res
        for key, r in res.items():
            print(f"ckpt n={r['n']:>7}  {r['arrays']} arrays, "
                  f"{r['bytes'] / 1e6:7.2f} MB  "
                  f"save={r['save_s'] * 1e3:8.2f}ms "
                  f"({r['save_mb_per_s']:.0f} MB/s)  "
                  f"restore={r['restore_s'] * 1e3:8.2f}ms "
                  f"({r['restore_mb_per_s']:.0f} MB/s)")
    if args.mode in ("padding", "all"):
        pad = run_padding_bench(n=args.participants, rounds=args.sim_rounds,
                                steps=args.steps, seed=args.seed,
                                base_width=args.base_width)
        results["padding"] = pad
        p, u = pad["padded"], pad["unpadded"]
        print(f"drift-heavy sim, {pad['participants']} participants × "
              f"{pad['rounds']} rounds, {u['migrations']} migrations")
        print(f"  padded   : {p['wall_s']:7.2f}s  "
              f"{p['xla_compiles']} XLA compiles "
              f"({p['round_programs']} programs)")
        print(f"  unpadded : {u['wall_s']:7.2f}s  "
              f"{u['xla_compiles']} XLA compiles "
              f"({u['round_programs']} programs)")
    if args.json:
        pathlib.Path(args.json).write_text(json.dumps(results, indent=2))
        print(f"wrote {args.json}")
    return results


if __name__ == "__main__":
    main()
