"""Warn-only perf-regression gate: diff a fresh BENCH_core.json against the
committed baseline (benchmarks/BENCH_baseline.json).

  PYTHONPATH=src python benchmarks/bench_check.py BENCH_core.json
      [--baseline benchmarks/BENCH_baseline.json] [--tolerance 2.0]
      [--strict]

Per shared row it compares ``us_per_call`` (lower is faster) and warns when
the fresh value exceeds ``tolerance ×`` the baseline.  The tolerance is
deliberately generous (default 2.0×): CI containers are noisy neighbors and
the goal is catching order-of-magnitude regressions (a retrace storm, an
accidentally-serialized pipeline), not 5% drift.  Exit code is 0 unless
``--strict`` is passed AND a row regressed — the gate is advisory by
default, exactly so flaky containers cannot block merges.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

_DEFAULT_BASELINE = pathlib.Path(__file__).parent / "BENCH_baseline.json"


def compare(fresh: dict, baseline: dict, tolerance: float):
    """Yields (name, fresh_us, base_us, ratio, regressed) per shared row;
    rows with a HARNESS_ERROR on either side are skipped (reported as
    status 'error' with ratio None)."""
    f_rows, b_rows = fresh.get("rows", {}), baseline.get("rows", {})
    for name in sorted(set(f_rows) & set(b_rows)):
        f, b = f_rows[name], b_rows[name]
        if ("HARNESS_ERROR" in str(f.get("derived", ""))
                or "HARNESS_ERROR" in str(b.get("derived", ""))):
            yield name, f.get("us_per_call"), b.get("us_per_call"), None, False
            continue
        fu, bu = float(f["us_per_call"]), float(b["us_per_call"])
        if bu <= 0 or fu <= 0:
            yield name, fu, bu, None, False
            continue
        ratio = fu / bu
        yield name, fu, bu, ratio, ratio > tolerance


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff BENCH_core.json vs the committed baseline "
                    "(warn-only by default)")
    ap.add_argument("fresh", help="freshly produced BENCH_core.json")
    ap.add_argument("--baseline", default=str(_DEFAULT_BASELINE))
    ap.add_argument("--tolerance", type=float, default=2.0,
                    help="warn when fresh us_per_call > tolerance × "
                         "baseline (default 2.0 — generous on purpose)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on regression instead of warn-only")
    args = ap.parse_args(argv)

    try:
        fresh = json.loads(pathlib.Path(args.fresh).read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_check: cannot read {args.fresh}: {e}",
              file=sys.stderr)
        return 0 if not args.strict else 1
    try:
        baseline = json.loads(pathlib.Path(args.baseline).read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_check: no usable baseline ({e}) — nothing to diff",
              file=sys.stderr)
        return 0

    regressed, checked = [], 0
    for name, fu, bu, ratio, bad in compare(fresh, baseline, args.tolerance):
        if ratio is None:
            print(f"  skip  {name}: unusable timing "
                  f"(fresh={fu} base={bu})")
            continue
        checked += 1
        flag = "WARN" if bad else "  ok"
        print(f"  {flag}  {name}: {fu:.1f}us vs baseline {bu:.1f}us "
              f"({ratio:.2f}x)")
        if bad:
            regressed.append(name)
    print(f"bench_check: {checked} rows compared, {len(regressed)} over "
          f"{args.tolerance:.1f}x tolerance"
          + (f": {', '.join(regressed)}" if regressed else ""))
    if regressed and not args.strict:
        print("bench_check: advisory mode — not failing the build "
              "(pass --strict to gate)")
    return 1 if (regressed and args.strict) else 0


if __name__ == "__main__":
    raise SystemExit(main())
