"""α-compression family, analytic param counts, and the MAR cost model."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.core import cost_model, scaling
from repro.core.resources import Participant
from repro.models import registry


@pytest.mark.parametrize("arch", list_archs())
def test_analytic_param_count_matches_init(arch, key):
    cfg = get_config(arch, smoke=True)
    params = registry.init_params(cfg, key)
    real = registry.param_count(params)
    approx = scaling.param_count(cfg)
    assert abs(real - approx) / real < 0.03, (arch, real, approx)


def test_compress_family_monotone():
    cfg = get_config("qwen3-8b")
    fam = scaling.model_family(cfg, 0.5, 4)
    sizes = [scaling.param_count(c) for c in fam]
    assert all(a > b for a, b in zip(sizes, sizes[1:]))
    assert fam[0] is cfg                      # master uncompressed (M1 = M)
    for c in fam[1:]:
        assert c.d_ff % 128 == 0              # MXU/mesh alignment preserved
        assert c.d_model == cfg.d_model       # KD logit space unchanged
        assert c.vocab_size == cfg.vocab_size


def test_compress_moe_reduces_experts():
    cfg = get_config("qwen3-moe-235b-a22b")
    c2 = scaling.compress_config(cfg, 0.5, 2)
    assert c2.n_experts == 32
    assert c2.n_experts >= c2.experts_per_tok


def test_active_params_moe_smaller_than_total():
    cfg = get_config("qwen3-moe-235b-a22b")
    assert scaling.active_param_count(cfg) < 0.25 * scaling.param_count(cfg)
    # brief: ~235B total / ~22B active
    assert 1.8e11 < scaling.param_count(cfg) < 2.6e11
    assert 1.5e10 < scaling.active_param_count(cfg) < 3.0e10


def test_param_counts_match_brief_sizes():
    """Sanity vs the assigned model-card sizes (loose bands; vocab padding
    and tied embeddings shift totals slightly)."""
    bands = {
        "olmo-1b": (0.9e9, 1.6e9),
        "qwen3-8b": (7e9, 9.5e9),
        "gemma2-9b": (8e9, 11e9),
        "jamba-v0.1-52b": (4.3e10, 6.0e10),
        "minicpm-2b": (2.2e9, 3.3e9),
        "qwen2-vl-2b": (1.2e9, 2.3e9),
        "xlstm-350m": (2.8e8, 5.5e8),
    }
    for arch, (lo, hi) in bands.items():
        n = scaling.param_count(get_config(arch))
        assert lo <= n <= hi, (arch, n)


def test_cost_model_round_time_components():
    p = Participant(0, s=2.0, r=10.0, a=4, n_data=100)
    t_total = cost_model.round_time(p, 1e7, 4e6, E=2)
    assert t_total == pytest.approx(
        cost_model.train_time(p, 1e7, 2) + cost_model.comm_time(p, 4e6))
    # slower link → strictly more time
    slow = Participant(1, s=2.0, r=1.0, a=4, n_data=100)
    assert cost_model.round_time(slow, 1e7, 4e6, 2) > t_total


def test_mar_parallel_beats_sequential():
    """Eq. 9 vs Eq. 10: master-then-parallel-slaves < fully sequential."""
    for m in (2, 3, 5):
        for kappa in (0.3, 0.5, 0.8):
            par = cost_model.mar_parallel(100.0, kappa, m)
            seq = cost_model.mar_sequential(100.0, kappa, m)
            assert par <= seq + 1e-9
    # m=1: both equal the single cluster time
    assert cost_model.mar_parallel(50.0, 0.5, 1) == pytest.approx(50.0)
    assert cost_model.mar_sequential(50.0, 0.5, 1) == pytest.approx(50.0)


def test_analytic_step_flops_orders():
    cfg = get_config("olmo-1b")
    tr = scaling.analytic_step_flops(cfg, "train", 256, 4096)
    pf = scaling.analytic_step_flops(cfg, "prefill", 256, 4096)
    dc = scaling.analytic_step_flops(cfg, "decode", 256, 4096)
    assert tr > pf > dc
    assert tr == pytest.approx(3 * pf, rel=1e-6)
