"""repro.sim: deterministic event ordering, MAR drop/mask semantics, and
vmapped-vs-looped cluster-training equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cost_model
from repro.core import server as srv
from repro.core.families import cnn_family
from repro.core.resources import Participant, participants_from_matrix
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_classification, train_test_split
from repro.sim import (Arrival, Departure, EventQueue, HeterogeneitySim,
                       ResourceDrift, SimConfig, StragglerSpike, make_trace,
                       sample_profiles)

FAM = cnn_family(classes=10, in_channels=1, base_width=0.125)


def _setup(parts_V=None, n=8, samples=500, seed=0, n_data=None, **cfg_kw):
    ds = make_classification("synth-mnist", samples, seed=seed)
    train, test = train_test_split(ds)
    idx = dirichlet_partition(train.y, n, alpha=2.0, seed=seed)
    V = parts_V if parts_V is not None else sample_profiles(n, seed=seed)
    parts = participants_from_matrix(
        V, n_data=n_data if n_data is not None else [len(p) for p in idx])
    cd = [{"x": train.x[p], "y": train.y[p]} for p in idx]
    cfg = srv.FLConfig(steps_per_round=3, lr=0.08, seed=seed,
                       local_batch=8, **cfg_kw)
    eng = srv.FedRAC(parts, cd, FAM, cfg, classes=10).setup()
    testb = {"x": jnp.asarray(test.x), "y": jnp.asarray(test.y)}
    return eng, testb


# ------------------------------------------------------------ determinism
def test_event_queue_fifo_tie_break():
    q = EventQueue()
    q.push(1.0, Departure(0))
    q.push(0.0, Arrival(1))
    q.push(1.0, StragglerSpike(2))
    q.push(1.0, Arrival(3))
    assert [e for _, e in q.pop_due(0.5)] == [Arrival(1)]
    # equal timestamps pop Arrivals first (priority 0), then the other
    # classes in insertion order — the total (time, priority, seq) key
    assert [e.pid for _, e in q.pop_due(1.0)] == [3, 0, 2]
    assert len(q) == 0


def test_trace_generation_deterministic():
    a = make_trace("mixed", 10, 6, seed=7)
    b = make_trace("mixed", 10, 6, seed=7)
    assert a.events == b.events
    c = make_trace("mixed", 10, 6, seed=8)
    assert a.events != c.events


def test_sim_run_deterministic():
    def run_once():
        eng, testb = _setup(n=8, compact_to=2)
        trace = make_trace("mixed", 8, 3, seed=5)
        sim = HeterogeneitySim(eng, trace, SimConfig(rounds=3))
        rep = sim.run(testb)
        return [(r.round, r.duration, [(c.level, c.active, c.dropped,
                                        c.offline, sorted(c.masked))
                                       for c in r.clusters], r.events)
                for r in rep.rows], rep.final_acc

    rows_a, acc_a = run_once()
    rows_b, acc_b = run_once()
    assert rows_a == rows_b
    assert acc_a == acc_b


# ------------------------------------------------------------ MAR semantics
def _straggler_setup():
    """6 healthy devices, one moderate straggler (pid 6, 4× slower compute)
    and one hopeless one (pid 7), all in a single cluster with a budget that
    admits the healthy, partially fits the moderate, and excludes pid 7."""
    V = np.array([[3.0, 30.0, 8.0]] * 6
                 + [[0.75, 30.0, 8.0], [1e-4, 30.0, 8.0]])
    eng, testb = _setup(parts_V=V, n=8, compact_to=1, mar=1e9,
                        n_data=[50] * 8)
    spec = eng.specs[0]
    t = {p: cost_model.round_time(eng.parts[p], spec.flops_per_sample,
                                  spec.model_bytes, spec.E,
                                  eng.assignment.n_eff[p])
         for p in range(8)}
    spec.mar = 0.6 * t[6]          # moderate straggler fits 60% of a round
    assert max(t[p] for p in range(6)) < spec.mar < t[6] < t[7]
    return eng, testb


def test_mar_drop_excludes_stragglers_every_round():
    eng, testb = _straggler_setup()
    sim = HeterogeneitySim(eng, make_trace("stable", 8, 3),
                           SimConfig(rounds=3, mar_policy="drop"))
    rep = sim.run(testb)
    for row in rep.rows:
        c = row.clusters[0]
        assert sorted(c.violations) == [6, 7] == sorted(c.dropped)
        assert sorted(c.active) == list(range(6))
        # round time is bounded by the survivors, not the stragglers
        assert c.time <= eng.specs[0].mar


def test_mar_mask_never_grants_full_steps():
    eng, testb = _straggler_setup()
    S = eng.cfg.steps_per_round
    sim = HeterogeneitySim(eng, make_trace("stable", 8, 3),
                           SimConfig(rounds=3, mar_policy="mask"))
    rep = sim.run(testb)
    for row in rep.rows:
        c = row.clusters[0]
        assert sorted(c.violations) == [6, 7]
        # slower than the budget → strictly fewer than S local steps
        assert 0 < c.masked[6] < S
        assert 6 in c.active
        # a hopeless device (0 steps fit) degrades to a download-only drop
        assert c.masked.get(7, 0) == 0 and 7 in c.dropped


def test_mar_wait_keeps_stragglers_and_pays_eq2_time():
    eng, testb = _straggler_setup()
    sim = HeterogeneitySim(eng, make_trace("stable", 8, 2),
                           SimConfig(rounds=2, mar_policy="wait"))
    rep = sim.run(testb)
    for row in rep.rows:
        c = row.clusters[0]
        assert sorted(c.violations) == [6, 7]
        assert 7 in c.active and not c.dropped
        assert c.time > eng.specs[0].mar     # straggler-bound round (Eq. 2)


def test_departure_colliding_with_rejoin_still_applies():
    """A fresh Departure landing on the same round as a scheduled rejoin must
    net to 'rejoined, then dropped again' — not be silently swallowed."""
    eng, testb = _setup(n=8, compact_to=1, mar=1e9)
    trace = make_trace("stable", 8, 5)
    trace.events.append((1.0, Departure(2, rejoin_after=2.0)))  # rejoin @ 3
    trace.events.append((3.0, Departure(2, rejoin_after=2.0)))  # collides
    sim = HeterogeneitySim(eng, trace, SimConfig(rounds=5))
    rep = sim.run(testb)
    offline = [2 in r.clusters[0].offline for r in rep.rows]
    assert offline == [False, True, True, True, True]


def test_permanent_departure_during_rejoin_window_sticks():
    """A permanent Departure landing while the participant is transiently
    offline supersedes the pending rejoin — it must not rejoin at round 3
    and stay online forever."""
    eng, testb = _setup(n=8, compact_to=1, mar=1e9)
    trace = make_trace("stable", 8, 6)
    trace.events.append((1.0, Departure(5, rejoin_after=2.0)))  # rejoin @ 3
    trace.events.append((2.0, Departure(5, rejoin_after=None)))  # permanent
    # trace noise after the permanent dropout must not schedule a rejoin
    trace.events.append((3.0, Departure(5, rejoin_after=1.0)))
    sim = HeterogeneitySim(eng, trace, SimConfig(rounds=6))
    rep = sim.run(testb)
    offline = [5 in r.clusters[0].offline for r in rep.rows]
    assert offline == [False, True, True, True, True, True]


def test_dropout_participant_does_not_contribute():
    eng, testb = _setup(n=8, compact_to=1, mar=1e9)
    trace = make_trace("stable", 8, 3)
    trace.events.append((1.0, Departure(2, rejoin_after=1.0)))
    sim = HeterogeneitySim(eng, trace, SimConfig(rounds=3))
    rep = sim.run(testb)
    assert 2 in rep.rows[0].clusters[0].active
    assert 2 in rep.rows[1].clusters[0].offline
    assert 2 in rep.rows[2].clusters[0].active        # rejoined


# ------------------------------------------------------------ equivalence
@pytest.mark.slow
def test_vmap_matches_loop_aggregated_params():
    """The batched vmap cluster update reproduces the per-pid loop's
    aggregated params (master FedAvg and slave KD paths)."""
    results = {}
    for vm in (True, False):
        eng, testb = _setup(n=8, samples=400, compact_to=2, vmap_clusters=vm)
        eng.train(testb)
        results[vm] = eng
    assert results[True].m == results[False].m
    for lvl, pv in results[True].cluster_params.items():
        pl = results[False].cluster_params[lvl]
        for a, b in zip(jax.tree.leaves(pv), jax.tree.leaves(pl)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=1e-5)


def test_cluster_round_partial_aggregation_renormalizes():
    """Zero-weight (dropped) members leave the aggregate unchanged vs an
    explicit sub-cluster round over the survivors."""
    eng, testb = _setup(n=6, compact_to=1, mar=1e9)
    members = list(eng.assignment.members[0])
    params = eng.family.init(jax.random.PRNGKey(0), 0)
    S = eng.cfg.steps_per_round
    masks = np.ones((len(members), S), np.float32)
    weights = np.array([eng.assignment.n_eff[p] for p in members], np.float32)
    masks[2] = 0.0
    weights[2] = 0.0
    full, _ = eng.cluster_round(0, members, params, 0,
                                step_masks=jnp.asarray(masks),
                                weights=weights)
    sub_members = [p for i, p in enumerate(members) if i != 2]
    sub, _ = eng.cluster_round(0, sub_members, params, 0,
                               weights=[eng.assignment.n_eff[p]
                                        for p in sub_members])
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(sub)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_all_dropped_round_is_a_no_op():
    eng, testb = _setup(n=6, compact_to=1, mar=1e9)
    members = list(eng.assignment.members[0])
    params = eng.family.init(jax.random.PRNGKey(0), 0)
    S = eng.cfg.steps_per_round
    out, _ = eng.cluster_round(
        0, members, params, 0,
        step_masks=jnp.zeros((len(members), S), jnp.float32),
        weights=np.zeros(len(members), np.float32))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------ padding
def test_capacity_bucketing():
    eng, _ = _setup(n=6, compact_to=1, mar=1e9)
    eng.cfg.pad_max = 16
    for c, cap in ((1, 1), (2, 2), (3, 4), (5, 8), (9, 16), (16, 16),
                   (17, 32), (33, 48)):
        assert eng._capacity(c) == cap, (c, cap)
    # non-power-of-two pad_max: the pow2 branch is capped at pad_max so
    # capacities stay monotone and never exceed the bucket granularity
    eng.cfg.pad_max = 48
    for c, cap in ((33, 48), (47, 48), (48, 48), (49, 96)):
        assert eng._capacity(c) == cap, (c, cap)
    caps = [eng._capacity(c) for c in range(1, 100)]
    assert all(a <= b for a, b in zip(caps, caps[1:]))
    eng.cfg.pad_clusters = False
    assert eng._capacity(5) == 5


def test_padded_round_matches_unpadded_exactly():
    """Padding slots (zero batches, zero step-masks, zero weights) must not
    perturb the renormalized FedAvg — same round, padded vs exact-C."""
    eng, _ = _setup(n=6, compact_to=1, mar=1e9)
    members = list(eng.assignment.members[0])    # C=6 → capacity 8
    params = eng.family.init(jax.random.PRNGKey(0), 0)
    eng.cfg.pad_clusters = True
    padded, pl = eng.cluster_round(0, members, params, 0)
    assert eng._capacity(len(members)) > len(members)
    eng.cfg.pad_clusters = False
    eng._programs.clear()
    exact, el = eng.cluster_round(0, members, params, 0)
    for a, b in zip(jax.tree.leaves(padded), jax.tree.leaves(exact)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-6)
    assert pl.shape == el.shape == (len(members),)
    np.testing.assert_allclose(np.asarray(pl), np.asarray(el),
                               rtol=2e-4, atol=1e-6)


def test_procedure2_reassignment_does_not_retrace():
    """≥5 drift-driven cluster migrations must reuse the per-capacity round
    programs: each jitted program compiles exactly once."""
    eng, testb = _setup(n=10, compact_to=2)       # auto MAR: placement bites
    trace = make_trace("stable", 10, 8)
    # bounce one master member across the cluster boundary every round:
    # alternating extreme down/up drifts make each re-placement a migration
    pid = eng.assignment.members[0][0]
    for r in range(7):
        mult = 0.02 if r % 2 == 0 else 50.0
        trace.events.append((float(r), ResourceDrift(
            pid, s_mult=mult, r_mult=mult, a_mult=1.0)))
    sim = HeterogeneitySim(eng, trace, SimConfig(rounds=8))
    rep = sim.run(testb)
    migrations = sum(ev.count("→") for r in rep.rows for ev in r.events)
    assert migrations >= 5, f"only {migrations} migrations in trace"
    stats = eng.compile_stats()
    assert stats, "no round programs were built"
    retraced = {k: v for k, v in stats.items() if v != 1}
    assert not retraced, f"programs retraced: {retraced}"


# ------------------------------------------------------------ buffered async
def test_buffer_policy_banks_flushes_and_bounds_round_time():
    eng, testb = _straggler_setup()
    eng.cfg.aggregation = "buffered"
    sim = HeterogeneitySim(eng, make_trace("stable", 8, 4),
                           SimConfig(rounds=4, mar_policy="buffer"))
    rep = sim.run(testb)
    for i, row in enumerate(rep.rows):
        c = row.clusters[0]
        assert sorted(c.violations) == [6, 7] == sorted(c.banked)
        assert sorted(c.active) == list(range(6))
        assert not c.dropped
        # stragglers are off the critical path: survivors bound the round
        assert c.time <= eng.specs[0].mar
        # the previous round's banked updates are merged the next round;
        # the final round's bank is terminally flushed into the last row
        want = 0 if i == 0 else (4 if i == len(rep.rows) - 1 else 2)
        assert c.flushed == want
    s = rep.summary()
    # every banked update reaches an aggregate — nothing thrown away
    assert s["banked_total"] == 8 == s["flushed_total"]
    assert s["participation_rate"] == 1.0


def test_buffer_policy_all_members_banked_then_flushed():
    """A cluster where EVERY online member violates MAR: the round aggregates
    nothing (params unchanged) but every update is banked and flushes into
    the next round — no crash, no lost work."""
    eng, testb = _setup(n=6, compact_to=1, mar=1e9)
    eng.cfg.aggregation = "buffered"
    eng.specs[0].mar = 1e-9                       # everyone is late
    p0 = eng.family.init(jax.random.PRNGKey(eng.cfg.seed), 0)
    sim = HeterogeneitySim(eng, make_trace("stable", 6, 3),
                           SimConfig(rounds=3, mar_policy="buffer"))
    rep = sim.run(testb)
    c0 = rep.rows[0].clusters[0]
    assert sorted(c0.banked) == list(range(6)) and not c0.active
    assert c0.flushed == 0
    # round 1 flushes all six banked updates; params moved off the init
    c1 = rep.rows[1].clusters[0]
    assert c1.flushed == 6
    moved = any(not np.allclose(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(sim.params[0]),
                                jax.tree.leaves(p0)))
    assert moved
    assert rep.summary()["participation_rate"] == 1.0


def test_buffer_flush_during_offline_blip_keeps_anchor():
    """Ripe banked updates flushing into a round where EVERY member is
    offline must anchor on the current aggregate (live n_eff weight), not
    replace it with the discounted stale average."""
    eng, testb = _setup(n=6, compact_to=1, mar=1e9)
    eng.cfg.aggregation = "buffered"
    eng.specs[0].mar = 1e-9                       # round 0: everyone banked
    trace = make_trace("stable", 6, 3)
    for pid in range(6):                          # round 1: everyone offline
        trace.events.append((1.0, Departure(pid, rejoin_after=1.0)))
    sim = HeterogeneitySim(eng, trace, SimConfig(rounds=3,
                                                 mar_policy="buffer"))
    rep = sim.run(testb)
    c1 = rep.rows[1].clusters[0]
    assert len(c1.offline) == 6 and not c1.active
    assert c1.flushed == 6                        # flush-only round, no crash
    # the anchor kept a majority share: the flushed model must not coincide
    # with the unanchored pure-stale average (weights: W=6·n_eff vs Σu·0.6)
    assert rep.summary()["banked_total"] == rep.summary()["flushed_total"]


def test_buffer_policy_requires_buffered_aggregation():
    eng, _ = _setup(n=6, compact_to=1, mar=1e9)
    with pytest.raises(ValueError, match="buffered"):
        HeterogeneitySim(eng, make_trace("stable", 6, 2),
                         SimConfig(rounds=2, mar_policy="buffer"))


def test_buffered_merge_is_weighted_convex_combination():
    """cluster_round with a banked contribution equals the hand-computed
    FedAvg over live members and the stale params at their raw weights."""
    eng, _ = _setup(n=6, compact_to=1, mar=1e9)
    members = list(eng.assignment.members[0])
    params = eng.family.init(jax.random.PRNGKey(0), 0)
    stale = eng.family.init(jax.random.PRNGKey(1), 0)   # a banked update
    w = np.array([eng.assignment.n_eff[p] for p in members], np.float32)
    u = 2.5
    # reference: run the same round synchronously, then mix in stale params
    sync, _ = eng.cluster_round(0, members, params, 0, weights=w)
    W = float(w.sum())
    want = jax.tree.map(lambda a, b: (W * a + u * b) / (W + u), sync, stale)
    got, _ = eng.cluster_round(0, members, params, 0, weights=w,
                               buffered=[(stale, u)])
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-6)


@pytest.mark.slow
def test_buffered_convergence_smoke():
    """Under permanent stragglers the buffered schedule still learns: the
    master cluster clearly beats the 0.10 random baseline, and the banked
    updates keep total participation at 100%."""
    eng, testb = _straggler_setup()
    eng.cfg.aggregation = "buffered"
    sim = HeterogeneitySim(eng, make_trace("stable", 8, 6),
                           SimConfig(rounds=6, mar_policy="buffer",
                                     eval_every=6))
    rep = sim.run(testb)
    assert rep.final_acc[0] > 0.2
    assert rep.summary()["participation_rate"] == 1.0


@pytest.mark.slow
def test_padded_vs_unpadded_full_train_equivalence():
    """End-to-end: FedRAC.train with capacity padding reproduces the exact-C
    path's aggregated params (rtol 2e-4, matching the loop/vmap test)."""
    results = {}
    for pad in (True, False):
        eng, testb = _setup(n=8, samples=400, compact_to=2, pad_clusters=pad)
        eng.train(testb)
        results[pad] = eng
    for lvl, pv in results[True].cluster_params.items():
        pl = results[False].cluster_params[lvl]
        for a, b in zip(jax.tree.leaves(pv), jax.tree.leaves(pl)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=1e-5)
