"""Resource-aware clustering: paper-exact anchors + hypothesis properties."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import clustering as C
from repro.core import resources as R


# ------------------------------------------------------------- paper anchors
def test_table_i_normalization_matches_paper():
    """Table I row p2 = [50,15,30] → normalized [0,1,1]; p5 → [1,0,0]."""
    Vb = R.unit_normalize(R.TABLE_I)
    np.testing.assert_allclose(Vb[1], [0.0, 1.0, 1.0])
    np.testing.assert_allclose(Vb[4], [1.0, 0.0, 0.0])
    np.testing.assert_allclose(Vb[0], [0.5, 0.375, 0.5])


def test_example2_table_i_gives_k3():
    """Example 2: 10 participants, λ=1/3 → optimal k = 3 (k_max=⌊√10⌋=3)."""
    res = C.optimal_clusters(R.TABLE_I, R.LAMBDA_EQUAL, seed=0)
    assert res.k == 3


def test_table_iv_outcomes_with_paper_kmeans():
    """Table IV (single-run k-means, seed 3): unnormalized → k=4 (transmission
    dominates); normalized λ=(0.4,0.4,0.2) → k=5."""
    a = C.optimal_clusters(R.TABLE_III, R.LAMBDA_EQUAL, normalize=False,
                           seed=3, restarts=1)
    b = C.optimal_clusters(R.TABLE_III, R.LAMBDA_PAPER, normalize=True,
                           seed=3, restarts=1)
    assert a.k == 4
    assert b.k == 5


def test_multirestart_kmeans_never_worsens_inertia():
    """More restarts can only improve k-means' own objective: the strong
    restart set starts from the same rng stream, so it contains the weak
    run's init (k-means optimizes inertia, not DI — the DI argmax may move)."""
    Vb = R.unit_normalize(R.TABLE_III)
    X = Vb * np.sqrt(np.asarray(R.LAMBDA_PAPER))

    def inertia(lab, cents):
        return float(((X - cents[lab]) ** 2).sum())

    for k in (3, 4, 5):
        weak = inertia(*C.kmeans(X, k, seed=3, restarts=1))
        strong = inertia(*C.kmeans(X, k, seed=3, restarts=8))
        assert strong <= weak + 1e-9


def test_dbscan_di_decreases_with_k_table_ii():
    """Paper Table II: DBSCAN's DI falls with k (k=2 looks 'optimal')."""
    Vb = R.unit_normalize(R.TABLE_III)
    X = Vb * np.sqrt(np.asarray(R.LAMBDA_PAPER))
    S = R.similarity_matrix(Vb, R.LAMBDA_PAPER)
    dis = {}
    for k in (2, 4, 6):
        lab = C.dbscan_at_k(X, k)
        if lab is not None:
            dis[k] = C.dunn_index(S, lab)
    assert len(dis) >= 2
    ks = sorted(dis)
    assert dis[ks[0]] >= dis[ks[-1]]


def test_cluster_ordering_by_resources():
    res = C.optimal_clusters(R.TABLE_III, R.LAMBDA_PAPER, seed=3)
    lab = C.order_clusters_by_resources(res.normalized, res.labels,
                                        R.LAMBDA_PAPER)
    lam = np.asarray(R.LAMBDA_PAPER)
    means = [(res.normalized[lab == f] * lam).sum(axis=1).mean()
             for f in range(len(np.unique(lab)))]
    assert all(means[i] >= means[i + 1] - 1e-9 for i in range(len(means) - 1))


def test_cluster_ordering_respects_lambda_weights():
    """λ-weighted ordering must disagree with the unweighted sum when one
    cluster is rich only on the low-λ axis: memory-heavy devices (λ_a=0.2)
    outscore compute/radio-heavy ones (λ_s=λ_r=0.4 each) on the raw sum but
    not under the paper's weighting — the master slot must go to the
    λ-weighted winner."""
    V = np.array([[0.1, 0.1, 1.0]] * 3       # raw sum 1.2, λ-weighted 0.28
                 + [[0.5, 0.5, 0.0]] * 3)    # raw sum 1.0, λ-weighted 0.40
    labels = np.array([0] * 3 + [1] * 3)
    lam = (0.4, 0.4, 0.2)
    unweighted = C.order_clusters_by_resources(V, labels)
    weighted = C.order_clusters_by_resources(V, labels, lam)
    # unweighted: memory-heavy cluster wins the master slot (label 0)
    assert list(unweighted[:3]) == [0, 0, 0]
    # λ-weighted: compute/radio-heavy cluster is the master
    assert list(weighted[3:]) == [0, 0, 0]
    assert list(weighted) != list(unweighted)


# ------------------------------------------------------------- properties
@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_normalization_bounds(seed):
    rng = np.random.default_rng(seed)
    V = rng.uniform(0.1, 100, (12, 3))
    Vb = R.unit_normalize(V)
    assert Vb.min() >= 0.0 and Vb.max() <= 1.0 + 1e-12
    assert np.any(np.isclose(Vb.max(axis=0), 1.0))


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_similarity_is_metric_like(seed):
    rng = np.random.default_rng(seed)
    Vb = rng.uniform(0, 1, (10, 3))
    lam = rng.dirichlet([1, 1, 1])
    S = R.similarity_matrix(Vb, lam)
    assert np.allclose(S, S.T)
    assert np.allclose(np.diag(S), 0)
    assert (S >= 0).all()
    # triangle inequality (weighted Euclidean IS a metric)
    for _ in range(10):
        i, j, k = rng.integers(0, 10, 3)
        assert S[i, j] <= S[i, k] + S[k, j] + 1e-9


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_dunn_index_positive_and_merge_insensitive(seed):
    rng = np.random.default_rng(seed)
    # two well-separated blobs → k=2 should score high DI
    a = rng.normal(0.1, 0.02, (8, 3))
    b = rng.normal(0.9, 0.02, (8, 3))
    V = np.clip(np.concatenate([a, b]), 0, 1)
    S = R.similarity_matrix(V, (1 / 3, 1 / 3, 1 / 3))
    labels = np.array([0] * 8 + [1] * 8)
    di = C.dunn_index(S, labels)
    assert di > 1.0       # separation ≫ diameter
    # random split scores worse
    rand = rng.permutation(labels)
    assert C.dunn_index(S, rand) <= di


def test_kmeans_recovers_separated_clusters():
    rng = np.random.default_rng(1)
    centers = np.array([[0.1, 0.1, 0.1], [0.9, 0.9, 0.9], [0.1, 0.9, 0.5]])
    X = np.concatenate([c + rng.normal(0, 0.03, (15, 3)) for c in centers])
    lab, _ = C.kmeans(X, 3, seed=0)
    # every ground-truth group maps to exactly one cluster id
    for g in range(3):
        assert len(np.unique(lab[g * 15:(g + 1) * 15])) == 1
    assert len(np.unique(lab)) == 3


def test_optics_at_k_returns_k_clusters():
    Vb = R.unit_normalize(R.TABLE_III)
    for k in (2, 3, 4):
        lab = C.optics_at_k(Vb, k)
        assert len(np.unique(lab)) == k
