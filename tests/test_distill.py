"""KD loss (§IV-C): math properties + Pallas kernel vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import distill
from repro.kernels.distill import ops as dops
from repro.kernels.distill import ref as dref


def test_kl_zero_when_teacher_equals_student(key):
    t = jax.random.normal(key, (8, 50))
    kl = distill.kl_teacher_student(t, t, T=2.0)
    np.testing.assert_allclose(np.asarray(kl), 0.0, atol=1e-5)


def test_kl_positive(key):
    t = jax.random.normal(key, (8, 50))
    s = jax.random.normal(jax.random.fold_in(key, 1), (8, 50))
    assert (np.asarray(distill.kl_teacher_student(t, s, T=2.0)) > 0).all()


def test_kd_loss_reduces_to_ce_at_alpha_1(key):
    s = jax.random.normal(key, (8, 50))
    t = jax.random.normal(jax.random.fold_in(key, 1), (8, 50))
    y = jax.random.randint(key, (8,), 0, 50)
    kd = distill.kd_loss(s, y, t, T=2.0, alpha=1.0)
    ce = jnp.mean(distill.ce_loss(s, y))
    np.testing.assert_allclose(float(kd), float(ce), rtol=1e-6)


def test_vocab_mask_excludes_padding(key):
    s = jax.random.normal(key, (4, 32))
    t = jax.random.normal(jax.random.fold_in(key, 1), (4, 32))
    y = jax.random.randint(key, (4,), 0, 24)
    mask = jnp.arange(32) < 24
    # huge logits in the padded region must not change the masked loss
    s_bad = s.at[:, 24:].set(100.0)
    a = distill.kd_loss(s, y, t, valid_mask=mask)
    b = distill.kd_loss(s_bad, y, t, valid_mask=mask)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-5)


@pytest.mark.parametrize("N,V,T,alpha", [
    (8, 512, 1.0, 0.5), (16, 1000, 2.0, 0.3), (4, 2048, 4.0, 0.0),
    (128, 512, 2.0, 0.3), (8, 7000, 3.0, 0.7),
])
def test_kernel_matches_ref_sweep(key, N, V, T, alpha):
    s = jax.random.normal(key, (N, V)) * 3
    t = jax.random.normal(jax.random.fold_in(key, 7), (N, V)) * 3
    y = jax.random.randint(key, (N,), 0, V)
    got = float(dops.kd_loss(s, y, t, T=T, alpha=alpha))
    want = float(jnp.mean(dref.kd_loss_rows(s, t, y, T=T, alpha=alpha)))
    assert abs(got - want) < 1e-3 * max(1.0, abs(want))


def test_kernel_bf16_inputs(key):
    s = (jax.random.normal(key, (16, 512)) * 3).astype(jnp.bfloat16)
    t = (jax.random.normal(jax.random.fold_in(key, 7), (16, 512)) * 3).astype(jnp.bfloat16)
    y = jax.random.randint(key, (16,), 0, 512)
    got = float(dops.kd_loss(s, y, t))
    want = float(jnp.mean(dref.kd_loss_rows(s, t, y)))
    assert abs(got - want) < 5e-2 * max(1.0, abs(want))


def test_kernel_matches_core_jnp_path(key):
    """core.distill.kd_loss(use_kernel=True) ≡ jnp path on padded vocab."""
    s = jax.random.normal(key, (2, 6, 300)) * 2      # (B,S,V) logits
    t = jax.random.normal(jax.random.fold_in(key, 1), (2, 6, 300)) * 2
    y = jax.random.randint(key, (2, 6), 0, 300)
    a = float(distill.kd_loss(s, y, t, T=2.0, alpha=0.3))
    b = float(distill.kd_loss(s, y, t, T=2.0, alpha=0.3, use_kernel=True))
    assert abs(a - b) < 2e-3 * max(1.0, abs(a))


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_kernel_property_random(seed):
    k = jax.random.PRNGKey(seed)
    N = int(jax.random.randint(k, (), 2, 40))
    V = int(jax.random.randint(jax.random.fold_in(k, 1), (), 50, 3000))
    T = float(jax.random.uniform(jax.random.fold_in(k, 2), (), minval=0.5,
                                 maxval=6.0))
    s = jax.random.normal(jax.random.fold_in(k, 3), (N, V)) * 4
    t = jax.random.normal(jax.random.fold_in(k, 4), (N, V)) * 4
    y = jax.random.randint(jax.random.fold_in(k, 5), (N,), 0, V)
    got = float(dops.kd_loss(s, y, t, T=T, alpha=0.3))
    want = float(jnp.mean(dref.kd_loss_rows(s, t, y, T=T, alpha=0.3)))
    assert np.isfinite(got)
    assert abs(got - want) < 2e-3 * max(1.0, abs(want))
