"""HLO collective parser + roofline term unit tests."""
from repro.launch import hlo_analysis as H


HLO = """
  %ag = bf16[256,4096]{1,0} all-gather(%x), replica_groups=...
  %ar.1 = f32[1024]{0} all-reduce(%y), to_apply=%sum
  %t = (f32[8,128]{1,0}, f32[8]{0}) all-reduce-start(%a, %b)
  %td = (f32[8,128]{1,0}, f32[8]{0}) all-reduce-done(%t)
  %rs = bf16[64,64]{1,0} reduce-scatter(%z), dimensions={0}
  %cp = bf16[2,2]{1,0} collective-permute(%w), source_target_pairs=...
  %a2a = f32[16,16]{1,0} all-to-all(%v), dimensions={1}
  %dot = f32[128,128]{1,0} dot(%p, %q)
"""


def test_collective_bytes_parsing():
    out = H.collective_bytes(HLO)
    b = out["bytes"]
    assert b["all-gather"] == 256 * 4096 * 2
    # plain all-reduce + the -start tuple (done is skipped)
    assert b["all-reduce"] == 1024 * 4 + (8 * 128 * 4 + 8 * 4)
    assert b["reduce-scatter"] == 64 * 64 * 2
    assert b["collective-permute"] == 2 * 2 * 2
    assert b["all-to-all"] == 16 * 16 * 4
    assert out["counts"]["all-reduce"] == 2
    assert out["total"] == sum(b.values())


def test_dot_not_counted():
    out = H.collective_bytes("%dot = f32[128,128]{1,0} dot(%p, %q)")
    assert out["total"] == 0


def test_roofline_terms_and_dominance():
    r = H.Roofline(flops_per_device=197e12, bytes_per_device=819e9,
                   collective_bytes_per_device=0.0, chips=4,
                   model_flops_total=4 * 197e12 / 2)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 1.0) < 1e-9
    assert r.collective_s == 0.0
    assert r.useful_flops_ratio == 0.5
    r2 = H.Roofline(1.0, 1.0, 50e9 * 10, chips=1)
    assert r2.dominant == "collective"
    assert abs(r2.collective_s - 10.0) < 1e-9


def test_tuple_shape_bytes():
    assert H._shape_bytes("(f32[4,4]{1,0}, bf16[2]{0})") == 64 + 4
    assert H._shape_bytes("pred[128]") == 128
