"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see ONE device
(the 512-device forcing belongs exclusively to launch/dryrun.py)."""
import jax
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def tiny_fl_setup():
    """Small federated dataset + participants shared across FL tests."""
    from repro.core.resources import TABLE_III, participants_from_matrix
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import make_classification, train_test_split

    ds = make_classification("synth-mnist", 1200, seed=0)
    train, test = train_test_split(ds)
    idx = dirichlet_partition(train.y, 20, alpha=1.0, seed=0)
    parts = participants_from_matrix(TABLE_III[:20],
                                     n_data=[len(p) for p in idx])
    client_data = [{"x": train.x[p], "y": train.y[p]} for p in idx]
    return parts, client_data, train, test
