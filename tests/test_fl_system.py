"""End-to-end Fed-RAC system behaviour + baselines (integration tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as bl
from repro.core import server as srv
from repro.core.families import cnn_family
from repro.models import cnn

FAM = cnn_family(classes=10, in_channels=1, base_width=0.125)
CFG = dict(rounds=6, steps_per_round=4, lr=0.08, seed=3, local_batch=16)


def _testb(test):
    return {"x": jnp.asarray(test.x), "y": jnp.asarray(test.y)}


@pytest.fixture(scope="module")
def fedrac_result(tiny_fl_setup):
    parts, client_data, train, test = tiny_fl_setup
    cfg = srv.FLConfig(compact_to=3, **CFG)
    eng = srv.FedRAC(parts, client_data, FAM, cfg, classes=10).setup()
    res = eng.train(_testb(test))
    return eng, res


def test_fedrac_learns(fedrac_result):
    """Passes since the Procedure-1 k-selection fix: the corrected Dunn/
    k-means++ clustering yields a stronger master cluster at the same
    CPU-scale round budget."""
    eng, res = fedrac_result
    assert res.global_acc > 0.22          # 10 classes, random = 0.10
    assert res.final_acc[0] > 0.30        # master cluster trains properly


def test_fedrac_all_participants_used(fedrac_result):
    eng, res = fedrac_result
    assigned = [p for mem in res.assignment.members.values() for p in mem]
    assert sorted(assigned) == list(range(20))   # no straggler discarded


def test_fedrac_clusters_ordered(fedrac_result):
    eng, res = fedrac_result
    assert res.m == 3
    assert res.k_optimal >= 2
    assert max(res.di_values.values()) > 0


def test_master_slave_kd_helps_small_model(tiny_fl_setup):
    """Fig. 3 mechanism, isolated: with a WELL-TRAINED master as teacher, a
    level-2 slave distilled on limited, CLASS-SKEWED data beats the same
    model trained on the same data with plain CE — the teacher's soft
    targets carry signal about the classes missing from the slave's shard
    (the paper's leave-one-out motivation for §IV-C), which no amount of
    hard-label training can recover.  A small α/T grid stands in for the
    server's KD hyperparameter sweep; the 48-step budget gives the student
    room to exploit the soft targets (at the old 24-step budget every KD
    setting trailed CE — the former xfail)."""
    from repro.core.client import local_update
    from repro.data.sampler import sample_batches
    parts, client_data, train, test = tiny_fl_setup
    key = jax.random.PRNGKey(0)
    testb = _testb(test)

    # teacher: master model trained centrally to decent accuracy
    teacher = FAM.init(key, 0)
    loss0 = jax.tree_util.Partial(FAM.loss_and_logits, 0)
    batches = jax.tree.map(jnp.asarray, sample_batches(
        train.x, train.y, 32, 60, seed=0))
    teacher, _ = jax.jit(lambda p, b: local_update(loss0, p, b, 0.08))(
        teacher, batches)
    t_acc = float(jnp.mean(jnp.argmax(FAM.loss_and_logits(0, teacher, testb)[1],
                                      -1) == testb["y"]))
    assert t_acc > 0.5

    # student: level-2 slave on limited data covering only classes 0-5
    keep = train.y < 6
    sx, sy = train.x[keep][:150], train.y[keep][:150]
    small = jax.tree.map(jnp.asarray, sample_batches(sx, sy, 16, 48, seed=1))
    loss2 = jax.tree_util.Partial(FAM.loss_and_logits, 2)
    t_logits = jax.vmap(lambda b: loss0(teacher, b)[1])(small)
    s0 = FAM.init(jax.random.fold_in(key, 5), 2)

    def accuracy(p):
        return float(jnp.mean(jnp.argmax(
            FAM.loss_and_logits(2, p, testb)[1], -1) == testb["y"]))

    ce_student, _ = jax.jit(lambda p, b: local_update(loss2, p, b, 0.08))(
        s0, small)
    acc_ce = accuracy(ce_student)
    acc_kd = max(
        accuracy(jax.jit(lambda p, b, t: local_update(
            loss2, p, b, 0.08, teacher_logits=t, kd_T=T, kd_alpha=a))(
            s0, small, t_logits)[0])
        for a in (0.3, 0.5, 0.7) for T in (2.0, 4.0))
    assert acc_kd > acc_ce, (acc_kd, acc_ce)


def _loss_fn(params, batch):
    logits = cnn.forward(params, batch["x"])
    lse = jax.nn.logsumexp(logits, -1)
    picked = jnp.take_along_axis(logits, batch["y"][:, None], -1)[:, 0]
    return jnp.mean(lse - picked), logits


def test_baselines_run_and_learn(tiny_fl_setup):
    parts, client_data, train, test = tiny_fl_setup
    testb = _testb(test)
    cfg = bl.BaselineConfig(rounds=4, steps_per_round=3, lr=0.08, seed=0)
    init = cnn.init_params(jax.random.PRNGKey(0), base_width=0.125 * 0.25)
    _, h_avg = bl.fedavg(_loss_fn, init, parts, client_data, testb, cfg)
    _, h_prox = bl.fedprox(_loss_fn, init, parts, client_data, testb, cfg)
    _, h_oort = bl.oort(_loss_fn, init, parts, client_data, testb, cfg,
                        flops_per_sample=1e6, model_bytes=2e5)
    for h in (h_avg, h_prox, h_oort):
        assert len(h) == 4 and h[-1] > 0.15


def test_heterofl_runs(tiny_fl_setup):
    parts, client_data, train, test = tiny_fl_setup
    levels = {p.pid: p.pid % 3 for p in parts}
    cfg = bl.BaselineConfig(rounds=6, steps_per_round=3, lr=0.08, seed=0,
                            alpha=0.5)
    _, hist = bl.heterofl(parts, client_data, levels, _testb(test), cfg,
                          in_channels=1, classes=10, levels=3)
    # HeteroFL's sliced aggregation is noisy early; it must clearly exceed
    # the 0.10 random baseline within 6 rounds
    assert len(hist) == 6 and max(hist) > 0.15


def test_oort_selects_fewer_clients(tiny_fl_setup):
    parts, client_data, train, test = tiny_fl_setup
    cfg = bl.BaselineConfig(rounds=1, steps_per_round=2, lr=0.05,
                            oort_frac=0.3, seed=0)
    init = cnn.init_params(jax.random.PRNGKey(0), base_width=0.125 * 0.25)
    # selection function is internal; behavioural check: runs fine + history
    _, h = bl.oort(_loss_fn, init, parts, client_data, _testb(test), cfg,
                   flops_per_sample=1e6, model_bytes=2e5)
    assert len(h) == 1
