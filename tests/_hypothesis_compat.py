"""Optional-hypothesis shim: re-exports ``given``/``settings``/``st`` when
hypothesis is installed; otherwise provides stand-ins that mark the property
tests skipped (via ``pytest.importorskip``) while letting the rest of the
module collect and run.  Install the real thing with ``pip install -e .[dev]``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    import pytest

    class _StrategyStub:
        """Accepts any strategy construction (st.integers(...), st.lists(...))."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*_a, **_k):
        def deco(fn):
            def skipper(*a, **k):
                pytest.importorskip("hypothesis")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn
