"""Cross-path equivalence matrix: ONE golden suite for the five execution
paths × mesh shapes × aggregation schedules.

The paths under test:
  * ``loop``      — an independent per-pid reference loop (host FedAvg);
  * ``vmap``      — ``FedRAC.cluster_round`` (batched one-round program);
  * ``dispatch``  — scan-fused blocks (``FedRAC.dispatch_rounds``) at block
                    widths R ∈ {1, 8};
  * dispatch on a mesh — 1D member-sharded (``8x1``) and 2D
    (data × model) plane-column-sharded (``4x2``, ``2x4``) shard_map
    programs, plus the degenerate ``1x1``.

Historically the legacy paths drew batches from a host numpy stream and the
dispatch path from the in-program ``data/device_sampler`` stream, so
cross-path comparisons were only statistical.  ``StreamBridgedFedRAC``
closes that gap: its ``_client_batches`` replays the device-sampler draws
(keyed on absolute round × global member slot) on the host, so EVERY path
sees bit-identical batches and the whole matrix must agree to rtol 2e-4 on
the final parameters AND the per-round per-member losses — replacing the
scattered pairwise checks that previously lived in ``test_dispatch.py`` /
``test_mesh_plane.py``.

Coverage tiers (same scheme as ``test_mesh_plane.py``): the no-mesh and
``1x1`` columns always run; the 8-device columns run in-process when the
backend has ≥8 devices (CI mesh/mesh2d lanes) and through one slow
subprocess wrapper for tier-1.
"""
import functools
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.run_state import make_checkpointer
from repro.core import aggregation
from repro.core import server as srv
from repro.core.client import local_update
from repro.core.families import mlp_family
from repro.core.resources import participants_from_matrix
from repro.data import device_sampler
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_classification, train_test_split
from repro.launch.mesh import make_sim_mesh
from repro.sim import HeterogeneitySim, SimConfig, make_trace, sample_profiles
from repro.sim.faults import FaultInjector, FaultPlan, SimulatedCrash

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RTOL, ATOL = 2e-4, 1e-5
ROUNDS = 6

eightway = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 forced host devices (CI mesh lanes or the slow "
           "subprocess wrapper below)")


class StreamBridgedFedRAC(srv.FedRAC):
    """FedRAC whose legacy host batching replays the dispatch path's
    device-sampler stream, keyed on (absolute round, global member slot) —
    the bridge that makes loop/vmap/dispatch numerically comparable."""

    def _client_batches(self, pid, r, balanced):
        d = self.client_data[pid]
        slot = self._member_slot(pid)
        key = device_sampler.round_key(self.cfg.seed, r)
        steps, batch = self.cfg.steps_per_round, self.cfg.local_batch
        if balanced:
            table, counts = self._class_table(pid)
            idx = device_sampler.balanced_indices(
                key, steps, batch, jnp.asarray(table)[None],
                jnp.asarray(counts)[None], offset=slot)
        else:
            idx = device_sampler.uniform_indices(
                key, steps, batch,
                jnp.asarray([len(d["y"])], jnp.int32), offset=slot)
        idx = np.asarray(idx)[0]
        return {"x": d["x"][idx], "y": d["y"][idx]}

    def _member_slot(self, pid: int) -> int:
        for members in self.assignment.members.values():
            if pid in members:
                return list(members).index(pid)
        raise KeyError(pid)


def _build(mesh_shape=None, n=8, seed=0, family=None, **cfg_kw):
    ds = make_classification("synth-mnist", 400, seed=seed)
    train, test = train_test_split(ds)
    idx = dirichlet_partition(train.y, n, alpha=2.0, seed=seed)
    parts = participants_from_matrix(sample_profiles(n, seed=seed),
                                     n_data=[len(p) for p in idx])
    cd = [{"x": train.x[p], "y": train.y[p]} for p in idx]
    # auto-calibrated MAR splits the 8 participants ~3 master / ~5 slave,
    # so the KD column trains a real slave cluster (and C=3/5 exercises the
    # zero-row padding on every mesh width)
    cfg = srv.FLConfig(steps_per_round=3, lr=0.08, seed=seed, local_batch=8,
                       **({"compact_to": 2,
                           "rounds_per_dispatch": 8} | cfg_kw))
    mesh = make_sim_mesh(mesh_shape) if mesh_shape else None
    eng = StreamBridgedFedRAC(parts, cd, family or mlp_family(), cfg,
                              classes=10, mesh=mesh).setup()
    testb = {"x": jnp.asarray(test.x), "y": jnp.asarray(test.y)}
    return eng, testb


def _teacher(eng):
    return eng.family.init(jax.random.PRNGKey(42), 0)


# ------------------------------------------------------------ the five paths
def _run_loop(eng, level, members, rounds, teacher=None):
    """Independent golden reference: per-pid local_update + host FedAvg."""
    cfg = eng.cfg
    loss_fn = jax.tree_util.Partial(eng.family.loss_and_logits, level)
    t_loss_fn = jax.tree_util.Partial(eng.family.loss_and_logits, 0)
    params = eng.family.init(jax.random.PRNGKey(cfg.seed + level), level)
    weights = aggregation.normalized_weights(
        [eng.assignment.n_eff.get(p, 1) for p in members])
    losses_all = []
    for r in range(rounds):
        new_params, losses = [], []
        for pid in members:
            batches = jax.tree.map(jnp.asarray, eng._client_batches(
                pid, r, cfg.class_balanced and level == 0))
            tl = None
            if teacher is not None and cfg.use_kd:
                tl = jax.vmap(lambda b: t_loss_fn(teacher, b)[1])(batches)
            p_new, loss = local_update(loss_fn, params, batches, cfg.lr,
                                       teacher_logits=tl, kd_T=cfg.kd_T,
                                       kd_alpha=cfg.kd_alpha)
            new_params.append(p_new)
            losses.append(float(loss))
        stack = jax.tree.map(lambda *xs: jnp.stack(xs), *new_params)
        params = aggregation.aggregate(stack, weights)
        losses_all.append(losses)
    return params, np.asarray(losses_all, np.float32)


def _run_vmap(eng, level, members, rounds, teacher=None):
    """One batched cluster_round program per round (the legacy fast path)."""
    params = eng.family.init(
        jax.random.PRNGKey(eng.cfg.seed + level), level)
    weights = [eng.assignment.n_eff.get(p, 1) for p in members]
    losses = []
    for r in range(rounds):
        params, l = eng.cluster_round(level, members, params, r,
                                      teacher=teacher, weights=weights)
        losses.append(np.asarray(l))
    return params, np.stack(losses)


def _run_dispatch(eng, level, members, rounds, R, teacher=None):
    """Scan-fused blocks of width R (on whatever mesh ``eng`` carries)."""
    plane = eng.plane_of(level, eng.family.init(
        jax.random.PRNGKey(eng.cfg.seed + level), level))
    losses, r = [], 0
    while r < rounds:
        L = min(R, rounds - r)
        out = eng.dispatch_rounds(level, members, plane, r, L,
                                  teacher=teacher)
        plane = out.plane
        losses.append(np.asarray(out.losses))
        r += L
    return eng.params_of(level, plane), np.concatenate(losses)


def _assert_cell(golden, got, tag):
    gp, gl = golden
    p, l = got
    for x, y in zip(jax.tree.leaves(gp), jax.tree.leaves(p)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=RTOL,
                                   atol=ATOL, err_msg=f"params[{tag}]")
    np.testing.assert_allclose(gl, l, rtol=RTOL, atol=ATOL,
                               err_msg=f"losses[{tag}]")


@functools.lru_cache(maxsize=None)
def _golden(scenario):
    """Golden column: the independent loop on the no-mesh engine (cached —
    every matrix cell compares against the same one reference run)."""
    eng, _ = _build()
    level = 0 if scenario == "fedavg" else 1
    members = list(eng.assignment.members[level])
    teacher = _teacher(eng) if scenario == "kd" else None
    return _run_loop(eng, level, members, ROUNDS, teacher), level, members


# ----------------------------------------------------------- sync schedules
@pytest.mark.parametrize("scenario", ["fedavg", "kd"])
def test_matrix_sync_fast(scenario):
    """Always-on subset: {loop, vmap, dispatch R∈{1,8}} unsharded plus the
    degenerate 1x1 mesh, for the balanced FedAvg master and the KD slave."""
    golden, level, members = _golden(scenario)
    for tag, run in (
            ("vmap", lambda e, t: _run_vmap(e, level, members, ROUNDS, t)),
            ("disp-r1", lambda e, t: _run_dispatch(e, level, members,
                                                   ROUNDS, 1, t)),
            ("disp-r8", lambda e, t: _run_dispatch(e, level, members,
                                                   ROUNDS, 8, t))):
        eng, _ = _build()
        teacher = _teacher(eng) if scenario == "kd" else None
        _assert_cell(golden, run(eng, teacher), f"{scenario}/{tag}")
    eng, _ = _build(mesh_shape="1x1")
    teacher = _teacher(eng) if scenario == "kd" else None
    _assert_cell(golden, _run_dispatch(eng, level, members, ROUNDS, 8,
                                       teacher), f"{scenario}/1x1-r8")


@pytest.mark.parametrize("scenario", ["fedavg", "kd"])
def test_matrix_loop_engine_runs_fused(scenario):
    """The independent-loop column can opt into the fused path: a
    ``vmap_clusters=False`` engine with ``allow_loop_dispatch=True`` builds
    the same scan-fused block programs and matches the golden loop — so
    loop-mode debugging configs no longer pay one program per member per
    round when they only want the legacy batching semantics elsewhere."""
    golden, level, members = _golden(scenario)
    eng, _ = _build(vmap_clusters=False, allow_loop_dispatch=True)
    teacher = _teacher(eng) if scenario == "kd" else None
    _assert_cell(golden, _run_dispatch(eng, level, members, ROUNDS, 8,
                                       teacher),
                 f"{scenario}/loop-fused-r8")


def test_loop_dispatch_requires_opt_in():
    """R>1 on a loop engine stays an explicit contract: the engine ctor
    rejects it unless ``allow_loop_dispatch`` opts in (the error message
    names the escape hatch)."""
    with pytest.raises(ValueError, match="allow_loop_dispatch"):
        _build(vmap_clusters=False)
    eng, _ = _build(vmap_clusters=False, allow_loop_dispatch=True)
    assert not eng.cfg.vmap_clusters and eng.cfg.rounds_per_dispatch == 8


@eightway
@pytest.mark.parametrize("mesh_shape", ["8x1", "4x2", "2x4"])
@pytest.mark.parametrize("scenario", ["fedavg", "kd"])
def test_matrix_sync_eightway(scenario, mesh_shape):
    """8-device columns: member-sharded (8x1) and 2D plane-column-sharded
    (4x2 / 2x4) dispatch at R ∈ {1, 8} against the unsharded golden loop —
    with one compile per program and donation still enforced."""
    golden, level, members = _golden(scenario)
    eng, _ = _build(mesh_shape=mesh_shape)
    teacher = _teacher(eng) if scenario == "kd" else None
    for R in (1, 8):
        _assert_cell(golden, _run_dispatch(eng, level, members, ROUNDS, R,
                                           teacher),
                     f"{scenario}/{mesh_shape}-r{R}")
    stats = eng.compile_stats()
    retraced = {k: v for k, v in stats.items() if v != 1}
    assert not retraced, f"programs retraced on {mesh_shape}: {retraced}"
    # donated-plane reuse must still raise on the 2D mesh
    plane = eng.plane_of(level, eng.family.init(jax.random.PRNGKey(7), level))
    out = eng.dispatch_rounds(level, members, plane, 0, 2, teacher=teacher)
    assert plane.is_deleted() and not out.plane.is_deleted()
    with pytest.raises(RuntimeError, match="deleted"):
        np.asarray(plane)


@eightway
@pytest.mark.parametrize("mesh_shape", ["8x1", "4x2", "2x4"])
def test_matrix_kd_sim_eightway(mesh_shape):
    """KD at simulator granularity on 8 devices: fused blocks return the
    master's per-round ``want_history`` plane stack and scan the slaves'
    per-round ``teacher_planes`` — both column-sharded on the 2D meshes
    (the ``sp["stack"]`` specs and the teacher column gather) — and the
    result matches the unsharded dispatch engine."""
    outs = {}
    for shape in (None, mesh_shape):
        eng, testb = _build(mesh_shape=shape)
        sim = HeterogeneitySim(eng, make_trace("stable", 8, ROUNDS),
                               SimConfig(rounds=ROUNDS))
        sim._run_dispatch(testb)
        outs[shape] = sim.params
    assert len(outs[None]) > 1, "no slave cluster — teacher stacks unused"
    for lvl in outs[None]:
        for x, y in zip(jax.tree.leaves(outs[None][lvl]),
                        jax.tree.leaves(outs[mesh_shape][lvl])):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=RTOL, atol=ATOL,
                                       err_msg=f"kd-sim/{mesh_shape}/L{lvl}")


# ------------------------------------------------------------ buffered async
def _run_buffered_sim(mesh_shape, R, rounds=5, seed=0, mode="sync",
                      max_staleness=0, compact_to=1):
    """Buffered schedule under a straggling cluster (the slower half misses
    the deadline every round → banks, flushes next round).  Returns
    (final params, structural telemetry, per-round mean losses).  The
    stream bridge makes the comparison numeric, not just structural.
    ``mode="async"`` runs the continuous-time async server instead; with
    ``max_staleness=0`` (synchronized arrivals) it must reproduce the
    buffered path bit-for-bit."""
    from repro.core import cost_model
    eng, testb = _build(mesh_shape=mesh_shape, seed=seed,
                        compact_to=compact_to,
                        aggregation="buffered", rounds_per_dispatch=R)
    spec = eng.specs[0]
    t = sorted(cost_model.round_time(
        p, spec.flops_per_sample, spec.model_bytes, spec.E,
        eng.assignment.n_eff.get(p.pid, p.n_data)) for p in eng.parts)
    spec.mar = 0.5 * (t[len(t) // 2 - 1] + t[len(t) // 2])
    kw = ({"mode": "async", "max_staleness": max_staleness}
          if mode == "async" else {})
    sim = HeterogeneitySim(eng, make_trace("stable", len(eng.parts), rounds),
                           SimConfig(rounds=rounds, mar_policy="buffer",
                                     **kw))
    rep = sim.run(testb)
    tel = [(r.round, [(c.level, sorted(c.active), sorted(c.banked),
                       c.flushed) for c in r.clusters]) for r in rep.rows]
    losses = np.asarray([[c.mean_loss for c in r.clusters]
                         for r in rep.rows], np.float32)
    return sim.params, tel, losses


@functools.lru_cache(maxsize=None)
def _buffered_golden():
    """Legacy-engine buffered run (cached golden for all buffered cells)."""
    return _run_buffered_sim(None, 1)


def _assert_buffered_cell(golden, got, tag):
    gp, gtel, gl = golden
    p, tel, l = got
    assert tel == gtel, f"telemetry[{tag}]"
    banked = sum(len(b) for _, cs in gtel for _, _, b, _ in cs)
    assert banked > 0, "straggler setup never banked — matrix cell vacuous"
    np.testing.assert_allclose(gl, l, rtol=RTOL, atol=ATOL,
                               err_msg=f"mean_losses[{tag}]")
    for lvl in gp:
        for x, y in zip(jax.tree.leaves(gp[lvl]), jax.tree.leaves(p[lvl])):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=RTOL, atol=ATOL,
                                       err_msg=f"params[{tag}]")


@pytest.mark.parametrize("mesh_shape,R", [(None, 8), ("1x1", 8)])
def test_matrix_buffered_fast(mesh_shape, R):
    """Buffered column, always-on subset: legacy engine (golden) vs fused
    dispatch and the degenerate 1x1 mesh — same bank/flush telemetry, same
    mean losses, same final params."""
    _assert_buffered_cell(_buffered_golden(), _run_buffered_sim(mesh_shape, R),
                          f"buffered/{mesh_shape}-r{R}")


@eightway
@pytest.mark.parametrize("mesh_shape", ["8x1", "4x2", "2x4"])
def test_matrix_buffered_eightway(mesh_shape):
    """Buffered column at 8 devices: the bank rides the sharded scan carry
    (2D meshes: column-sharded) and still matches the legacy engine."""
    _assert_buffered_cell(_buffered_golden(), _run_buffered_sim(mesh_shape, 8),
                          f"buffered/{mesh_shape}-r8")


# ------------------------------------------------- async ≡ sync-arrivals
# The async-server anchor: ``mode="async"`` with ``max_staleness=0``
# (synchronized arrivals — every cluster merges at the shared barrier)
# must reproduce the buffered path BIT-exactly (np.array_equal, not the
# matrix rtol): same final params, same bank/flush telemetry, same
# per-round mean losses.  Version-based staleness discounts degenerate to
# the buffered round-age discounts round for round, so any drift here is
# an async-scheduler bug, not numerics.
def _assert_async_cell(golden, got, tag):
    gp, gtel, gl = golden
    p, tel, l = got
    assert tel == gtel, f"telemetry[{tag}]"
    assert np.array_equal(gl, l, equal_nan=True), f"mean_losses[{tag}]"
    for lvl in gp:
        for x, y in zip(jax.tree.leaves(gp[lvl]), jax.tree.leaves(p[lvl])):
            assert np.array_equal(np.asarray(x), np.asarray(y)), \
                f"params[{tag}] L{lvl} not bit-equal"


@pytest.mark.parametrize("R", [1, 8])
def test_matrix_async_sync_arrivals_fast(R):
    """Async column, always-on subset: legacy per-round jit (R=1, against
    the cached buffered golden) and fused dispatch (R=8) — synchronized
    arrivals reproduce the buffered engine bit-for-bit."""
    golden = (_buffered_golden() if R == 1
              else _run_buffered_sim(None, R))
    got = _run_buffered_sim(None, R, mode="async", max_staleness=0)
    _assert_async_cell(golden, got, f"async/sync-arrivals-r{R}")


def test_matrix_async_kd_barrier():
    """Async column with a real slave cluster (compact_to=2): the KD
    teacher rides ``MasterBlock`` — at synchronized arrivals the slave
    block aligns with the master's dispatch and gets the exact per-round
    teacher stack, so the whole two-cluster run stays bit-exact."""
    golden = _run_buffered_sim(None, 8, rounds=6, compact_to=2)
    got = _run_buffered_sim(None, 8, rounds=6, compact_to=2,
                            mode="async", max_staleness=0)
    _assert_async_cell(golden, got, "async/kd-barrier-r8")


@eightway
def test_matrix_async_eightway():
    """Async column at 8 devices: the 4x2 (data × model) mesh cell — the
    async scheduler drives the same column-sharded dispatch programs and
    synchronized arrivals still match the buffered run bit-exactly."""
    _assert_async_cell(_run_buffered_sim("4x2", 8),
                       _run_buffered_sim("4x2", 8, mode="async",
                                         max_staleness=0),
                       "async/4x2-r8")


# ------------------------------------------------------------ resume column
# kill/resume ≡ uninterrupted, at BIT-exactness (np.array_equal, not the
# rtol used across execution paths): every cell crashes at round boundary 3
# via an in-process SimulatedCrash, then a FRESH engine (new-process
# stand-in) resumes from the checkpoint and must reproduce the control
# run's final params, per-round rows, and summary totals exactly.
SIM_ROUNDS = 5


def _resume_cell_builder(mesh_shape=None, R=8, buffered=False, mode="sync",
                         max_staleness=None):
    """() -> (engine, test batch, SimConfig, trace) for one resume cell."""
    kw = ({"mode": "async", "max_staleness": max_staleness}
          if mode == "async" else {})

    def build():
        if buffered:
            from repro.core import cost_model
            eng, testb = _build(mesh_shape=mesh_shape, compact_to=1,
                                aggregation="buffered", rounds_per_dispatch=R)
            spec = eng.specs[0]
            t = sorted(cost_model.round_time(
                p, spec.flops_per_sample, spec.model_bytes, spec.E,
                eng.assignment.n_eff.get(p.pid, p.n_data))
                for p in eng.parts)
            spec.mar = 0.5 * (t[len(t) // 2 - 1] + t[len(t) // 2])
            simcfg = SimConfig(rounds=SIM_ROUNDS, mar_policy="buffer", **kw)
            trace = make_trace("stable", 8, SIM_ROUNDS, seed=5)
        else:
            eng, testb = _build(mesh_shape=mesh_shape, rounds_per_dispatch=R)
            simcfg = SimConfig(rounds=SIM_ROUNDS, mar_policy="mask", **kw)
            trace = make_trace("mixed", 8, SIM_ROUNDS, seed=5)
        return eng, testb, simcfg, trace
    return build


def _resume_run(ckpt_dir, builder, kill=None, resume=False):
    eng, testb, simcfg, trace = builder()
    ck = (make_checkpointer(str(ckpt_dir), every=1, resume=resume)
          if ckpt_dir is not None else None)
    faults = (FaultInjector(FaultPlan(kill_at_round=kill,
                                      raise_instead=True))
              if kill is not None else None)
    sim = HeterogeneitySim(eng, trace, simcfg, checkpoint=ck, faults=faults)
    try:
        rep = sim.run(testb)
    except SimulatedCrash:
        return None
    params = {lvl: [np.asarray(x) for x in jax.tree.leaves(p)]
              for lvl, p in sim.params.items()}
    rows = [(r.round, r.duration,
             [(c.level, c.time, c.mean_loss, sorted(c.active),
               sorted(c.dropped), sorted(c.offline),
               sorted(c.masked.items()), sorted(c.violations),
               sorted(c.banked), sorted(c.unselected), c.flushed, c.bytes,
               c.acc) for c in r.clusters]) for r in rep.rows]
    summary = {k: v for k, v in rep.summary().items()
               if k not in ("compiles", "transfers")}   # process-local
    return params, rows, summary


def _assert_resume_cell(ctrl, res, tag):
    assert res is not None, f"[{tag}] resumed run crashed"
    for lvl in ctrl[0]:
        for a, b in zip(ctrl[0][lvl], res[0][lvl]):
            assert np.array_equal(a, b), f"params[{tag}] L{lvl} not bit-equal"
    assert ctrl[1] == res[1], f"rows[{tag}]"
    assert ctrl[2] == res[2], f"summary[{tag}]"


RESUME_CELLS = {
    "legacy": lambda: _resume_cell_builder(R=1),
    "disp-r8": lambda: _resume_cell_builder(R=8),
    "buffered": lambda: _resume_cell_builder(buffered=True),
    # async cell: two clusters on independent clocks, unbounded staleness,
    # mixed arrival/departure trace; ``kill=3`` counts MERGE EVENTS (the
    # async checkpoint cadence), and the resumed run — per-cluster clocks,
    # server versions, in-flight ledger and pending blocks all off the
    # checkpoint — must match its own uninterrupted control bit-exactly
    "async": lambda: _resume_cell_builder(R=1, mode="async",
                                          max_staleness=None),
}


@pytest.mark.parametrize("cell", sorted(RESUME_CELLS))
def test_matrix_resume_fast(cell, tmp_path):
    """Resume column, always-on subset: legacy per-round jit, fused
    dispatch R=8, and the buffered/bank schedule (banked rows + ages ride
    the checkpoint) — each kill/resume bit-identical to its control."""
    builder = RESUME_CELLS[cell]()
    ctrl = _resume_run(None, builder)
    assert _resume_run(tmp_path, builder, kill=3) is None
    _assert_resume_cell(ctrl, _resume_run(tmp_path, builder, resume=True),
                        f"resume/{cell}")


@eightway
def test_matrix_resume_eightway(tmp_path):
    """Resume column at 8 devices: the 4x2 (data × model) mesh cell — the
    checkpointed planes are re-committed to the 2D sharding on restore and
    the resumed run still matches its own control bit-exactly."""
    builder = _resume_cell_builder(mesh_shape="4x2")
    ctrl = _resume_run(None, builder)
    assert _resume_run(tmp_path, builder, kill=3) is None
    _assert_resume_cell(ctrl, _resume_run(tmp_path, builder, resume=True),
                        "resume/4x2-r8")


# ------------------------------------------------------- sampler × 2D mesh
@eightway
def test_sampler_draws_independent_of_model_axis():
    """data/device_sampler regression on the 2D mesh: in-program draws are
    keyed on (absolute round, GLOBAL member slot) only, so a device's draw
    depends on its ``data`` coordinate alone — every ``model`` column draws
    bit-identically, and all equal the unsharded draw."""
    mesh = make_sim_mesh("4x2")
    from jax.sharding import PartitionSpec as P
    C, steps, batch = 8, 3, 4
    n = jnp.arange(5, 5 + C, dtype=jnp.int32) * 7
    key = device_sampler.round_key(3, 11)

    def draw(n_loc):
        off = jax.lax.axis_index("data") * n_loc.shape[0]
        idx = device_sampler.uniform_indices(key, steps, batch, n_loc,
                                             offset=off)
        # out_spec P('data', ...) demands model-axis replication: shard_map's
        # rep check would refuse to stitch draws that varied by model column
        return jax.lax.pmean(idx.astype(jnp.float32), "model")

    fn = aggregation._shard_map(draw, mesh=mesh, in_specs=(P("data"),),
                                out_specs=P("data", None, None))
    sharded = np.asarray(fn(n))
    full = np.asarray(device_sampler.uniform_indices(key, steps, batch, n))
    np.testing.assert_array_equal(sharded, full.astype(np.float32))


# --------------------------------------------------------------- TP column
# On 2D meshes the engine now defaults to the GSPMD tensor-parallel member
# forward (``FLConfig.tp_forward``), so every 4x2/2x4 cell above already
# exercises TP for the MLP family.  The cells below cover what those don't:
# the legacy shard_map gather path (``tp_forward=False``), the CNN/LM
# families' TP specs, and the per-device-memory acceptance criterion.
@eightway
@pytest.mark.parametrize("mesh_shape", ["4x2", "2x4"])
@pytest.mark.parametrize("scenario", ["fedavg", "kd"])
def test_matrix_legacy_gather_eightway(scenario, mesh_shape):
    """``tp_forward=False`` keeps the pre-TP shard_map path (transient
    column all-gather + replicated forward) working against the golden."""
    golden, level, members = _golden(scenario)
    eng, _ = _build(mesh_shape=mesh_shape, tp_forward=False)
    assert not eng._tp
    teacher = _teacher(eng) if scenario == "kd" else None
    _assert_cell(golden, _run_dispatch(eng, level, members, ROUNDS, 8,
                                       teacher),
                 f"legacy-gather/{scenario}/{mesh_shape}")


def _build_tp_family(famname, mesh_shape=None, **cfg_kw):
    """Engine over the CNN or (token-data) LM family for the TP cells."""
    if famname == "cnn":
        from repro.core.families import cnn_family
        fam = cnn_family(classes=10, in_channels=1, base_width=0.125)
        return _build(mesh_shape=mesh_shape, family=fam,
                      class_balanced=False, **cfg_kw)[0]
    from repro.configs.base import ModelConfig
    from repro.core.families import lm_family
    from repro.data.synthetic import make_lm_corpus, lm_batches
    base = ModelConfig(name="matrix-lm", family="dense", n_layers=2,
                       d_model=32, n_heads=4, n_kv_heads=4, head_dim=8,
                       d_ff=64, vocab_size=64, rope_theta=1e4)
    corpus = make_lm_corpus(64, 8_000, seed=0)
    chunks = np.array_split(corpus, 8)
    cd = [{"tokens": lm_batches(ch, 32, 17, 1, seed=i)[0]}
          for i, ch in enumerate(chunks)]
    parts = participants_from_matrix(sample_profiles(8, seed=0),
                                     n_data=[64] * 8)

    class TokenFedRAC(srv.FedRAC):
        def _batch_from_gathered(self, g):
            return {"tokens": g["tokens"], "y": g["tokens"][:, :, -1]}

    cfg = srv.FLConfig(steps_per_round=3, lr=0.05, seed=0, local_batch=4,
                       compact_to=2, rounds_per_dispatch=8,
                       class_balanced=False, **cfg_kw)
    mesh = make_sim_mesh(mesh_shape) if mesh_shape else None
    return TokenFedRAC(parts, cd, lm_family(base, alpha=0.5), cfg,
                       classes=64, mesh=mesh).setup()


def _bank_for(eng, level, cap):
    """Two seeded bank rows in THIS engine's plane layout (TP and legacy
    planes are not byte-compatible — banks only convert through pytrees)."""
    rows = jnp.stack([eng.plane_of(level, eng.family.init(
        jax.random.PRNGKey(100 + i), level)) for i in range(2)])
    D = rows.shape[1]
    return (eng.place_member_plane(
                jnp.zeros((cap, D), jnp.float32).at[:2].set(rows)),
            eng.place_member_sharded(
                jnp.zeros((cap,), jnp.float32).at[:2].set(
                    jnp.asarray([0.5, 0.25]))),
            eng.place_member_sharded(jnp.zeros((cap,), jnp.float32)))


@eightway
@pytest.mark.parametrize("famname", ["cnn", "lm"])
@pytest.mark.parametrize("scenario", ["fedavg", "kd", "buffered"])
def test_matrix_tp_families_eightway(famname, scenario):
    """TP ≡ replicated for the CNN and LM families on the 2x4 mesh:
    identical dispatch blocks (same sampler stream, same bank rows) on the
    TP engine and the unsharded engine must agree to matrix tolerance —
    with one compile per program (the LM KD cell also runs the teacher
    forward TP-sharded)."""
    level = 0 if scenario == "fedavg" else 1
    outs = {}
    for shape in (None, "2x4"):
        eng = _build_tp_family(famname, mesh_shape=shape)
        if shape is not None:
            assert eng._tp, "TP inactive on the 2D mesh"
        members = list(eng.assignment.members[level])
        cap = eng._capacity(len(members))
        teacher = (eng.family.init(jax.random.PRNGKey(42), 0)
                   if scenario != "fedavg" else None)
        bank = _bank_for(eng, level, cap) if scenario == "buffered" else None
        plane = eng.plane_of(level, eng.family.init(
            jax.random.PRNGKey(eng.cfg.seed + level), level))
        out = eng.dispatch_rounds(level, members, plane, 0, ROUNDS,
                                  teacher=teacher, bank=bank)
        outs[shape] = (eng.params_of(level, out.plane),
                       np.asarray(out.losses))
        if shape is not None:
            stats = eng.compile_stats()
            bad = {k: v for k, v in stats.items() if v != 1}
            assert not bad, bad
    _assert_cell(outs[None], outs["2x4"], f"tp/{famname}/{scenario}")


@eightway
def test_tp_member_forward_sharding_eightway():
    """Acceptance criterion for the TP member forward: per-device plane
    bytes scale as D/model_size, and the lowered dispatch program contains
    NO plane-magnitude all-gather — the transient column gather the TP
    path exists to kill (the legacy path all-gathers the full (D,) plane
    into every device each round)."""
    from repro.launch.hlo_analysis import collective_bytes
    eng, _ = _build(mesh_shape="2x4")
    level, members = 0, list(eng.assignment.members[0])
    cap = eng._capacity(len(members))
    spec = eng.plane_spec(level)
    plane = eng.plane_of(level, eng.family.init(jax.random.PRNGKey(3), level))
    out = eng.dispatch_rounds(level, members, plane, 0, 8)
    # each device holds exactly its 1/msize column slice of the plane
    shard_sizes = {s.data.size for s in out.plane.addressable_shards}
    assert shard_sizes == {spec.d_pad // spec.msize}, shard_sizes
    # lower the cached program and audit its collectives
    balanced = eng.cfg.class_balanced and level == 0
    pack = eng._shard_pack(level, members, cap, balanced)
    prog = eng._dispatch_programs(level, False, cap, 8, balanced, False,
                                  False, pack=pack)
    masks = eng.place_member_sharded(
        jnp.ones((cap, eng.cfg.steps_per_round), jnp.float32))
    w = eng.place_member_sharded(jnp.ones((cap,), jnp.float32))
    low = prog.lower(out.plane, pack["shards"], pack["n"], pack["tables"],
                     pack["counts"], jnp.asarray(0, jnp.int32), masks, w,
                     None)
    cb = collective_bytes(low.compile().as_text())
    plane_bytes = spec.d_pad * 4
    assert cb["bytes"].get("all-gather", 0) < plane_bytes // 2, cb["bytes"]


# ------------------------------------------------------ subprocess (tier-1)
@pytest.mark.slow
def test_matrix_under_forced_host_devices():
    """Tier-1 coverage of the 8-device matrix columns: rerun the
    ``eightway`` cells in a subprocess with 8 forced host devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.abspath(__file__), "-k", "eightway or model_axis"],
        capture_output=True, text=True, timeout=560, env=env, cwd=REPO)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr[-3000:]
    assert "26 passed" in r.stdout, r.stdout
