"""PartitionSpec rule unit tests against an AbstractMesh(16,16) — no devices
needed; validates divisibility fallbacks and mode switches."""
import jax
import numpy as np
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config
from repro.launch import sharding, specs

def _abstract_mesh(*axes):
    try:                                  # jax <= 0.5: shape_tuple pairs
        return AbstractMesh(tuple(axes))
    except TypeError:                     # newer jax: (axis_sizes, axis_names)
        return AbstractMesh(tuple(s for _, s in axes),
                            tuple(n for n, _ in axes))


MESH = _abstract_mesh(("data", 16), ("model", 16))
MESH3 = _abstract_mesh(("pod", 2), ("data", 16), ("model", 16))


def _specs_for(arch, **over):
    cfg = get_config(arch)
    if over:
        cfg = cfg.replace(**over)
    p_shape = specs.params_shape(cfg)
    return cfg, p_shape, sharding.param_specs(cfg, p_shape, MESH)


def test_tp_rules_olmo():
    cfg, p_shape, sp = _specs_for("olmo-1b")
    assert sp["embed"] == P("model", None)                    # vocab-sharded
    blk = sp["blocks"]["p0"]
    assert blk["mixer"]["wq"] == P(None, None, "model")       # (sb, d, q_dim)
    assert blk["mixer"]["wo"] == P(None, "model", None)
    assert blk["ffn"]["w_down"] == P(None, "model", None)


def test_small_dims_fall_back_to_replication():
    cfg, p_shape, sp = _specs_for("xlstm-350m")
    blk = sp["blocks"]["p0"]["mixer"]
    # w_if: (sb, di, 2H) with 2H=8 < 16 → replicated
    assert blk["w_if"] == P(None, None, None)
    assert blk["wq"] == P(None, None, "model")


def test_moe_tp_vs_ep():
    _, _, sp_tp = _specs_for("qwen3-moe-235b-a22b", moe_shard="tp")
    _, _, sp_ep = _specs_for("qwen3-moe-235b-a22b", moe_shard="ep")
    tp = sp_tp["blocks"]["p0"]["ffn"]
    ep = sp_ep["blocks"]["p0"]["ffn"]
    # (sb, E, d, f): TP shards f, EP shards E
    assert tp["w_gate"] == P(None, None, None, "model")
    assert ep["w_gate"] == P(None, "model", None, None)
    assert ep["w_down"] == P(None, "model", None, None)
    # granite: 32 experts also divide 16
    _, _, g = _specs_for("granite-moe-1b-a400m", moe_shard="ep")
    assert g["blocks"]["p0"]["ffn"]["w_up"] == P(None, "model", None, None)


def test_fsdp_mode_shards_largest_dim_over_both_axes():
    cfg, p_shape, sp = _specs_for("olmo-1b", shard_mode="fsdp")
    # embed (V_pad=50304? -> 50304 % 256 == 0) largest dim over (data, model)
    v = cfg.padded_vocab
    assert v % 256 == 0
    assert sp["embed"] == P(("data", "model"), None)
    blk = sp["blocks"]["p0"]
    # wq: (sb=16, 2048, 2048): largest divisible dim gets both axes
    assert ("data", "model") in tuple(blk["mixer"]["wq"])


def test_batch_specs_modes():
    cfg = get_config("olmo-1b")
    batch = specs.train_inputs(cfg, specs.INPUT_SHAPES["train_4k"])
    sp = sharding.batch_specs(cfg, batch, MESH)
    assert sp["tokens"] == P(("data",), None)
    sp3 = sharding.batch_specs(cfg, batch, MESH3)
    assert sp3["tokens"] == P(("pod", "data"), None)
    # fsdp: batch over all axes (256 % 256 == 0)
    spf = sharding.batch_specs(cfg.replace(shard_mode="fsdp"), batch, MESH)
    assert spf["tokens"] == P(("data", "model"), None)


def test_batch_indivisible_replicates():
    cfg = get_config("olmo-1b")
    import jax.numpy as jnp
    b = {"x": jax.ShapeDtypeStruct((3, 8), jnp.int32)}
    sp = sharding.batch_specs(cfg, b, MESH)
    assert sp["x"] == P(None, None)


def test_cache_shard_modes():
    cfg = get_config("gemma2-9b")
    _, _, cache = specs.decode_inputs(cfg, specs.INPUT_SHAPES["decode_32k"])
    # production default is "seq" (§Perf H2)
    seq = sharding.cache_specs(cfg, cache, MESH, shard_seq=False)
    assert seq["p0"]["k"] == P(None, ("data",), "model", None, None)
    hd = sharding.cache_specs(cfg.replace(cache_shard="hd"), cache, MESH,
                              shard_seq=False)
    k = hd["p0"]["k"]                       # (sb, B, S, KV, hd)
    assert k == P(None, ("data",), None, None, "model")
    bat = sharding.cache_specs(cfg.replace(cache_shard="batch"), cache, MESH,
                               shard_seq=False)
    assert bat["p0"]["k"] == P(None, ("data",), None, None, None)


def test_long_context_shard_seq():
    cfg = get_config("gemma2-9b")
    _, _, cache = specs.decode_inputs(cfg, specs.INPUT_SHAPES["long_500k"])
    sp = sharding.cache_specs(cfg.replace(cache_shard="hd"), cache, MESH,
                              shard_seq=True)
    k = sp["p0"]["k"]
    assert k[2] in ("data", ("data",))      # sequence axis sharded
    sp2 = sharding.cache_specs(cfg, cache, MESH, shard_seq=True)
    assert sp2["p0"]["k"][2] == ("data", "model")   # default "seq" 


def test_shard_seq_fallback_divisibility():
    """shard_seq fallback chain: (data, model) when S divides the full
    product, data-only when S divides only dp_size, REPLICATED otherwise —
    the dp fallback used to be unconditional, emitting invalid specs for
    sequence lengths not divisible by the data axis."""
    import jax.numpy as jnp
    cfg = get_config("gemma2-9b")          # cache_shard="seq" default
    seq_total = 16 * 16                    # data * model on MESH

    def k_spec(S):
        cache = {"p0": {"k": jax.ShapeDtypeStruct((2, 1, S, 2, 8),
                                                  jnp.bfloat16)}}
        sp = sharding.cache_specs(cfg, cache, MESH, shard_seq=True)
        return sp["p0"]["k"]

    assert k_spec(seq_total)[2] == ("data", "model")   # full split
    assert k_spec(16 * 17)[2] == ("data",)             # dp-only fallback
    assert k_spec(274)[2] is None                      # 274 % 16 != 0
    # hd-mode: seq_total is dp_size only; same chain without `model`
    def k_spec_hd(S):
        cache = {"p0": {"k": jax.ShapeDtypeStruct((2, 1, S, 2, 32),
                                                  jnp.bfloat16)}}
        sp = sharding.cache_specs(cfg.replace(cache_shard="hd"), cache,
                                  MESH, shard_seq=True)
        return sp["p0"]["k"]

    assert k_spec_hd(32)[2] == ("data",)
    assert k_spec_hd(34)[2] is None


def test_prefill_out_spec_guards_compose():
    """The prefill logit out-spec's batch and vocab guards act on their own
    axes: a non-divisible global_batch drops ONLY the batch split and must
    not resurrect a vocab split the vocab guard rejected."""
    from repro.configs.base import InputShape
    from repro.launch.dryrun import prefill_out_spec
    cfg = get_config("olmo-1b")
    dp = ("data",)
    assert cfg.padded_vocab % 16 == 0
    ok = InputShape("p", 128, 32, "prefill")          # 32 % 16 == 0
    odd = InputShape("p", 128, 3, "prefill")          # 3 % 16 != 0
    assert prefill_out_spec(cfg, ok, MESH, dp) == P(dp, "model")
    assert prefill_out_spec(cfg, odd, MESH, dp) == P(None, "model")
    # a model axis the (256-padded) vocab does NOT divide: vocab never
    # sharded, whatever the batch does (this is the composition the
    # unconditional override used to break)
    mesh5 = _abstract_mesh(("data", 16), ("model", 5))
    assert cfg.padded_vocab % 5 != 0
    assert prefill_out_spec(cfg, ok, mesh5, dp) == P(dp, None)
    assert prefill_out_spec(cfg, odd, mesh5, dp) == P(None, None)


def test_applicability_rules():
    ok, _ = specs.applicable(get_config("xlstm-350m"), "long_500k")
    assert ok
    ok, _ = specs.applicable(get_config("jamba-v0.1-52b"), "long_500k")
    assert ok
    ok, _ = specs.applicable(get_config("gemma2-9b"), "long_500k")
    assert ok                               # sliding-window dense
    ok, why = specs.applicable(get_config("qwen3-8b"), "long_500k")
    assert not ok and "full-attention" in why
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        for arch in ("qwen3-8b", "seamless-m4t-medium"):
            ok, _ = specs.applicable(get_config(arch), shape)
            assert ok
