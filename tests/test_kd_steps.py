"""KD train-step correctness at smoke scale: the cached-teacher step (the
paper's logit-broadcast schedule) must produce the same loss/update as the
recompute-teacher step given identical teacher logits."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import registry
from repro.optim import optimizers


def _setup(key):
    # import inside: dryrun sets XLA_FLAGS via setdefault (harmless post-init)
    from repro.launch.dryrun import make_kd_train_step
    from repro.core.scaling import compress_config
    cfg_t = get_config("qwen3-8b", smoke=True)
    cfg_s = compress_config(cfg_t, 0.5, 1)
    step, step_cached = make_kd_train_step(cfg_t, cfg_s, lr=0.01)
    t_params = registry.init_params(cfg_t, key)
    s_params = registry.init_params(cfg_s, jax.random.fold_in(key, 1))
    opt_state = optimizers.adamw().init(s_params)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg_t.vocab_size)}
    return cfg_t, step, step_cached, t_params, s_params, opt_state, batch


def test_kd_cached_matches_recompute(key):
    cfg_t, step, step_cached, tp, sp, opt, batch = _setup(key)
    t_logits, _ = registry.forward(cfg_t, tp, batch)
    sp1, _, l1 = jax.jit(step)(tp, sp, opt, batch)
    sp2, _, l2 = jax.jit(step_cached)(t_logits, sp, opt, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    # AdamW's rsqrt amplifies bitwise scheduling differences near v≈0;
    # loss matches to 1e-5, parameters to 1e-3.
    for a, b in zip(jax.tree.leaves(sp1), jax.tree.leaves(sp2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


def test_kd_step_reduces_loss(key):
    cfg_t, step, _, tp, sp, opt, batch = _setup(key)
    jstep = jax.jit(step)
    losses = []
    for _ in range(8):
        sp, opt, l = jstep(tp, sp, opt, batch)
        losses.append(float(l))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


import pytest


# S=16 → 15 label positions: 5 divides; 4 leaves a 3-token tail; 6 leaves 3;
# 20 > S-1 makes the WHOLE sequence the tail (zero scanned chunks)
@pytest.mark.parametrize("chunk", [5, 4, 6, 20])
def test_kd_chunked_matches_full(key, chunk):
    """Chunked KD loss ≡ full-logits KD loss, including at chunk sizes that
    do NOT divide S-1 — the (S-1) mod chunk tail used to be dropped."""
    from repro.launch.dryrun import make_kd_train_step
    from repro.core.scaling import compress_config
    cfg_t = get_config("olmo-1b", smoke=True)
    cfg_s = compress_config(cfg_t, 0.5, 1)
    step_f, _ = make_kd_train_step(cfg_t, cfg_s, lr=0.01, chunk=0)
    step_c, _ = make_kd_train_step(cfg_t, cfg_s, lr=0.01, chunk=chunk)
    key2 = jax.random.fold_in(key, 9)
    tp = registry.init_params(cfg_t, key2)
    sp = registry.init_params(cfg_s, jax.random.fold_in(key2, 1))
    opt = optimizers.adamw().init(sp)
    batch = {"tokens": jax.random.randint(key2, (2, 16), 0, cfg_t.vocab_size)}
    sp_f, _, lf = jax.jit(step_f)(tp, sp, opt, batch)
    sp_c, _, lc = jax.jit(step_c)(tp, sp, opt, batch)
    np.testing.assert_allclose(float(lf), float(lc), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(sp_f), jax.tree.leaves(sp_c)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)
