"""Per-arch smoke tests (deliverable f): reduced same-family variant (2
layers, d_model ≤ 512, ≤ 4 experts) runs a real forward + ONE train step on
CPU; asserts output shapes and no NaNs.  Decode parity (KV-cache/SSM-state
correctness) is asserted for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import encdec, registry, transformer
from repro.optim import optimizers

ARCHS = list_archs()


def _batch(cfg, key, B=2, S=16):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend:
        batch["embeds"] = jax.random.normal(key, (B, 8, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_constraints(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch, key):
    cfg = get_config(arch, smoke=True)
    params = registry.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, aux = registry.forward(cfg, params, batch)
    B = batch["tokens"].shape[0]
    exp_S = batch["tokens"].shape[1] + (8 if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), arch

    opt = optimizers.sgd()
    state = opt.init(params)

    @jax.jit
    def step(p, s, b):
        (loss, ce), g = jax.value_and_grad(
            lambda pp: registry.loss_fn(cfg, pp, b), has_aux=True)(p)
        p2, s2 = opt.update(g, s, p, 0.05)
        return p2, s2, loss

    p2, _, loss0 = step(params, state, batch)
    _, _, loss1 = step(p2, state, batch)
    assert np.isfinite(float(loss0)) and np.isfinite(float(loss1))
    assert float(loss1) < float(loss0)       # one step on same batch improves


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch, key):
    cfg = get_config(arch, smoke=True)
    params = registry.init_params(cfg, key)
    B, S = 2, 8
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.family == "encdec":
        emb = jax.random.normal(key, (B, 8, cfg.d_model))
        full, _ = encdec.forward(cfg, params, toks, embeds=emb)
        cache = encdec.init_cache(cfg, B, S, 8)
        cache = encdec.build_cross_cache(cfg, params, cache, emb)
        step = lambda c, t, i: encdec.decode_step(cfg, params, c, t, i)
    elif cfg.family == "vlm":
        # text-only decode parity (frontend positions exercised in forward)
        full, _ = transformer.forward(cfg, params, toks)
        cache = transformer.init_cache(cfg, B, S)
        step = lambda c, t, i: transformer.decode_step(cfg, params, c, t, i)
    else:
        full, _ = transformer.forward(cfg, params, toks)
        cache = transformer.init_cache(cfg, B, S)
        step = lambda c, t, i: transformer.decode_step(cfg, params, c, t, i)
    outs = []
    for t in range(S):
        lg, cache = step(cache, toks[:, t:t + 1], t)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-3, rtol=1e-3)


def test_moe_capacity_matches_dense_at_high_capacity(key):
    """GShard capacity dispatch → dense dispatch as capacity → ∞ (no drops)."""
    from repro.models.moe import apply_moe, init_moe
    cfg = get_config("granite-moe-1b-a400m", smoke=True).replace(
        moe_impl="capacity", moe_capacity=8.0, moe_group=64)
    p = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 32, cfg.d_model))
    y_cap, aux_c = apply_moe(p, cfg, x)
    y_dense, aux_d = apply_moe(p, cfg.replace(moe_impl="dense"), x)
    np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_dense),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(float(aux_c), float(aux_d), rtol=1e-4)


def test_mrope_reduces_to_rope_for_text(key):
    """M-RoPE with identical position streams ≡ standard RoPE."""
    from repro.models.layers import apply_mrope, apply_rope
    x = jax.random.normal(key, (2, 8, 4, 64))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 8))
    a = apply_rope(x, pos, 1e4)
    b = apply_mrope(x, pos3, 1e4, (8, 12, 12))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_gemma2_softcap_bounds_logits(key):
    cfg = get_config("gemma2-9b", smoke=True)
    params = registry.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, _ = registry.forward(cfg, params, batch)
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_softcap + 1e-3


def test_olmo_norm_has_no_params(key):
    cfg = get_config("olmo-1b", smoke=True)
    params = registry.init_params(cfg, key)
    assert params["final_norm"] == {}


def test_mlstm_chunked_matches_sequential(key):
    """Chunkwise-parallel mLSTM (TPU-native form) ≡ sequential cell."""
    from repro.models import transformer
    cfg = get_config("xlstm-350m", smoke=True)
    params = transformer.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 128), 0, cfg.vocab_size)
    l_seq, _ = transformer.forward(cfg, params, toks)
    l_chk, _ = transformer.forward(cfg.replace(mlstm_impl="chunk"), params, toks)
    np.testing.assert_allclose(np.asarray(l_chk), np.asarray(l_seq),
                               atol=2e-4, rtol=1e-3)


def test_mlstm_chunked_odd_chunk_boundary(key):
    """Chunk math must be exact when S spans multiple chunks (carry path)."""
    from repro.models.xlstm_blocks import (_mlstm_chunked, _mlstm_seq,
                                           init_mlstm, _mlstm_qkvif)
    cfg = get_config("xlstm-350m", smoke=True)
    p = init_mlstm(key, cfg, jnp.float32)
    B, S = 2, 192                     # 3 chunks of 64
    xm = jax.random.normal(key, (B, S, cfg.mlstm_expand * cfg.d_model))
    q, k, v, it, ft, _ = _mlstm_qkvif(p, cfg, xm)
    H = cfg.n_heads
    hd = (cfg.mlstm_expand * cfg.d_model) // H
    a = _mlstm_seq(cfg, q, k, v, it, ft, B, S, H, hd)
    b = _mlstm_chunked(cfg, q, k, v, it, ft, B, S, H, hd)
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=1e-4)


def test_blocked_attention_matches_dense(key):
    """Flash-style jnp blocked attention (§Perf prefill fix) ≡ dense SDPA,
    including full-MHA (minicpm), sliding-window+softcap (gemma2), qk_norm."""
    for arch in ("minicpm-2b", "gemma2-9b", "qwen3-8b"):
        cfg = get_config(arch, smoke=True)
        params = registry.init_params(cfg, key)
        toks = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
        a, _ = transformer.forward(cfg, params, toks)
        b, _ = transformer.forward(cfg.replace(attn_impl="blocked"),
                                   params, toks)
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=3e-4,
                                   rtol=1e-3)
