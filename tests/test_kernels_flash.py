"""Flash attention kernel: shape/dtype sweep + masking semantics vs ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash import ops as fops
from repro.kernels.flash import ref as fref


def _run(key, B, S, H, KV, hd, dtype, **kw):
    q = jax.random.normal(key, (B, S, H, hd)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd)).astype(dtype)
    out = fops.flash_attention(q, k, v, **kw)
    G = H // KV
    kk = jnp.repeat(k, G, 2).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vv = jnp.repeat(v, G, 2).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    qq = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    ref = fref.attention_bh(qq, kk, vv, **{k_: v_ for k_, v_ in kw.items()
                                           if k_ in ("causal", "window", "softcap")})
    ref = ref.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    return np.asarray(out, np.float32), np.asarray(ref, np.float32)


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 64, 2, 2, 64), (2, 128, 4, 2, 64), (1, 256, 4, 1, 128),
    (1, 128, 2, 2, 256),
])
def test_causal_sweep(key, B, S, H, KV, hd):
    out, ref = _run(key, B, S, H, KV, hd, jnp.float32, causal=True,
                    block_q=64, block_k=64)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)


def test_sliding_window(key):
    out, ref = _run(key, 1, 256, 2, 2, 64, jnp.float32, causal=True,
                    window=32, block_q=64, block_k=64)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)


def test_softcap(key):
    out, ref = _run(key, 1, 128, 2, 2, 64, jnp.float32, causal=True,
                    softcap=30.0, block_q=64, block_k=64)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)


def test_non_causal(key):
    out, ref = _run(key, 2, 128, 2, 2, 64, jnp.float32, causal=False,
                    block_q=64, block_k=64)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)


def test_bf16(key):
    out, ref = _run(key, 1, 128, 2, 2, 64, jnp.bfloat16, causal=True,
                    block_q=64, block_k=64)
    np.testing.assert_allclose(out, ref, atol=3e-2, rtol=3e-2)


def test_block_size_invariance(key):
    q = jax.random.normal(key, (1, 256, 2, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 256, 2, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 256, 2, 64))
    a = fops.flash_attention(q, k, v, block_q=64, block_k=64)
    b = fops.flash_attention(q, k, v, block_q=128, block_k=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_window_equals_full_when_larger_than_seq(key):
    out, ref = _run(key, 1, 128, 2, 2, 64, jnp.float32, causal=True,
                    window=4096, block_q=64, block_k=64)
    full, _ = _run(key, 1, 128, 2, 2, 64, jnp.float32, causal=True,
                   block_q=64, block_k=64)
    np.testing.assert_allclose(out, full, atol=2e-5)


@pytest.mark.parametrize("H,KV", [(4, 2), (4, 1), (8, 2)])
def test_gqa_inkernel_map_bitwise_vs_repeat(key, H, KV):
    """The grid→KV-row index map over compact (B·KV,…) K/V must be
    BIT-identical to feeding the kernel G×-repeated K/V with an identity
    map: same blocks, same accumulation order — only the memory footprint
    changed."""
    from repro.kernels.flash.kernel import flash_attention_bh
    B, S, hd = 2, 128, 64
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    G = H // KV
    qq = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kc = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vc = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    kr = jnp.repeat(k, G, 2).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vr = jnp.repeat(v, G, 2).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    compact = flash_attention_bh(qq, kc, vc, causal=True, block_q=64,
                                 block_k=64, heads=H)
    repeat = flash_attention_bh(qq, kr, vr, causal=True, block_q=64,
                                block_k=64)
    assert (np.asarray(compact) == np.asarray(repeat)).all()


def test_flash_attention_grad_matches_ref(key):
    """custom_vjp backward (jnp-reference recompute) vs autodiff through
    the pure-jnp oracle — what makes attn_impl='pallas' trainable."""
    B, S, H, KV, hd = 1, 64, 4, 2, 32
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))

    def f_kernel(q, k, v):
        return jnp.sum(fops.flash_attention(q, k, v, causal=True,
                                            block_q=64, block_k=64) ** 2)

    def f_ref(q, k, v):
        G = H // KV
        qq = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        kk = jnp.repeat(k, G, 2).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        vv = jnp.repeat(v, G, 2).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        o = fref.attention_bh(qq, kk, vv, causal=True)
        return jnp.sum(o.reshape(B, H, S, hd).transpose(0, 2, 1, 3) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                                   rtol=1e-4)


def test_model_attn_impl_pallas_matches_jnp(key):
    """cfg.attn_impl='pallas' routes forward through the kernel — outputs
    must match the jnp path."""
    from repro.configs import get_config
    from repro.models import transformer
    cfg = get_config("qwen3-8b", smoke=True).replace(vocab_size=256)
    params = transformer.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
    lj, _ = transformer.forward(cfg, params, toks)
    lp, _ = transformer.forward(cfg.replace(attn_impl="pallas"), params, toks)
    np.testing.assert_allclose(np.asarray(lj), np.asarray(lp), atol=2e-4,
                               rtol=1e-3)
