"""Unit/property tests for the continuous-time async parameter server:
the deterministic ``(time, priority, seq)`` event-queue key, the
version-based staleness discounts and their zero-total merge guard, the
``AsyncPlaneServer`` ledger protocol, the per-merge conservation
invariant, and the fleet-level async wall-clock accounting (independent
cluster clocks never exceed the barrier schedule).

Property tests run through the optional-hypothesis shim (skip without the
``[dev]`` extra); the seeded ``*_examples`` paths keep every checker
executable in any environment.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import aggregation as agg
from repro.core.resources import Fleet
from repro.sim import (AsyncPlaneServer, ClusterClock, ClusterDone,
                       EventQueue, FleetSim, FleetSimConfig,
                       HeterogeneitySim, SimConfig, event_priority,
                       make_fleet_trace, sample_profiles)
from repro.sim.events import (Arrival, Departure, SpikeEnd, StragglerSpike,
                              decode_event, encode_event)


# ------------------------------------------------------------ event queue
def test_heap_key_orders_time_then_priority_then_seq():
    """The explicit (time, priority, seq) key reproduces the engine's old
    stable-sort contract: strictly by time first; at equal times arrivals
    beat every other class; within a class, FIFO insertion order."""
    q = EventQueue()
    q.push(2.0, Departure(7))
    q.push(1.0, Departure(3))          # depart pushed BEFORE the arrival…
    q.push(1.0, Arrival(4))
    q.push(1.0, Arrival(5))
    q.push(1.0, StragglerSpike(6, 2.0, 1.0))
    got = [(t, type(ev).__name__, ev.pid) for t, ev in q.pop_due(2.0)]
    assert got == [(1.0, "Arrival", 4),    # …but arrivals pop first
                   (1.0, "Arrival", 5),    # FIFO among equal keys
                   (1.0, "Departure", 3),
                   (1.0, "StragglerSpike", 6),
                   (2.0, "Departure", 7)]


def test_event_priority_arrival_first():
    assert event_priority(Arrival(0)) == 0
    for ev in (Departure(0), StragglerSpike(0, 2.0, 1.0), SpikeEnd(0),
               ClusterDone(-1, level=2)):
        assert event_priority(ev) == 1


def test_pop_due_where_preserves_total_order():
    """Async per-cluster event consumption: popping only one predicate's
    events must leave the rest in their ORIGINAL total order for later
    pops — no re-stamped seq, no reordering."""
    q = EventQueue()
    for pid in (0, 1, 2, 3):
        q.push(1.0, Departure(pid))
    mine = q.pop_due_where(1.0, lambda ev: ev.pid % 2 == 0)
    assert [ev.pid for _, ev in mine] == [0, 2]
    rest = q.pop_due(1.0)
    assert [ev.pid for _, ev in rest] == [1, 3]


def test_queue_encode_roundtrip_and_legacy_3tuple():
    """encode()/load_encoded() round-trips the 4-tuple key exactly, and a
    pre-priority checkpoint (3-tuple ``(t, seq, event)`` entries, no
    priority column) still loads with priorities re-derived — the old
    on-disk format stays resumable."""
    q = EventQueue()
    q.push(1.0, Departure(3))
    q.push(1.0, Arrival(4))
    rec = q.encode()
    q2 = EventQueue()
    q2.load_encoded(rec)
    assert q2.encode() == rec
    assert [ev.pid for _, ev in q2.pop_due(1.0)] == [4, 3]
    legacy = {"seq": 2,
              "entries": [[1.0, 0, encode_event(Departure(3))],
                          [1.0, 1, encode_event(Arrival(4))]]}
    q3 = EventQueue()
    q3.load_encoded(legacy)
    assert [ev.pid for _, ev in q3.pop_due(1.0)] == [4, 3]


def test_cluster_done_codec():
    ev = ClusterDone(-1, level=3)
    assert decode_event(encode_event(ev)) == ev


# ------------------------------------------- version staleness + merge guard
def check_version_equals_age(ns, lags, discount):
    """Version-based staleness with versions advancing one per round IS the
    buffered round-age discount: lag k ≡ age k, numerically identical."""
    v = 100
    got = agg.version_staleness_weights(ns, [v - k for k in lags], v,
                                        discount)
    ref = agg.staleness_weights(ns, lags, discount)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def check_anchored_guard(anchor, us):
    """anchored_merge_weights never emits NaN: a zero total degenerates to
    (anchor keeps weight 1, every ledger row 0) — a zero delta; a positive
    total yields a convex combination."""
    aw, uw = agg.anchored_merge_weights(anchor, us)
    assert np.isfinite(aw) and np.isfinite(np.asarray(uw)).all()
    total = float(anchor) + float(sum(us))
    if total <= 0.0:
        assert aw == 1.0 and all(u == 0.0 for u in uw)
    else:
        np.testing.assert_allclose(aw + sum(uw), 1.0, rtol=1e-9)


@given(st.lists(st.floats(0.0, 50.0), min_size=1, max_size=6),
       st.lists(st.integers(0, 9), min_size=6, max_size=6),
       st.floats(0.05, 1.0))
@settings(max_examples=60, deadline=None)
def test_version_staleness_equals_round_age(ns, lags, discount):
    check_version_equals_age(ns, lags[:len(ns)], discount)


@given(st.floats(0.0, 100.0),
       st.lists(st.floats(0.0, 20.0), max_size=5))
@settings(max_examples=60, deadline=None)
def test_anchored_merge_weights_guard(anchor, us):
    check_anchored_guard(anchor, us)


def test_staleness_and_guard_examples():
    check_version_equals_age([2.0, 3.0], [0, 4], 0.5)
    check_version_equals_age([1.0], [1], 0.9)
    check_anchored_guard(0.0, [])
    check_anchored_guard(0.0, [0.0, 0.0])    # the PR-4 contract: no NaN
    check_anchored_guard(3.0, [1.0, 2.0])
    rng = np.random.default_rng(0)
    for _ in range(25):
        k = int(rng.integers(1, 6))
        check_version_equals_age(rng.uniform(0, 50, k).tolist(),
                                 rng.integers(0, 9, k).tolist(),
                                 float(rng.uniform(0.05, 1.0)))
        check_anchored_guard(float(rng.uniform(0, 100)),
                             rng.uniform(0, 20, k).tolist())


# ------------------------------------------------------------ server object
def test_async_server_ledger_protocol():
    bank = []
    srv = AsyncPlaneServer(0, state="s0", ledger=bank)
    assert srv.pull() == ("s0", 0)
    bank.append({"pid": 7, "round": 0, "n_eff": 3, "plane": None})
    assert srv.ripe() == []            # banked AT the current version: not ripe
    srv.commit("s1", 2)
    assert srv.pull() == ("s1", 2) and srv.merges == 1
    assert len(srv.ripe()) == 1 and srv.lag_of(bank[0]) == 2
    bank.append({"pid": 8, "round": 2, "n_eff": 1, "plane": None})
    srv.drop_ripe()
    assert [b["pid"] for b in bank] == [8]
    assert srv.ledger is bank          # in-place: the engine alias survives


def test_cluster_clock():
    c = ClusterClock()
    c.advance(1.5, rounds=2)
    c.advance(0.5)
    assert (c.now, c.round) == (2.0, 2)


# ------------------------------------------------------------ invariants
def test_conservation_invariant_raises():
    from repro.sim.report import ClusterRoundStats
    ok = ClusterRoundStats(level=0, time=1.0, active=[0, 1], dropped=[2],
                           offline=[3], banked=[4], unselected=[5])
    HeterogeneitySim._check_conservation(ok, 6, 0)
    with pytest.raises(RuntimeError, match="conservation"):
        HeterogeneitySim._check_conservation(ok, 7, 0)


def test_async_mode_validation():
    fleet = Fleet.from_matrix(sample_profiles(16, seed=0))
    trace = make_fleet_trace("stable", 16, 2, seed=0)
    with pytest.raises(ValueError, match="parallel"):
        FleetSim(fleet, trace, FleetSimConfig(rounds=2, mode="async",
                                              schedule="sequential"))
    with pytest.raises(ValueError, match="mode"):
        FleetSim(fleet, trace, FleetSimConfig(rounds=2, mode="bogus"))


# ------------------------------------------------------ fleet async clocks
def _fleet_run(mode, n=600, rounds=4):
    fleet = Fleet.from_matrix(sample_profiles(n, seed=0))
    trace = make_fleet_trace("straggler", n, rounds, seed=0)
    return FleetSim(fleet, trace,
                    FleetSimConfig(rounds=rounds, seed=0, mode=mode,
                                   mar_policy="wait")).run()


def test_fleet_async_wall_clock_at_most_barrier():
    """Independent cluster clocks: async total wall-clock telescopes to
    max_l Σ_r t[l,r], which is ≤ the barrier's Σ_r max_l t[l,r] — and on a
    straggler-spike trace (some cluster slowest in some round only) it is
    strictly less.  Per-round per-cluster times are identical: the async
    fleet changes ACCOUNTING, not scheduling decisions."""
    sync, async_ = _fleet_run("sync"), _fleet_run("async")
    ws, wa = sync.summary()["wall_clock_s"], async_.summary()["wall_clock_s"]
    assert wa <= ws + 1e-9
    for rs, ra in zip(sync.rows, async_.rows):
        np.testing.assert_array_equal(rs.time, ra.time)
        np.testing.assert_array_equal(rs.active, ra.active)
    total = sum(r.duration for r in async_.rows)
    per_cluster = np.sum([r.time for r in async_.rows], axis=0)
    np.testing.assert_allclose(total, float(per_cluster.max()), rtol=1e-9)


# ------------------------------------------------------ engine-level config
def test_engine_async_rejects_sequential():
    from repro.core.resources import participants_from_matrix

    class _Eng:    # duck-typed minimal engine: ctor validation only
        parts = participants_from_matrix(sample_profiles(4, seed=0),
                                         n_data=[10] * 4)
        cfg = None
    with pytest.raises(ValueError, match="parallel"):
        HeterogeneitySim(_Eng(), None,
                         SimConfig(rounds=2, mode="async",
                                   schedule="sequential"))
    with pytest.raises(ValueError, match="mode"):
        HeterogeneitySim(_Eng(), None, SimConfig(rounds=2, mode="bogus"))
