"""Eq. 6/7/8 — paper Example 3 exact + bound behaviour properties."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import rounds as rnd


def test_example3_exact():
    """μ=0.7, L=1.5, B=1, E||w1-w*||=0.08, E_f=20, q_o=0.05 → β=20, R_f=6."""
    c = rnd.example3_constants()
    assert rnd.beta(20, c) == 20          # max(8·1.5/0.7=17.14, 20)
    assert rnd.communication_rounds(0.05, 20, c, B=1.0) == 6


def test_rounds_decrease_with_looser_precision():
    c = rnd.example3_constants()
    rs = [rnd.communication_rounds(q, 20, c, B=1.0)
          for q in (0.01, 0.05, 0.2)]
    assert rs[0] >= rs[1] >= rs[2]


def test_rounds_decrease_with_more_local_epochs():
    c = rnd.example3_constants()
    # more local work per round → fewer rounds (for fixed B)
    assert (rnd.communication_rounds(0.05, 40, c, B=1.0)
            <= rnd.communication_rounds(0.05, 5, c, B=1.0))


def test_precision_bound_consistent_with_eq7():
    """Rounds from Eq. 7 must achieve precision ≤ q_target under Eq. 6
    (same B) — the inversion is self-consistent."""
    c = rnd.ConvergenceConstants()
    eps = np.full(8, 1 / 8)
    E = 5
    B = rnd.b_constant(eps, E, c)
    R = rnd.communication_rounds(0.05, E, c, B=B)
    q = rnd.precision_bound(eps, E, R, c, B=B)
    assert q <= 0.05 + 1e-9


@given(st.integers(2, 12), st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_precision_improves_with_rounds(F, seed):
    rng = np.random.default_rng(seed)
    c = rnd.ConvergenceConstants()
    n = rng.integers(10, 100, F).astype(float)
    eps = n / n.sum()
    qs = [rnd.precision_bound(eps, 5, R, c) for R in (2, 8, 32)]
    assert qs[0] >= qs[1] >= qs[2]


def test_single_participant_has_zero_error():
    """Procedure 2 Case 1: err ≡ 0 for a lone participant."""
    c = rnd.ConvergenceConstants()
    assert rnd.optimization_error([1.0], [10], 0.01, 10, c) == 0.0


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_error_grows_with_tau_heterogeneity(seed):
    """Eq. 8: more heterogeneous τ_j (same mean) → larger bound."""
    c = rnd.ConvergenceConstants()
    eps = np.full(4, 0.25)
    homo = rnd.optimization_error(eps, [10, 10, 10, 10], 0.01, 20, c)
    hetero = rnd.optimization_error(eps, [1, 5, 15, 19], 0.01, 20, c)
    assert hetero > homo


def test_error_decreases_with_rounds():
    c = rnd.ConvergenceConstants()
    eps = np.full(4, 0.25)
    taus = [2, 4, 8, 16]
    e1 = rnd.optimization_error(eps, taus, 0.01, 5, c)
    e2 = rnd.optimization_error(eps, taus, 0.01, 50, c)
    assert e2 < e1
