"""Crash-safety subsystem: manifest-layer hardening, run-state round trips
across every live dtype/shape family, kill/resume bit-exactness for both
simulators, graceful degradation under checkpoint corruption, and the
serving-side plane hot-reload.

The in-process tests simulate SIGKILL with ``FaultPlan(raise_instead=True)``
(→ ``SimulatedCrash``) and then build a FRESH engine — a stand-in for a new
process — with ``resume=True``; the slow subprocess tests deliver a real
SIGKILL/SIGTERM through the ``sim_run`` CLI.
"""
import json
import os
import signal
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointError
from repro.ckpt.manifest import CheckpointManager
from repro.ckpt.run_state import (RUN_STATE_VERSION, RunCheckpointer,
                                  make_checkpointer)
from repro.core import server as srv
from repro.core.families import cnn_family
from repro.core.plane import make_plane_spec
from repro.core.resources import Fleet, participants_from_matrix
from repro.data import device_sampler
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_classification, train_test_split
from repro.launch.serve import PlaneWatcher
from repro.sim import (FleetSim, FleetSimConfig, HeterogeneitySim, SimConfig,
                       make_fleet_trace, make_trace, sample_profiles)
from repro.sim.faults import (CORRUPTION_MODES, FaultInjector, FaultPlan,
                              SimulatedCrash, compare_reports,
                              corrupt_checkpoint)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FAM = cnn_family(classes=10, in_channels=1, base_width=0.125)
HDR = {"run_state": {"version": RUN_STATE_VERSION, "kind": "hetero-sim"}}


# ------------------------------------------------------- manifest layer
def _families():
    """One array per live dtype/shape family the run-state snapshot holds."""
    rng = np.random.default_rng(0)
    spec = make_plane_spec({"w": np.zeros((9, 3), np.float32)}, model_size=4)
    return {
        "plane/0": rng.normal(size=spec.d_pad).astype(np.float32),
        "labels": rng.integers(0, 10, 500).astype(np.int32),
        "fleet/n_data": rng.integers(1, 9999, 1000).astype(np.int64),
        "parts/V": rng.normal(size=(16, 3)),                    # float64
        "rows/active": np.zeros((0, 3), np.int64),              # empty bank
        "online": rng.integers(0, 2, 1000).astype(bool),
    }


def test_manager_roundtrip_every_dtype_family(tmp_path):
    """fp32 planes (model_size-padded), int32 label shards, int64 fleet
    columns, float64 resource matrices, bool masks and EMPTY arrays all
    survive a manifest save/load bit-identically, as writable copies."""
    arrays = _families()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"tag": "fam"}, arrays)
    meta, back = mgr.load_step(1)
    assert meta["tag"] == "fam"
    assert set(back) == set(arrays)
    for k, a in arrays.items():
        assert back[k].dtype == a.dtype and back[k].shape == a.shape, k
        np.testing.assert_array_equal(back[k], a, err_msg=k)
        assert back[k].flags.writeable, k
    # model_size padding is a multiple of 128*model_size, not plain 128
    assert arrays["plane/0"].shape[0] % (128 * 4) == 0


def test_manager_rotation_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"r": s}, {"a": np.full(3, s, np.float32)})
    assert mgr.steps() == [3, 4]
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert sorted(dirs) == ["step_00000003", "step_00000004"]
    assert mgr.load_latest()[0] == 4


@pytest.mark.parametrize("mode", CORRUPTION_MODES)
def test_manager_degrades_to_previous_valid(tmp_path, mode):
    """A corrupted/truncated/deleted NEWEST checkpoint never crashes the
    restore: ``load_latest`` walks back to the previous valid step (or, for
    manifest damage, the directory scan still finds intact steps)."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    for s in (1, 2):
        mgr.save(s, {"r": s}, {"a": np.full(4, s, np.float32)})
    corrupt_checkpoint(str(tmp_path), mode)
    got = CheckpointManager(str(tmp_path), keep=3).load_latest()
    assert got is not None, f"[{mode}] no fallback checkpoint found"
    step, meta, arrays = got
    # manifest damage loses no step data; payload damage falls back to 1
    assert step == (2 if mode == "manifest" else 1)
    np.testing.assert_array_equal(arrays["a"], np.full(4, step, np.float32))


def test_manager_no_checkpoints(tmp_path):
    assert CheckpointManager(str(tmp_path)).load_latest() is None
    assert CheckpointManager(str(tmp_path / "nonexistent")).steps() == []


def test_run_checkpointer_header_validation(tmp_path):
    """Foreign kinds and incompatible versions are skipped with a warning,
    not loaded into the wrong engine."""
    ck = make_checkpointer(str(tmp_path), every=2)
    assert not ck.due(0) and not ck.due(1) and ck.due(2) and not ck.due(3)
    ck.save(2, "fleet-sim", {"round": 2}, {"a": np.zeros(2, np.float32)})
    assert ck.load_latest("hetero-sim") is None      # kind mismatch
    assert ck.load_latest("fleet-sim")[0] == 2
    bad = dict(HDR, run_state={"version": RUN_STATE_VERSION + 1,
                               "kind": "hetero-sim"})
    ck.manager.save(4, bad, {"a": np.zeros(2, np.float32)})
    assert ck.load_latest("hetero-sim") is None      # version mismatch


def test_sampler_stream_fingerprint():
    """The resume integrity probe: equal (seed, round) → equal fingerprint,
    different seed or round → different (the guard that refuses to resume a
    checkpoint whose sampler stream diverged)."""
    a = device_sampler.stream_fingerprint(3, 7)
    assert a == device_sampler.stream_fingerprint(3, 7)
    assert a != device_sampler.stream_fingerprint(4, 7)
    assert a != device_sampler.stream_fingerprint(3, 8)


# ------------------------------------------------------- engine resume
def _setup(seed=0, **cfg_kw):
    ds = make_classification("synth-mnist", 400, seed=seed)
    train, test = train_test_split(ds)
    idx = dirichlet_partition(train.y, 8, alpha=2.0, seed=seed)
    parts = participants_from_matrix(sample_profiles(8, seed=seed),
                                     n_data=[len(p) for p in idx])
    cd = [{"x": train.x[p], "y": train.y[p]} for p in idx]
    cfg = srv.FLConfig(steps_per_round=2, lr=0.08, seed=seed, local_batch=8,
                       compact_to=2, **cfg_kw)
    eng = srv.FedRAC(parts, cd, FAM, cfg, classes=10).setup()
    testb = {"x": jnp.asarray(test.x), "y": jnp.asarray(test.y)}
    return eng, testb


def _run_sim(ckpt_dir=None, resume=False, plan=None, rounds=4, **cfg_kw):
    eng, testb = _setup(**cfg_kw)
    trace = make_trace("mixed", 8, rounds, seed=5)
    ck = (make_checkpointer(str(ckpt_dir), every=1, resume=resume)
          if ckpt_dir else None)
    sim = HeterogeneitySim(eng, trace, SimConfig(rounds=rounds,
                                                 mar_policy="mask"),
                           checkpoint=ck,
                           faults=FaultInjector(plan) if plan else None)
    try:
        rep = sim.run(testb)
    except SimulatedCrash:
        return None
    return _sim_key(sim, rep)


def _sim_key(sim, rep):
    params = {lvl: [np.asarray(x) for x in jax.tree.leaves(p)]
              for lvl, p in sim.params.items()}
    rows = [(r.round, r.duration,
             [(c.level, c.time, c.mean_loss, sorted(c.active),
               sorted(c.dropped), sorted(c.offline),
               sorted(c.masked.items()), sorted(c.violations),
               sorted(c.banked), sorted(c.unselected), c.flushed, c.bytes,
               c.acc) for c in r.clusters]) for r in rep.rows]
    summary = {k: v for k, v in rep.summary().items()
               if k not in ("compiles", "transfers")}   # process-local
    return params, rows, summary


def _assert_identical(ctrl, res, tag):
    assert res is not None, f"[{tag}] resume crashed"
    for lvl in ctrl[0]:
        for a, b in zip(ctrl[0][lvl], res[0][lvl]):
            assert np.array_equal(a, b), f"[{tag}] params differ L{lvl}"
    assert ctrl[1] == res[1], f"[{tag}] rows differ"
    assert ctrl[2] == res[2], f"[{tag}] summary differs"


@pytest.mark.parametrize("mode", ["legacy", "dispatch"])
def test_engine_resume_bit_identical(tmp_path, mode):
    """Crash at a round boundary, resume in a FRESH engine (new process
    stand-in) → final params, per-round rows, and summary totals are
    bit-identical to the uninterrupted control run — both engine modes."""
    kw = {"rounds_per_dispatch": 4} if mode == "dispatch" else {}
    ctrl = _run_sim(**kw)
    assert _run_sim(tmp_path, plan=FaultPlan(kill_at_round=2,
                                             raise_instead=True),
                    **kw) is None
    _assert_identical(ctrl, _run_sim(tmp_path, resume=True, **kw), mode)


def test_engine_resume_mid_block_recompute(tmp_path):
    """A SIGKILL inside a dispatch block (fused program ran, rounds not yet
    recorded) loses the in-flight work; resume recomputes the whole block
    from the last boundary checkpoint bit-identically."""
    kw = {"rounds_per_dispatch": 3}
    ctrl = _run_sim(rounds=5, **kw)
    assert _run_sim(tmp_path, rounds=5,
                    plan=FaultPlan(kill_mid_block=4, raise_instead=True),
                    **kw) is None
    _assert_identical(ctrl, _run_sim(tmp_path, resume=True, rounds=5, **kw),
                      "mid-block")


def test_engine_resume_cross_mode(tmp_path):
    """Checkpoints are mode-agnostic: state is serialized as flat planes in
    both engine modes, so a checkpoint written by a LEGACY run loads under
    a dispatch engine — the restored round history is preserved verbatim
    and the run completes.  (Full-run bit-equality ACROSS modes is not
    expected: the two modes draw different batch streams; numeric agreement
    is the equivalence matrix's stream-bridge territory.)"""
    ctrl = _run_sim()                                   # legacy control
    assert _run_sim(tmp_path, plan=FaultPlan(kill_at_round=2,
                                             raise_instead=True)) is None
    res = _run_sim(tmp_path, resume=True, rounds_per_dispatch=4)
    assert res is not None, "legacy checkpoint failed to load under dispatch"
    assert res[1][:2] == ctrl[1][:2], "restored row prefix mutated"
    assert len(res[1]) == len(ctrl[1])


def test_engine_resume_skips_corrupt_newest(tmp_path):
    """The newest checkpoint is garbage-corrupted after the crash: resume
    degrades to the previous valid one (recomputing one more round) and the
    run is STILL bit-identical — never a crash."""
    ctrl = _run_sim(rounds_per_dispatch=4)
    assert _run_sim(tmp_path, plan=FaultPlan(kill_at_round=3,
                                             raise_instead=True),
                    rounds_per_dispatch=4) is None
    corrupt_checkpoint(str(tmp_path), "garbage")
    _assert_identical(ctrl, _run_sim(tmp_path, resume=True,
                                     rounds_per_dispatch=4),
                      "corrupt-newest")


def test_engine_resume_no_valid_checkpoint_starts_fresh(tmp_path):
    """No checkpoint validates at all → degrade to a from-scratch run (with
    a warning), which still ends bit-identical to the control."""
    ctrl = _run_sim()
    (tmp_path / "MANIFEST.json").write_text("not json at all")
    _assert_identical(ctrl, _run_sim(tmp_path, resume=True), "fresh-fallback")


def test_engine_resume_rejects_foreign_seed(tmp_path):
    """A checkpoint whose sampler stream diverged from the engine's config
    must fail LOUDLY (resuming it could not be bit-identical)."""
    assert _run_sim(tmp_path, plan=FaultPlan(kill_at_round=2,
                                             raise_instead=True)) is None
    with pytest.raises(CheckpointError, match="seed"):
        _run_sim(tmp_path, resume=True, seed=1)


def test_engine_save_now_writes_pending_boundary(tmp_path):
    """``save_now`` (the SIGTERM path) writes the newest retained boundary
    snapshot even when the periodic cadence never fired."""
    eng, testb = _setup()
    ck = make_checkpointer(str(tmp_path), every=100)   # never due
    sim = HeterogeneitySim(eng, make_trace("mixed", 8, 3, seed=5),
                           SimConfig(rounds=3, mar_policy="mask"),
                           checkpoint=ck)
    sim.run(testb)
    assert ck.manager.steps() == []                    # cadence never fired
    assert sim.save_now() == 3
    step, meta, _ = ck.load_latest("hetero-sim")
    assert step == 3 and meta["round"] == 3
    # no checkpointer armed → save_now is a harmless no-op
    assert HeterogeneitySim(eng, make_trace("stable", 8, 1),
                            SimConfig(rounds=1)).save_now() is None


# ------------------------------------------------------- fleet resume
def _run_fleet(ckpt_dir=None, resume=False, plan=None, rounds=6, seed=3):
    fleet = Fleet.from_matrix(sample_profiles(1500, seed=seed))
    trace = make_fleet_trace("mixed", 1500, rounds, seed=4)
    ck = (make_checkpointer(str(ckpt_dir), every=2, resume=resume)
          if ckpt_dir else None)
    sim = FleetSim(fleet, trace, FleetSimConfig(rounds=rounds, seed=seed),
                   checkpoint=ck,
                   faults=FaultInjector(plan) if plan else None)
    try:
        rep = sim.run()
    except SimulatedCrash:
        return None
    rows = [{f: (getattr(r, f).tolist()
                 if isinstance(getattr(r, f), np.ndarray) else getattr(r, f))
             for f in ("round", "duration", "time", "active", "masked",
                       "dropped", "offline", "unselected", "violations",
                       "banked", "flushed", "bytes", "events")}
            for r in rep.rows]
    return rows, rep.summary(), rep.levels.tolist()


def test_fleet_resume_bit_identical(tmp_path):
    """FleetSim: SIGKILL at a round boundary, fresh-engine resume → every
    per-round column, the summary, and the level assignment are identical
    (cadence every=2, so resume also recomputes one unsaved round)."""
    ctrl = _run_fleet()
    assert _run_fleet(tmp_path, plan=FaultPlan(kill_at_round=5,
                                               raise_instead=True)) is None
    res = _run_fleet(tmp_path, resume=True)
    assert ctrl == res


def test_fleet_resume_corrupt_newest(tmp_path):
    ctrl = _run_fleet()
    assert _run_fleet(tmp_path, plan=FaultPlan(kill_at_round=5,
                                               raise_instead=True)) is None
    corrupt_checkpoint(str(tmp_path), "truncate")
    assert ctrl == _run_fleet(tmp_path, resume=True)


# ------------------------------------------------------- plane hot-reload
def test_plane_watcher_hot_reload_and_degrade(tmp_path):
    """serve-side watcher: adapts the newest valid ``plane/<level>`` into
    the params template, skips corrupt steps and shape-incompatible planes
    with a warning, and keeps the previous plane on every failure."""
    tmpl = {"w": np.zeros((7, 5), np.float32), "b": np.zeros(5, np.float32)}
    spec = make_plane_spec(tmpl)
    mgr = CheckpointManager(str(tmp_path), keep=10)
    for s in (1, 2):
        mgr.save(s, HDR, {"plane/0": np.full(spec.d_pad, float(s),
                                             np.float32)})
    w = PlaneWatcher(str(tmp_path), tmpl, level=0)
    p, fresh = w.poll(tmpl)
    assert fresh and w.step == 2
    assert float(np.asarray(p["w"])[0, 0]) == 2.0
    p2, fresh = w.poll(p)
    assert not fresh and p2 is p                     # nothing newer
    mgr.save(3, HDR, {"plane/0": np.full(spec.d_pad, 3.0, np.float32)})
    corrupt_checkpoint(str(tmp_path), "garbage")     # newest now corrupt
    _, fresh = w.poll(p)
    assert not fresh, "corrupt newest must not reload"
    mgr.save(4, HDR, {"plane/0": np.full(spec.d_pad, 4.0, np.float32)})
    p4, fresh = w.poll(p)
    assert fresh and w.step == 4
    mgr.save(5, HDR, {"plane/0": np.zeros(spec.d_pad * 2, np.float32)})
    p5, fresh = w.poll(p4)                           # wrong model
    assert not fresh and p5 is p4
    mgr.save(6, HDR, {"other": np.zeros(4, np.float32)})
    _, fresh = w.poll(p4)                            # plane key absent
    assert not fresh


# ------------------------------------------------------- real signals (CLI)
SIM_CLI = [sys.executable, "-m", "repro.launch.sim_run", "--trace", "mixed",
           "--participants", "8", "--samples", "400", "--rounds", "4",
           "--steps-per-round", "2", "--base-width", "0.125",
           "--mar-policy", "mask", "--rounds-per-dispatch", "4"]


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["PYTHONUNBUFFERED"] = "1"
    return env


@pytest.mark.slow
def test_cli_sigkill_resume_bit_identical(tmp_path):
    """The CI lane's contract end to end: a real SIGKILL at round boundary
    2, then ``--resume`` in a new process; the resumed report JSON
    (including per-level params CRC32) is bit-identical to the
    uninterrupted control's."""
    ctrl, res = str(tmp_path / "ctrl.json"), str(tmp_path / "res.json")
    ck = str(tmp_path / "ckpt")
    r = subprocess.run(SIM_CLI + ["--report-out", ctrl], env=_env(),
                       capture_output=True, text=True, timeout=420, cwd=REPO)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    r = subprocess.run(SIM_CLI + ["--ckpt-dir", ck, "--kill-at-round", "2"],
                       env=_env(), capture_output=True, text=True,
                       timeout=420, cwd=REPO)
    assert r.returncode == -signal.SIGKILL, (r.returncode, r.stderr[-500:])
    r = subprocess.run(SIM_CLI + ["--ckpt-dir", ck, "--resume",
                                  "--report-out", res],
                       env=_env(), capture_output=True, text=True,
                       timeout=420, cwd=REPO)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    assert compare_reports(ctrl, res) == []
    with open(res) as f:
        assert json.load(f)["params_crc32"], "params CRC missing from report"


@pytest.mark.slow
def test_cli_sigterm_graceful_shutdown(tmp_path):
    """SIGTERM mid-run (fleet path, per-round stdout): the process flushes
    a final checkpoint + partial report and exits 128+15."""
    ck = str(tmp_path / "ckpt")
    rep = str(tmp_path / "partial.json")
    # 50k rounds ≈ minutes of fleet-sim runtime (every 2nd round also pays
    # a checkpoint write), so the TERM below always lands mid-run; trace
    # generation itself stays a few seconds
    cmd = [sys.executable, "-m", "repro.launch.sim_run", "--fleet-size",
           "2000", "--trace", "mixed", "--rounds", "50000",
           "--ckpt-dir", ck, "--ckpt-every", "2", "--report-out", rep]
    proc = subprocess.Popen(cmd, env=_env(), stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True, cwd=REPO)
    try:
        # the CLI prints its timeline only at the end, so progress is
        # observed through the checkpoints themselves
        deadline = time.time() + 300
        while time.time() < deadline and not CheckpointManager(ck).steps():
            if proc.poll() is not None:
                break
            time.sleep(0.25)
        assert CheckpointManager(ck).steps(), "no checkpoint appeared in 300s"
        proc.send_signal(signal.SIGTERM)
        out, _ = proc.communicate(timeout=120)
        rc = proc.returncode
    finally:
        if proc.poll() is None:
            proc.kill()
    assert rc == 128 + signal.SIGTERM, (rc, out[-2000:])
    assert "final checkpoint at round" in out, out[-2000:]
    steps = CheckpointManager(ck).steps()
    assert steps, "graceful shutdown wrote no checkpoint"
    with open(rep) as f:
        doc = json.load(f)
    assert doc["interrupted"] == signal.SIGTERM
