"""Device-resident round pipeline (FLConfig.rounds_per_dispatch): simulator
telemetry/KD/buffered R-invariance, donation semantics, compile stability
under Procedure-2 churn, flat-plane aggregation, and the padded-label dtype
regression.  The cross-path numerical equivalence (loop/vmap/dispatch ×
mesh shapes) moved to ``tests/test_equivalence_matrix.py``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, cost_model
from repro.core import server as srv
from repro.core.families import cnn_family, mlp_family
from repro.core.resources import participants_from_matrix
from repro.data import device_sampler
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_classification, train_test_split
from repro.sim import (HeterogeneitySim, ResourceDrift, SimConfig,
                       make_trace, sample_profiles)

FAM = cnn_family(classes=10, in_channels=1, base_width=0.125)


def _setup(parts_V=None, n=8, samples=400, seed=0, n_data=None, fam=FAM,
           **cfg_kw):
    ds = make_classification("synth-mnist", samples, seed=seed)
    train, test = train_test_split(ds)
    idx = dirichlet_partition(train.y, n, alpha=2.0, seed=seed)
    V = parts_V if parts_V is not None else sample_profiles(n, seed=seed)
    parts = participants_from_matrix(
        V, n_data=n_data if n_data is not None else [len(p) for p in idx])
    cd = [{"x": train.x[p], "y": train.y[p]} for p in idx]
    cfg = srv.FLConfig(steps_per_round=3, lr=0.08, seed=seed,
                       local_batch=8, **cfg_kw)
    eng = srv.FedRAC(parts, cd, fam, cfg, classes=10).setup()
    testb = {"x": jnp.asarray(test.x), "y": jnp.asarray(test.y)}
    return eng, testb


def _allclose_trees(a, b, rtol=2e-4, atol=1e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


# ------------------------------------------------------------ R-invariance
def test_dispatch_intra_block_history_is_exact():
    """A record boundary strictly inside a block is served from the
    scan-stacked per-round planes — identical history to unfused blocks."""
    hists = {}
    for R in (1, 8):
        eng, testb = _setup(n=6, compact_to=1, mar=1e9,
                            rounds_per_dispatch=R)
        m = list(eng.assignment.members[0])
        p0 = eng.family.init(jax.random.PRNGKey(0), 0)
        _, hists[R] = eng._train_cluster_dispatch(0, m, 6, testb, p0,
                                                  record_every=1)
    assert len(hists[1]) == len(hists[8]) == 6
    assert hists[1] == hists[8]


@pytest.mark.slow
def test_dispatch_full_train_matches_r1_blocks():
    """End-to-end FedRAC.train (master FedAvg + slave KD) is invariant to
    the dispatch width."""
    results = {}
    for R in (1, 8):
        eng, testb = _setup(n=8, compact_to=2, rounds_per_dispatch=R,
                            rounds=6)
        # force the dispatch machinery for BOTH widths (R=1 exercises
        # single-round blocks of the same pipeline)
        eng.cfg.rounds_per_dispatch = R
        ref = srv.FedRAC._train_cluster_dispatch
        orig = srv.FedRAC._train_cluster

        def routed(self, level, members, n_rounds, test, teacher=None,
                   record_every=1):
            params = self.family.init(
                jax.random.PRNGKey(self.cfg.seed + level), level)
            if not members:
                return params, []
            return ref(self, level, members, n_rounds, test, params,
                       teacher, record_every)

        srv.FedRAC._train_cluster = routed
        try:
            res = eng.train(testb)
        finally:
            srv.FedRAC._train_cluster = orig
        results[R] = eng
    for lvl, pv in results[8].cluster_params.items():
        _allclose_trees(pv, results[1].cluster_params[lvl])


# ------------------------------------------------------------ simulator
def _telemetry(rep):
    return [(r.round, round(r.duration, 6),
             [(c.level, sorted(c.active), sorted(c.dropped),
               sorted(c.offline), sorted(c.masked), sorted(c.violations),
               sorted(c.banked), c.flushed, round(c.bytes, 1))
              for c in r.clusters], r.events) for r in rep.rows]


def test_sim_dispatch_telemetry_matches_legacy():
    """Per-round MAR telemetry (active/dropped/offline/masked/violations/
    banked/flushed/bytes/durations/events) is identical between the legacy
    per-round engine and the fused dispatch engine on an event-heavy
    trace — fusion never lands a block across an event."""
    tel = {}
    for R in (1, 4):
        eng, testb = _setup(n=8, compact_to=2, rounds_per_dispatch=R)
        sim = HeterogeneitySim(eng, make_trace("mixed", 8, 5, seed=5),
                               SimConfig(rounds=5))
        tel[R] = _telemetry(sim.run(testb))
    assert tel[1] == tel[4]


def _straggler_setup(**kw):
    V = np.array([[3.0, 30.0, 8.0]] * 6
                 + [[0.75, 30.0, 8.0], [1e-4, 30.0, 8.0]])
    eng, testb = _setup(parts_V=V, n=8, compact_to=1, mar=1e9,
                        n_data=[50] * 8, **kw)
    spec = eng.specs[0]
    t = {p: cost_model.round_time(eng.parts[p], spec.flops_per_sample,
                                  spec.model_bytes, spec.E,
                                  eng.assignment.n_eff[p])
         for p in range(8)}
    spec.mar = 0.6 * t[6]
    return eng, testb


@pytest.mark.slow
def test_sim_dispatch_buffered_r_invariance():
    """Buffered async aggregation under fusion: the bank rides the scan
    carry, and final params + banked/flushed accounting are invariant to
    the dispatch width."""
    outs = {}
    for R in (2, 8):
        eng, testb = _straggler_setup(aggregation="buffered",
                                      rounds_per_dispatch=R)
        sim = HeterogeneitySim(eng, make_trace("stable", 8, 6),
                               SimConfig(rounds=6, mar_policy="buffer"))
        rep = sim.run(testb)
        outs[R] = (_telemetry(rep), sim.params[0], rep.summary())
    assert outs[2][0] == outs[8][0]
    _allclose_trees(outs[2][1], outs[8][1])
    s = outs[8][2]
    assert s["banked_total"] == s["flushed_total"] > 0
    assert s["participation_rate"] == 1.0


def test_sim_dispatch_kd_teacher_refresh_r_invariance():
    """KD slave clusters see a per-round-refreshed teacher INSIDE fused
    blocks: R=1 vs R=8 produce the same cluster params under the serial
    (sequential, Eq. 10) master→slave schedule — post-round master planes —
    and under the parallel (Eq. 9) schedule — pre-round master planes
    (rtol 2e-4, matching the parallel-schedule fixed-teacher test)."""
    for schedule in ("sequential", "parallel"):
        outs = {}
        for R in (1, 8):
            eng, testb = _setup(n=8, compact_to=2, fam=mlp_family(),
                                rounds_per_dispatch=R)
            sim = HeterogeneitySim(eng, make_trace("stable", 8, 6),
                                   SimConfig(rounds=6, schedule=schedule))
            # drive the dispatch machinery for BOTH widths (R=1 runs
            # single-round blocks of the same pipeline)
            sim._run_dispatch(testb)
            outs[R] = sim.params
        for lvl in outs[1]:
            _allclose_trees(outs[1][lvl], outs[8][lvl])


# ------------------------------------------------------------ weight edges
def test_normalized_weights_zero_total_returns_zeros():
    """All-violator rounds make the live weight sum 0 — n/Σn must come back
    as zeros, not NaN, and the server deltas must skip to a zero update."""
    w = aggregation.normalized_weights([0.0, 0.0, 0.0])
    assert np.isfinite(np.asarray(w)).all()
    np.testing.assert_array_equal(np.asarray(w), 0.0)
    stack = {"p": jnp.ones((3, 5))}
    delta = aggregation.fedavg_delta({"p": jnp.full((5,), 7.0)}, stack, w)
    np.testing.assert_array_equal(np.asarray(delta["p"]), 0.0)
    plane = jnp.ones((3, 128))
    g = jnp.full((128,), 7.0)
    dp = aggregation.fedavg_delta_plane(g, plane, jnp.zeros((3,)))
    np.testing.assert_array_equal(np.asarray(dp), 0.0)


def test_all_violator_buffered_round_keeps_plane_finite():
    """Regression: a trace where EVERY member of the cluster violates the
    deadline in the same round (live weight sum 0) must not NaN-poison the
    dispatch-path plane — updates bank, flush next round, params stay
    finite and telemetry matches the legacy engine."""
    tel = {}
    for R in (1, 4):
        eng, testb = _setup(n=6, compact_to=1, mar=1e9, fam=mlp_family(),
                            aggregation="buffered", rounds_per_dispatch=R)
        eng.specs[0].mar = 1e-9                    # everyone is always late
        sim = HeterogeneitySim(eng, make_trace("stable", 6, 3),
                               SimConfig(rounds=3, mar_policy="buffer"))
        rep = sim.run(testb)
        c0 = rep.rows[0].clusters[0]
        assert sorted(c0.banked) == sorted(eng.assignment.members[0])
        assert not c0.active
        for p in sim.params.values():
            for leaf in jax.tree.leaves(p):
                assert np.isfinite(np.asarray(leaf)).all()
        tel[R] = _telemetry(rep)
    assert tel[1] == tel[4]


# ------------------------------------------------------------ sampler edges
def test_balanced_indices_narrow_table_not_skewed():
    """A class table narrower than counts.max() must clamp the instance
    draw to the table width: draws stay uniform over each class's first m
    indices instead of silently clamping out-of-range gathers onto the last
    column (which skewed the class distribution)."""
    y = np.array([0] * 12 + [1] * 3)
    table, counts = device_sampler.build_class_table(y, classes=2, m=4)
    assert table.shape == (2, 4) and counts.tolist() == [12, 3]
    idx = np.asarray(device_sampler.balanced_indices(
        device_sampler.round_key(0, 0), steps=64, batch=8,
        tables=jnp.asarray(table[None]), counts=jnp.asarray(counts[None])))[0]
    cls0, cls1 = idx[:, 0::2].ravel(), idx[:, 1::2].ravel()
    # class-0 slots: uniform over the first m=4 class-0 indices {0..3};
    # the unclamped draw bound (counts[0]=12 > m) would clamp ~2/3 of the
    # gathers onto table[0, -1] == 3
    assert set(cls0.tolist()) == {0, 1, 2, 3}
    assert (cls0 == 3).mean() < 0.5
    # class-1 slots: 3 samples < m, bounded by counts as before
    assert set(cls1.tolist()) <= {12, 13, 14}


def test_sampler_offset_slices_global_stream():
    """Per-member keyed draws: a device holding member rows [k:] with
    offset=k draws bit-identically to rows [k:] of the full draw — the
    invariant that makes mesh-sharded programs match unsharded ones."""
    key = device_sampler.round_key(3, 7)
    n = jnp.asarray([5, 9, 17, 33, 2, 50, 50, 50], jnp.int32)
    full = np.asarray(device_sampler.uniform_indices(key, 3, 4, n))
    part = np.asarray(device_sampler.uniform_indices(key, 3, 4, n[5:],
                                                     offset=5))
    np.testing.assert_array_equal(full[5:], part)
    tables = jnp.tile(jnp.arange(6, dtype=jnp.int32)[None, None], (8, 3, 1))
    counts = jnp.tile(jnp.asarray([4, 6, 0], jnp.int32)[None], (8, 1))
    fullb = np.asarray(device_sampler.balanced_indices(key, 3, 4, tables,
                                                       counts))
    partb = np.asarray(device_sampler.balanced_indices(key, 3, 4, tables[2:],
                                                       counts[2:], offset=2))
    np.testing.assert_array_equal(fullb[2:], partb)


def test_bank_carry_compresses_overflow():
    """Banked backlog larger than a (shrunk) cluster capacity must not
    crash the dispatch engine: overflow rows compress into one
    weighted-average row preserving Σu and Σu·p exactly."""
    eng, testb = _setup(n=6, compact_to=1, mar=1e9, fam=mlp_family(),
                        aggregation="buffered", rounds_per_dispatch=4,
                        pad_clusters=False)
    sim = HeterogeneitySim(eng, make_trace("stable", 6, 2),
                           SimConfig(rounds=2, mar_policy="buffer"))
    members = list(eng.assignment.members[0])[:2]     # capacity 2
    cap = eng._capacity(len(members))
    dp = eng.plane_spec(0).d_pad
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    ripe = [{"pid": i, "round": 0, "n_eff": i + 1,
             "plane": eng.plane_of(0, eng.family.init(k, 0))}
            for i, k in enumerate(keys)]              # 5 entries > cap 2
    bank_plane, bank_w, gain = sim._bank_carry(0, members, ripe, [], r=2)
    assert bank_plane.shape == (cap, dp) and bank_w.shape == (cap,)
    us = aggregation.staleness_weights([b["n_eff"] for b in ripe],
                                       [2] * 5, eng.cfg.staleness_discount)
    np.testing.assert_allclose(float(bank_w.sum()), sum(us), rtol=1e-6)
    want = sum(u * np.asarray(b["plane"]) for u, b in zip(us, ripe))
    got = np.asarray(bank_w) @ np.asarray(bank_plane)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------ donation
def test_donated_plane_is_consumed():
    """With donate_plane the input plane buffer is dead after a dispatch —
    reusing it must raise (no silent aliasing of stale buffers); with
    donation off it stays valid and round-trips."""
    eng, _ = _setup(n=6, compact_to=1, mar=1e9, rounds_per_dispatch=4)
    m = list(eng.assignment.members[0])
    params = eng.family.init(jax.random.PRNGKey(0), 0)
    plane = eng.plane_of(0, params)
    out = eng.dispatch_rounds(0, m, plane, 0, 4)
    assert plane.is_deleted()
    with pytest.raises(RuntimeError, match="deleted"):
        np.asarray(plane)
    assert not out.plane.is_deleted()

    eng2, _ = _setup(n=6, compact_to=1, mar=1e9, rounds_per_dispatch=4,
                     donate_plane=False)
    m2 = list(eng2.assignment.members[0])
    plane2 = eng2.plane_of(0, params)
    out2 = eng2.dispatch_rounds(0, m2, plane2, 0, 4)
    assert not plane2.is_deleted()
    _allclose_trees(eng2.params_of(0, plane2), params, rtol=0, atol=0)
    # the two variants still compute the same result
    np.testing.assert_allclose(np.asarray(out.plane), np.asarray(out2.plane),
                               rtol=2e-4, atol=1e-5)


# ------------------------------------------------------------ compile stats
def test_dispatch_compile_stable_under_churn():
    """Procedure-2 churn (≥5 drift migrations) in dispatch mode reuses the
    per-(level, capacity, R) block programs: every jitted program compiles
    exactly once — checked both through ``compile_stats()`` and through the
    obs registry's per-program ``fl/compiles/*`` counters, which must stay
    in lockstep (the registry is the surfaced view of the same drift
    invariant)."""
    from repro.obs import make_observability
    eng, testb = _setup(n=10, samples=500, compact_to=2,
                        rounds_per_dispatch=4)
    trace = make_trace("stable", 10, 8)
    pid = eng.assignment.members[0][0]
    for r in range(7):
        mult = 0.02 if r % 2 == 0 else 50.0
        trace.events.append((float(r), ResourceDrift(
            pid, s_mult=mult, r_mult=mult, a_mult=1.0)))
    obs = make_observability(trace=False)
    sim = HeterogeneitySim(eng, trace, SimConfig(rounds=8), obs=obs)
    rep = sim.run(testb)
    migrations = sum(ev.count("→") for r in rep.rows for ev in r.events)
    assert migrations >= 5, f"only {migrations} migrations in trace"
    stats = eng.compile_stats()
    dispatch_keys = [k for k in stats if k[0] == "dispatch"]
    assert dispatch_keys, "no dispatch programs were built"
    retraced = {k: v for k, v in stats.items() if v != 1}
    assert not retraced, f"programs retraced: {retraced}"
    # one program per (level, capacity, R) triple
    triples = [(k[1], k[3], k[4]) for k in dispatch_keys]
    assert len(triples) == len(set(triples))
    # registry view: one fl/compiles/dispatch_* counter per triple, each 1,
    # with a positive wall-time gauge beside it
    compiles = {k: c.value for k, c in obs.registry.counters.items()
                if k.startswith("fl/compiles/dispatch_")}
    assert len(compiles) == len(triples), (compiles, triples)
    assert all(v == 1 for v in compiles.values()), compiles
    for label in compiles:
        g = obs.registry.gauges["fl/compile_s/" + label.split("/")[-1]]
        assert g.value > 0
    assert obs.registry.histograms["fl/compile_s"].count >= len(triples)


# ------------------------------------------------------------ dtype hazard
def test_padded_batches_and_shards_keep_label_dtype():
    """Regression: integer-label pytrees keep their dtype through capacity
    zero-padding (legacy ``_stacked_batches``) and through the
    device-resident shard pack + in-program gather."""
    eng, _ = _setup(n=6, compact_to=1, mar=1e9, rounds_per_dispatch=4)
    m = list(eng.assignment.members[0])
    assert eng.client_data[m[0]]["y"].dtype == np.int32
    cap = len(m) + 2
    batches = eng._stacked_batches(m, 0, 0, cap)
    assert batches["y"].dtype == jnp.int32
    assert batches["x"].dtype == jnp.float32
    assert batches["y"].shape[0] == cap
    np.testing.assert_array_equal(np.asarray(batches["y"][len(m):]), 0)
    pack = eng._shard_pack(0, m, cap, balanced=False)
    assert pack["shards"]["y"].dtype == jnp.int32
    assert pack["n"].dtype == jnp.int32
    # the fused program consumes them end to end without dtype surgery
    plane = eng.plane_of(0, eng.family.init(jax.random.PRNGKey(0), 0))
    out = eng.dispatch_rounds(0, m, plane, 0, 2)
    assert np.isfinite(np.asarray(out.losses)).all()


# ------------------------------------------------------------ plane ops
def test_plane_roundtrip_and_alignment():
    from repro.core.plane import PLANE_ALIGN
    eng, _ = _setup(n=6, compact_to=1, mar=1e9)
    params = eng.family.init(jax.random.PRNGKey(3), 0)
    spec = eng.plane_spec(0)
    assert spec.d_pad % PLANE_ALIGN == 0 and spec.d_pad >= spec.d
    plane = eng.plane_of(0, params)
    assert plane.shape == (spec.d_pad,) and plane.dtype == jnp.float32
    back = eng.params_of(0, plane)
    _allclose_trees(back, params, rtol=0, atol=0)


def test_aggregate_plane_matches_tree_and_kernel():
    """Flat-plane aggregation == pytree FedAvg == the Pallas fedagg kernel
    run directly on the plane (interpret mode)."""
    from repro.kernels.fedagg.ops import aggregate_plane as kernel_plane
    eng, _ = _setup(n=6, compact_to=1, mar=1e9, fam=mlp_family())
    spec = eng.plane_spec(0)
    C = 5
    keys = jax.random.split(jax.random.PRNGKey(0), C)
    stacks = [eng.family.init(k, 0) for k in keys]
    stack = jax.tree.map(lambda *xs: jnp.stack(xs), *stacks)
    w = aggregation.normalized_weights([3, 1, 4, 1, 5])
    want = aggregation.aggregate(stack, w)
    plane = jnp.stack([eng.plane_of(0, p) for p in stacks])
    got = eng.params_of(0, aggregation.aggregate_plane(plane, w))
    _allclose_trees(got, want, rtol=1e-6, atol=1e-6)
    got_k = eng.params_of(0, kernel_plane(plane, w, interpret=True))
    _allclose_trees(got_k, want, rtol=1e-6, atol=1e-6)
    # delta + buffered merge on the plane
    g = plane[0]
    delta = aggregation.fedavg_delta_plane(g, plane, w)
    np.testing.assert_allclose(
        np.asarray(delta),
        np.asarray(aggregation.aggregate_plane(plane, w) - g), rtol=1e-6)
    merged = aggregation.merge_buffered_plane(
        aggregation.aggregate_plane(plane, w * 0.5), plane, w * 0.5)
    np.testing.assert_allclose(np.asarray(merged),
                               np.asarray(aggregation.aggregate_plane(plane, w)),
                               rtol=1e-5, atol=1e-6)
