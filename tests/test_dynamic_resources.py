"""§IV-A dynamic resources: participants upgrade/downgrade clusters when
their (s, r, a) change mid-deployment."""
import numpy as np

from repro.core import assignment as asg
from repro.core import rounds as rnd
from repro.core.resources import TABLE_III, participants_from_matrix


def _setup(mar=1.0):
    parts = participants_from_matrix(TABLE_III, n_data=[60] * 40)
    c = rnd.ConvergenceConstants()
    sizes = [(4e5 * 0.5 ** l, 2e6 * 0.5 ** l) for l in range(4)]
    specs = asg.build_cluster_specs(sizes, c, E=2, mar=mar)
    out = asg.assign(parts, specs, c)
    return parts, specs, c, out


def _level_of(out, pid):
    return next(l for l, m in out.members.items() if pid in m)


def test_degraded_participant_downgrades():
    parts, specs, c, out = _setup()
    # pick someone in the master cluster and choke their link
    pid = out.members[0][0]
    lvl0 = _level_of(out, pid)
    p = parts[pid]
    p.r = 0.5                                     # Mbps — straggler now
    old, new = asg.reassign(p, out, specs, c)
    assert old == lvl0
    assert new > old                              # downgraded
    assert pid in out.members[new] and pid not in out.members[old]


def test_boosted_participant_upgrades():
    parts, specs, c, out = _setup()
    low = max(l for l, m in out.members.items() if m)
    if not out.members[low]:
        return
    pid = out.members[low][0]
    p = parts[pid]
    p.s, p.r, p.a = 3.2, 80.0, 8.0                # best-in-fleet resources
    old, new = asg.reassign(p, out, specs, c)
    assert old == low
    assert new <= old                             # upgraded (or equal)
    assert new == 0                               # in fact reaches the master


def test_reassign_preserves_total_membership():
    parts, specs, c, out = _setup()
    for pid in (0, 7, 21):
        parts[pid].r = max(0.5, parts[pid].r / 10)
        asg.reassign(parts[pid], out, specs, c)
    assigned = sorted(p for mem in out.members.values() for p in mem)
    assert assigned == list(range(40))            # nobody lost or duplicated


def test_server_update_resources(tiny_fl_setup):
    import dataclasses

    from repro.core import server as srv
    from repro.core.families import cnn_family
    parts, client_data, train, test = tiny_fl_setup
    # update_resources mutates Participant objects in place — copy them so
    # the session-scoped fixture stays pristine for later test modules
    parts = [dataclasses.replace(p) for p in parts]
    fam = cnn_family(classes=10, in_channels=1, base_width=0.125)
    cfg = srv.FLConfig(rounds=1, steps_per_round=1, compact_to=3, seed=3)
    eng = srv.FedRAC(parts, client_data, fam, cfg, classes=10).setup()
    pid = eng.assignment.members[0][0]
    old, new = eng.update_resources(pid, r=0.2)
    assert old == 0 and new > 0
