"""FedAvg aggregation: tree / Pallas-kernel / manual equivalence + properties."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import aggregation as agg
from repro.kernels.fedagg import ops as kops
from repro.kernels.fedagg import ref as kref
from repro.kernels.fedagg.kernel import weighted_aggregate


def _stack(key, C=6):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (C, 13, 7)),
            "b": jax.random.normal(k2, (C, 5))}


def test_tree_aggregate_matches_manual(key):
    stack = _stack(key)
    w = agg.normalized_weights([1, 2, 3, 4, 5, 6])
    out = agg.aggregate(stack, w)
    manual = jax.tree.map(
        lambda x: sum(w[i] * x[i] for i in range(6)), stack)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(manual)):
        np.testing.assert_allclose(a, b, rtol=1e-5)


def test_kernel_aggregate_matches_tree(key):
    stack = _stack(key)
    w = agg.normalized_weights([3, 1, 4, 1, 5, 9])
    a = agg.aggregate(stack, w)
    b = kops.aggregate_tree(stack, w)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-6)


def test_kernel_vs_ref_shapes_dtypes(key):
    for C in (2, 7, 16):
        for D in (64, 1000, 4096):
            for dt in (jnp.float32, jnp.bfloat16):
                x = jax.random.normal(key, (C, D)).astype(dt)
                w = jax.nn.softmax(jax.random.normal(key, (C,)))
                pad = (-D) % min(2048, D)
                xp = jnp.pad(x, ((0, 0), (0, pad)))
                got = weighted_aggregate(xp, w, block_d=min(2048, D + pad))[:D]
                want = kref.weighted_aggregate(x, w)
                np.testing.assert_allclose(
                    np.asarray(got, np.float32), np.asarray(want, np.float32),
                    rtol=2e-2 if dt == jnp.bfloat16 else 1e-5, atol=1e-2)


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_aggregation_linearity(seed):
    """agg(stack, w) is linear: agg(a·x) == a·agg(x)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, 20)).astype(np.float32))
    w = jnp.asarray(rng.dirichlet(np.ones(4)).astype(np.float32))
    a = 2.5
    y1 = agg.aggregate({"x": a * x}, w)["x"]
    y2 = a * agg.aggregate({"x": x}, w)["x"]
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-6)


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_aggregate_of_identical_params_is_identity(seed):
    rng = np.random.default_rng(seed)
    p = jnp.asarray(rng.normal(size=(11,)).astype(np.float32))
    stack = {"p": jnp.stack([p] * 5)}
    w = jnp.asarray(rng.dirichlet(np.ones(5)).astype(np.float32))
    out = agg.aggregate(stack, w)["p"]
    np.testing.assert_allclose(out, p, rtol=1e-5)


def test_sharded_aggregate_matches_tree_on_single_device(key):
    """shard_map psum path on a 1×1 mesh ≡ plain tree aggregation (the
    multi-device equivalence is exercised in test_dryrun_small.py)."""
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    stack = _stack(key, C=4)
    w = agg.normalized_weights([1, 1, 2, 2])
    a = agg.aggregate(stack, w)
    b = agg.aggregate_sharded(mesh, stack, w)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(x, y, rtol=1e-5)
