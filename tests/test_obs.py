"""Observability layer: metrics registry primitives (counter/gauge/
histogram/ring-buffer tables), span tracer + Chrome-trace export, the JSONL
schema validator, SimReport's registry-backed summary (including the
masked-participation regression), and one small end-to-end sim run
asserting the acceptance contract: ≥95% span coverage and bit-exact
summary parity between ``--metrics-out`` and ``report.summary()``."""
import json
import math

import numpy as np
import pytest

from repro.obs import (NULL_OBS, NULL_TRACER, MetricsRegistry, Tracer,
                       make_observability, span_coverage)
from repro.obs.registry import Table
from repro.obs.validate import (check_summary_parity, validate_metrics_jsonl,
                                validate_trace)
from repro.sim.report import ClusterRoundStats, RoundRecord, SimReport


# ------------------------------------------------------------ registry
def test_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("a/b")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    assert reg.counter("a/b") is c                # get-or-create identity
    g = reg.gauge("g")
    assert math.isnan(g.value)
    g.set(4)
    g.set(7.0)
    assert g.value == 7.0
    h = reg.histogram("h")
    for v in (1e-3, 2e-3, 5.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 3
    assert s["sum"] == pytest.approx(5.003)
    assert s["min"] == 1e-3 and s["max"] == 5.0
    assert sum(n for _, n in s["buckets"]) == 3


def test_table_append_growth_and_order():
    t = Table("t", {"a": "int64", "b": "float64"}, capacity=2, max_rows=64)
    for i in range(10):
        t.append(a=i, b=i * 0.5)
    assert len(t) == 10 and t.dropped == 0        # grew past capacity=2
    np.testing.assert_array_equal(t.column("a"), np.arange(10))
    assert [r["b"] for r in t.rows()] == [i * 0.5 for i in range(10)]


def test_table_ring_wrap_counts_dropped():
    t = Table("t", {"a": "int64"}, capacity=4, max_rows=4)
    for i in range(7):
        t.append(a=i)
    assert len(t) == 4 and t.dropped == 3
    # oldest retained first
    np.testing.assert_array_equal(t.column("a"), [3, 4, 5, 6])


def test_table_bump_last_and_reset():
    t = Table("t", {"round": "int64", "level": "int64", "flushed": "int64"})
    t.append(round=0, level=0, flushed=0)
    t.append(round=0, level=1, flushed=0)
    t.append(round=1, level=0, flushed=1)
    assert t.bump_last("flushed", 2, match={"round": 0, "level": 1})
    assert not t.bump_last("flushed", 9, match={"round": 5, "level": 0})
    assert t.column("flushed").tolist() == [0, 2, 1]
    t.reset()
    assert len(t) == 0 and t.dropped == 0
    t.append(round=7, level=0, flushed=0)
    assert t.column("round").tolist() == [7]


def test_table_defaults_fill_missing_fields():
    t = Table("t", {"x": "int64", "acc": "float64"},
              defaults={"acc": math.nan})
    t.append(x=1)
    t.append(x=2, acc=0.5)
    acc = t.column("acc")
    assert math.isnan(acc[0]) and acc[1] == 0.5


def test_registry_text_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("fl/compiles/p0").inc(2)
    reg.gauge("fl/compile_s/p0").set(0.25)
    reg.histogram("fl/compile_s").observe(0.25)
    txt = reg.render_text()
    assert "# TYPE fl_compiles_p0 counter" in txt
    assert "fl_compiles_p0 2" in txt
    assert 'fl_compile_s_bucket{le="1"} 1' in txt
    assert "fl_compile_s_count 1" in txt
    snap = reg.snapshot()
    assert snap["counters"]["fl/compiles/p0"] == 2
    assert snap["gauges"]["fl/compile_s/p0"] == 0.25
    assert snap["histograms"]["fl/compile_s"]["count"] == 1


def test_jsonl_roundtrip_through_validator(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c").inc(3)
    reg.gauge("nanned").set(float("nan"))         # NaN → null, not a crash
    t = reg.table("tab", {"a": "int64", "b": "float64"})
    t.append(a=1, b=0.1)
    t.append(a=2, b=0.2)
    p = tmp_path / "m.jsonl"
    n = reg.to_jsonl(p)
    assert n == 2 + 1 + 2                         # counter+gauge, meta, rows
    out = validate_metrics_jsonl(p)
    assert out["counters"]["c"] == 3
    assert out["gauges"]["nanned"] is None
    assert [r["a"] for r in out["tables"]["tab"]] == [1, 2]
    assert out["dropped"] == {"tab": 0}


def test_validator_rejects_schema_drift(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text(
        json.dumps({"kind": "table", "name": "t", "columns": ["a"],
                    "rows": 1, "dropped": 0}) + "\n"
        + json.dumps({"kind": "row", "table": "t", "a": 1, "EXTRA": 2}) + "\n")
    with pytest.raises(ValueError, match="column"):
        validate_metrics_jsonl(p)


# ------------------------------------------------------------ tracer
def test_tracer_spans_nest_and_export(tmp_path):
    tr = Tracer()
    with tr.span("root", cat="engine", mode="x"):
        with tr.span("child_a"):
            pass
        with tr.span("child_b"):
            pass
    tr.instant("marker")
    evs = tr.events()
    names = [e["name"] for e in evs]
    assert set(names) == {"root", "child_a", "child_b", "marker"}
    root = next(e for e in evs if e["name"] == "root")
    assert root["args"] == {"mode": "x"}
    for e in evs:
        if e["name"].startswith("child"):
            assert e["ts"] >= root["ts"]
            assert e["ts"] + e["dur"] <= root["ts"] + root["dur"] + 1e-6
    doc = tr.to_chrome()
    assert doc["traceEvents"][0]["ph"] == "M"     # process_name metadata
    p = tmp_path / "trace.json"
    tr.write(p)
    assert json.loads(p.read_text())["displayTimeUnit"] == "ms"
    validate_trace(p)                             # loadable, well-formed


def test_tracer_complete_is_retroactive():
    tr = Tracer()
    with tr.span("root"):
        pass
    import time
    t0 = time.perf_counter_ns()
    tr.complete("compile", t0 - 10_000, 10_000, cat="fl", level=0)
    ev = next(e for e in tr.events() if e["name"] == "compile")
    assert ev["dur"] == pytest.approx(10.0)       # ns → µs
    assert ev["args"]["level"] == 0


def test_span_coverage_math():
    # hand-built events: root [0, 100], children covering [0,60]+[50,90]
    mk = lambda n, ts, dur: {"name": n, "ph": "X", "ts": ts, "dur": dur}
    evs = [mk("root", 0, 100), mk("a", 0, 60), mk("b", 50, 40)]
    assert span_coverage(evs, "root") == pytest.approx(0.9)
    with pytest.raises(ValueError, match="no 'nope' span"):
        span_coverage(evs, "nope")


def test_null_tracer_is_inert():
    with NULL_TRACER.span("x", cat="y", z=1):
        pass
    NULL_TRACER.instant("x")
    NULL_TRACER.complete("x", 0, 1)
    obj = object()
    assert NULL_TRACER.fence(obj) is obj          # identity, no jax import
    assert NULL_TRACER.events() == []
    assert not NULL_OBS.on
    assert NULL_OBS.tracer is NULL_TRACER


# ------------------------------------------------------------ SimReport
def _mk_report(obs=None):
    rep = SimReport(scenario="t", mar_policy="mask", schedule="sync",
                    obs=obs)
    rep.add(RoundRecord(round=0, t_start=0.0, duration=2.0, clusters=[
        ClusterRoundStats(level=0, time=2.0, active=[0, 1], bytes=100.0,
                          mean_loss=1.0, masked={2: 1},  # 2 NOT in active
                          violations=[2]),
        ClusterRoundStats(level=1, time=1.0, active=[3], bytes=50.0,
                          mean_loss=2.0, dropped=[4]),
    ]))
    rep.add(RoundRecord(round=1, t_start=2.0, duration=3.0, clusters=[
        ClusterRoundStats(level=0, time=3.0, active=[0, 1, 2], bytes=100.0,
                          mean_loss=0.5, acc=0.9),
        ClusterRoundStats(level=1, time=1.0, active=[3], bytes=50.0,
                          mean_loss=1.5, banked=[4]),
    ]))
    return rep


def test_summary_counts_masked_participants():
    """Regression: a member masked to a partial-step update (and not listed
    in ``active``) still participated — it must appear in the participant
    set, the active-slot numerator, and the registry's ``active`` column."""
    rep = _mk_report()
    s = rep.summary()
    assert s["participants"] == 5                 # pids 0..4; 2 via masked
    # slots: r0 (2a+1mask)+(1a+1drop), r1 3a+(1a+1bank) = active 8, bank 1,
    # drop 1 → rate (8+1)/(8+1+1)
    assert s["participation_rate"] == pytest.approx(9 / 10)
    assert s["mar_violations"] == 1
    assert s["dropped_total"] == 1 and s["banked_total"] == 1
    assert s["total_bytes"] == 300.0
    # the columnar row for r0/L0 counted the masked pid as active
    tab = rep.registry.tables["sim/cluster_rounds"]
    assert tab.column("active").tolist() == [3, 1, 3, 1]
    assert tab.column("masked").tolist() == [1, 0, 0, 0]


def test_bump_flushed_keeps_view_and_table_in_sync():
    rep = _mk_report()
    rep.bump_flushed(1, 2)
    assert rep.rows[-1].clusters[1].flushed == 2
    tab = rep.registry.tables["sim/cluster_rounds"]
    assert tab.column("flushed").tolist() == [0, 0, 0, 2]
    assert rep.summary()["flushed_total"] == 2


def test_shared_registry_resets_between_reports():
    obs = make_observability(trace=False)
    _mk_report(obs=obs)
    rep2 = _mk_report(obs=obs)                    # same registry, new run
    assert len(obs.registry.tables["sim/cluster_rounds"]) == 4
    assert rep2.summary()["rounds"] == 2


def test_summary_parity_with_jsonl_export(tmp_path):
    obs = make_observability(trace=False)
    rep = _mk_report(obs=obs)
    rep.bump_flushed(0, 1)
    m = tmp_path / "metrics.jsonl"
    r = tmp_path / "report.json"
    obs.registry.to_jsonl(m)
    r.write_text(json.dumps(rep.to_dict()))
    parity = check_summary_parity(validate_metrics_jsonl(m), r)
    assert parity["total_bytes"] == 300.0


# ------------------------------------------------------ end-to-end (small)
def test_sim_obs_end_to_end(tmp_path):
    """A 4-round dispatch-mode sim with observability on: the trace loads,
    round blocks cover ≥95% of ``sim.run``, compile counters are all 1 and
    agree with ``compile_stats()``, and the exported JSONL reproduces
    ``summary()`` exactly."""
    import jax.numpy as jnp

    from repro.core import server as srv
    from repro.core.families import mlp_family
    from repro.core.resources import participants_from_matrix
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import make_classification, train_test_split
    from repro.sim import (HeterogeneitySim, SimConfig, make_trace,
                           sample_profiles)

    ds = make_classification("synth-mnist", 160, seed=0)
    train, test = train_test_split(ds)
    idx = dirichlet_partition(train.y, 4, alpha=2.0, seed=0)
    parts = participants_from_matrix(sample_profiles(4, seed=0),
                                     n_data=[len(p) for p in idx])
    cd = [{"x": train.x[p], "y": train.y[p]} for p in idx]
    cfg = srv.FLConfig(steps_per_round=2, lr=0.08, seed=0, local_batch=8,
                       rounds_per_dispatch=2)
    eng = srv.FedRAC(parts, cd, mlp_family(), cfg, classes=10).setup()
    obs = make_observability(fence=True)
    sim = HeterogeneitySim(eng, make_trace("stable", 4, 4),
                           SimConfig(rounds=4), obs=obs)
    rep = sim.run({"x": jnp.asarray(test.x), "y": jnp.asarray(test.y)})

    # --- trace: loadable + coverage
    tp = tmp_path / "trace.json"
    obs.tracer.write(tp)
    stats = validate_trace(tp, coverage_root="sim.run", min_coverage=0.95)
    assert stats["coverage"] >= 0.95
    names = {e["name"] for e in obs.tracer.events()}
    for expected in ("sim.run", "round_block", "dispatch", "compile"):
        assert expected in names, f"missing {expected!r} span"

    # --- compile accounting through the registry matches compile_stats()
    snap = obs.registry.snapshot()
    compiles = {k: v for k, v in snap["counters"].items()
                if k.startswith("fl/compiles/")}
    assert compiles and all(v == 1 for v in compiles.values()), compiles
    assert snap["counters"]["fl/compile_total"] == sum(compiles.values())
    stats = eng.compile_stats()
    assert sum(compiles.values()) <= sum(stats.values())
    assert snap["counters"]["fl/dispatch_blocks"] >= 2
    assert snap["counters"]["fl/h2d_bytes"] > 0

    # --- metrics JSONL reproduces summary() bit-exactly
    mp, rp = tmp_path / "m.jsonl", tmp_path / "r.json"
    obs.registry.to_jsonl(mp)
    rp.write_text(json.dumps(rep.to_dict()))
    parity = check_summary_parity(validate_metrics_jsonl(mp), rp)
    assert parity["total_bytes"] == rep.summary()["total_bytes"]
