"""Mesh/sharding integration on 8 forced host devices (subprocess-isolated so
the main test process keeps its single device).  Mirrors launch/dryrun.py at
smoke scale: lower+compile train & decode under the sharding rules, and check
the shard_map FedAvg aggregation equals the single-device tree aggregation."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    return subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                          capture_output=True, text=True, timeout=500, env=env)


@pytest.mark.slow
def test_sharded_train_and_decode_compile():
    r = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config
        from repro.launch import sharding, specs
        from repro.launch.dryrun import make_train_step, make_serve_step
        from repro.launch.mesh import make_host_mesh
        from repro.models import registry
        from repro.optim import optimizers
        from repro.configs.base import InputShape

        assert len(jax.devices()) == 8
        mesh = make_host_mesh(2, 4)
        cfg = get_config("jamba-v0.1-52b", smoke=True).replace(
            d_model=256, n_heads=4, n_kv_heads=2, head_dim=64)
        shape = InputShape("t", 64, 8, "train")
        p_shape = specs.params_shape(cfg)
        p_spec = sharding.param_specs(cfg, p_shape, mesh)
        opt_shape = jax.eval_shape(optimizers.adamw().init, p_shape)
        o_spec = {"m": p_spec, "v": p_spec, "t": P()}
        batch = specs.train_inputs(cfg, shape)
        b_spec = sharding.batch_specs(cfg, batch, mesh)
        step, _ = make_train_step(cfg)
        jitted = jax.jit(step,
            in_shardings=sharding.to_named(mesh, (p_spec, o_spec, b_spec)),
            out_shardings=sharding.to_named(mesh, (p_spec, o_spec, P())))
        with mesh:
            c = jitted.lower(p_shape, opt_shape, batch).compile()
        assert c.cost_analysis() is not None
        print("TRAIN_OK")

        dshape = InputShape("d", 64, 8, "decode")
        token, pos, cache_shape = specs.decode_inputs(cfg, dshape)
        c_spec = sharding.cache_specs(cfg, cache_shape, mesh, shard_seq=False)
        serve = make_serve_step(cfg)
        jit2 = jax.jit(serve,
            in_shardings=sharding.to_named(mesh, (p_spec, c_spec, P(("data",), None), P())),
            out_shardings=sharding.to_named(mesh, (P(), c_spec)))
        with mesh:
            c2 = jit2.lower(p_shape, cache_shape, token, pos).compile()
        print("DECODE_OK")
    """)
    assert "TRAIN_OK" in r.stdout and "DECODE_OK" in r.stdout, (
        r.stdout + "\n" + r.stderr[-3000:])


@pytest.mark.slow
def test_shard_map_aggregation_multidevice():
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import aggregation as agg
        from repro.launch.mesh import make_host_mesh

        assert len(jax.devices()) == 8
        mesh = make_host_mesh(8, 1)
        key = jax.random.PRNGKey(0)
        stack = {"w": jax.random.normal(key, (16, 33)),
                 "b": jax.random.normal(key, (16, 5, 3))}
        w = agg.normalized_weights(np.arange(1, 17))
        a = agg.aggregate(stack, w)
        b = agg.aggregate_sharded(mesh, stack, w)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-5, atol=1e-6)
        print("AGG_OK")
    """)
    assert "AGG_OK" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]


@pytest.mark.slow
def test_long_context_seq_sharding_compiles():
    """batch=1 decode with the KV-cache sequence axis sharded (long_500k
    pattern) must lower+compile with GSPMD-inserted collectives."""
    r = _run("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.launch import sharding, specs
        from repro.launch.dryrun import make_serve_step
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(2, 4)
        cfg = get_config("gemma2-9b", smoke=True).replace(
            d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
            sliding_window=64)
        dshape = InputShape("l", 512, 1, "decode")
        token, pos, cache_shape = specs.decode_inputs(cfg, dshape)
        p_shape = specs.params_shape(cfg)
        p_spec = sharding.param_specs(cfg, p_shape, mesh)
        c_spec = sharding.cache_specs(cfg, cache_shape, mesh, shard_seq=True)
        jit2 = jax.jit(make_serve_step(cfg),
            in_shardings=sharding.to_named(mesh, (p_spec, c_spec, P(), P())),
            out_shardings=sharding.to_named(mesh, (P(), c_spec)))
        with mesh:
            c = jit2.lower(p_shape, cache_shape, token, pos).compile()
        hlo = c.as_text()
        print("LONG_OK")
    """)
    assert "LONG_OK" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]
