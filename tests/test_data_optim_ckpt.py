"""Substrate tests: partitioner, samplers, schedules, optimizers, checkpoint."""
import os

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.ckpt import checkpoint
from repro.data import partition, sampler
from repro.data.synthetic import make_classification, make_lm_corpus
from repro.optim import optimizers, schedules


# ------------------------------------------------------------------ data
@given(st.integers(0, 1000), st.integers(2, 12))
@settings(max_examples=15, deadline=None)
def test_dirichlet_partition_conserves_items(seed, n_clients):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 5, 300)
    parts = partition.dirichlet_partition(labels, n_clients, alpha=0.5,
                                          seed=seed, min_per_client=0)
    all_idx = np.concatenate([p for p in parts if len(p)])
    assert len(all_idx) == 300
    assert sorted(all_idx.tolist()) == list(range(300))


def test_dirichlet_skew_increases_as_alpha_drops():
    labels = np.random.default_rng(0).integers(0, 10, 2000)

    def skew(alpha):
        parts = partition.dirichlet_partition(labels, 10, alpha=alpha, seed=1)
        per_class = np.stack([
            np.bincount(labels[p], minlength=10) for p in parts]).astype(float)
        per_class /= per_class.sum(1, keepdims=True)
        return float(np.std(per_class))

    assert skew(0.1) > skew(100.0)


def test_class_balanced_batches_are_balanced():
    rng = np.random.default_rng(0)
    y = np.concatenate([np.zeros(90), np.ones(10)]).astype(np.int32)
    x = rng.normal(size=(100, 4, 4, 1)).astype(np.float32)
    b = sampler.class_balanced_batches(x, y, 20, 10, classes=2, seed=0)
    frac1 = (b["y"] == 1).mean()
    assert 0.4 <= frac1 <= 0.6          # vs 0.1 in the raw distribution


def test_leave_one_out_removes_class():
    ds = make_classification("synth-har", 300, seed=0)
    x, y = sampler.leave_one_out(ds.x, ds.y, leave_class=2)
    assert (y != 2).all() and len(y) < 300


def test_lm_corpus_learnable_structure():
    toks = make_lm_corpus(50, 5000, seed=0)
    # Markov structure → bigram entropy far below uniform
    big = {}
    for a, b in zip(toks[:-1], toks[1:]):
        big.setdefault(int(a), []).append(int(b))
    ents = []
    for a, nxt in big.items():
        if len(nxt) > 20:
            p = np.bincount(nxt, minlength=50) / len(nxt)
            p = p[p > 0]
            ents.append(-(p * np.log(p)).sum())
    assert np.mean(ents) < 0.8 * np.log(50)


# ------------------------------------------------------------------ optim
def test_wsd_schedule_shape():
    f = schedules.wsd(1.0, 100, warmup_frac=0.1, decay_frac=0.2)
    assert float(f(0)) < 0.2                     # warmup start
    np.testing.assert_allclose(float(f(10)), 1.0, rtol=1e-5)   # end of warmup
    np.testing.assert_allclose(float(f(50)), 1.0, rtol=1e-6)   # stable
    assert float(f(99)) < 0.2                    # decayed
    # stable region is FLAT (the WSD signature)
    assert float(f(30)) == float(f(60))


def test_cosine_schedule_monotone_after_warmup():
    f = schedules.cosine(1.0, 100, warmup=10)
    vals = [float(f(s)) for s in range(10, 100, 10)]
    assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))


def test_adamw_reduces_quadratic_loss():
    opt = optimizers.adamw(weight_decay=0.0)
    params = {"w": jnp.ones((4,)) * 5}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, 0.1)
    assert float(loss(params)) < 1e-2


def test_grad_clip_bounds_norm():
    g = {"a": jnp.ones((100,)) * 10}
    c = optimizers.clip_by_global_norm(g, 1.0)
    assert float(optimizers.global_norm(c)) <= 1.0 + 1e-5


def test_fedprox_term_pulls_towards_global(key):
    """FedProx local update stays closer to the global model than plain SGD."""
    from repro.core.client import local_update
    w0 = {"w": jnp.zeros((8,))}
    target = jax.random.normal(key, (16, 8))
    y = jnp.sum(target, axis=1, keepdims=True)
    batches = {"x": target[None].repeat(10, 0), "y": y[None].repeat(10, 0)}
    loss_fn = lambda p, b: (jnp.mean((b["x"] @ p["w"][:, None] - b["y"]) ** 2),
                            b["x"] @ p["w"][:, None])
    plain, _ = local_update(loss_fn, w0, batches, 0.05)
    prox, _ = local_update(loss_fn, w0, batches, 0.05, prox_mu=10.0,
                           global_params=w0)
    assert (float(jnp.linalg.norm(prox["w"]))
            < float(jnp.linalg.norm(plain["w"])))


# ------------------------------------------------------------------ ckpt
def test_checkpoint_roundtrip(tmp_path, key):
    tree = {"a": jax.random.normal(key, (3, 4)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32),
                       "c": jnp.float32(2.5)}}
    path = os.path.join(tmp_path, "t.ckpt")
    checkpoint.save(path, tree)
    back = checkpoint.restore(path, like=tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_step_management(tmp_path, key):
    tree = {"w": jnp.ones((2,))}
    for s in (1, 2, 3, 4):
        checkpoint.save_step(str(tmp_path), s, tree, keep=2)
    assert checkpoint.latest_step(str(tmp_path)) == 4
    files = sorted(os.listdir(tmp_path))
    assert len(files) == 2


def test_checkpoint_restore_raises_real_exceptions(tmp_path, key):
    """Hardened restore: missing file, truncation, missing leaf, and shape
    mismatch raise ``CheckpointError`` — never a bare assert (which
    vanishes under ``python -O``) and never silent garbage."""
    import pytest
    tree = {"a": jax.random.normal(key, (3, 4)),
            "b": jnp.arange(5, dtype=jnp.int32)}
    path = os.path.join(tmp_path, "t.ckpt")
    checkpoint.save(path, tree)
    with pytest.raises(checkpoint.CheckpointError, match="cannot read"):
        checkpoint.restore(os.path.join(tmp_path, "nope.ckpt"), like=tree)
    with pytest.raises(checkpoint.CheckpointError, match="missing leaf"):
        checkpoint.restore(path, like=dict(tree, c=jnp.zeros(2)))
    with pytest.raises(checkpoint.CheckpointError, match="shape"):
        checkpoint.restore(path, like=dict(tree, a=jnp.zeros((4, 4))))
    with open(path, "rb") as f:
        data = f.read()
    with open(path, "wb") as f:       # SIGKILL-mid-write artifact
        f.write(data[:len(data) // 2])
    with pytest.raises(checkpoint.CheckpointError):
        checkpoint.restore(path, like=tree)


def test_checkpoint_restored_arrays_are_writable(tmp_path):
    """Restored leaves are independently-owned WRITABLE copies, not
    read-only ``np.frombuffer`` views of the msgpack payload — callers feed
    them into donated jax buffers and mutate them in place."""
    tree = {"a": np.arange(6, dtype=np.float32),
            "n": {"b": np.ones((2, 3), dtype=np.int64)}}
    path = os.path.join(tmp_path, "t.ckpt")
    checkpoint.save(path, tree)
    for back in (checkpoint.restore(path),           # raw {path: array} map
                 checkpoint.restore(path, like=tree)):
        for leaf in jax.tree.leaves(back):
            arr = np.asarray(leaf)
            assert arr.flags.writeable
            arr[(0,) * arr.ndim] = 42                # must not raise
