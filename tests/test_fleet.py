"""Fleet-scale stack: struct-of-arrays state, batched trace equivalence,
sampled-Dunn Procedure 1, FedCS selection, delta shard-packs.

The contract under test: every vectorized path must reproduce its scalar
reference bit-for-bit (traces, similarity, delta packs) or provably bound
it (sampled Dunn ≥ exact Dunn), so fleet scale is a performance mode, not
a different simulator.
"""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import clustering as C
from repro.core import resources as R
from repro.sim import (FleetSim, FleetSimConfig, HeterogeneitySim, SimConfig,
                       make_fleet_trace, make_trace, sample_profiles)
from repro.sim import traces as T


# -------------------------------------------------- trace equivalence
@pytest.mark.parametrize("seed", [0, 1, 123])
@pytest.mark.parametrize("rate", [0.0, 0.08, 0.5, 0.9])
def test_vectorized_generators_match_legacy_loops(seed, rate):
    """The batched table builders replay the legacy per-(round, pid) scalar
    rng loops bit-identically: same seeds → same (time, event) stream, for
    every generator, including the interleaved gate/value draws."""
    for n, rounds in [(1, 1), (7, 3), (50, 4)]:
        assert (T.dropout_events(n, rounds, rate, seed)
                == T.legacy_dropout_events(n, rounds, rate, seed))
        assert (T.drift_events(n, rounds, rate, seed)
                == T.legacy_drift_events(n, rounds, rate, seed))
        assert (T.straggler_events(n, rounds, rate, seed)
                == T.legacy_straggler_events(n, rounds, rate, seed))


def test_vectorized_arrivals_match_legacy():
    assert T.late_arrivals(200, 8, 0.4, 3) == T.legacy_late_arrivals(
        200, 8, 0.4, 3)
    # permutation order is the FIFO tie-break and must survive batching
    off, evs = T.late_arrivals(50, 6, 0.5, 0)
    _, levs = T.legacy_late_arrivals(50, 6, 0.5, 0)
    assert [e.pid for _, e in evs] == [e.pid for _, e in levs]


def test_mixed_scenario_matches_legacy_composition():
    """make_trace('mixed') = dropout ⊕ drift ⊕ spikes at seed/seed+1/seed+2,
    exactly as the legacy scenario composed them."""
    ev = make_trace("mixed", 40, 5, seed=9).events
    legacy = (T.legacy_dropout_events(40, 5, 0.08, 9)
              + T.legacy_drift_events(40, 5, 0.05, 10)
              + T.legacy_straggler_events(40, 5, 0.08, 11))
    assert ev == legacy


def test_fleet_trace_is_columnar_and_scales():
    tr = make_fleet_trace("mixed", 5000, 3, seed=0)
    assert tr.n == 5000 and tr.rounds == 3
    for tab in (tr.dropouts, tr.drifts, tr.spikes):
        assert set(tab) >= {"time", "pid"}
        assert all(isinstance(v, np.ndarray) for v in tab.values())
    # Bernoulli(rate) per slot: event count concentrates around n·rounds·rate
    n_drop = len(tr.dropouts["time"])
    assert abs(n_drop - 5000 * 3 * 0.08) < 5 * math.sqrt(5000 * 3 * 0.08)


def test_make_trace_rejects_unknown_knobs():
    with pytest.raises(TypeError, match="does not accept"):
        make_trace("drift", 8, 4, seed=0, dropout_rate=0.2)
    with pytest.raises(TypeError, match="does not accept"):
        make_fleet_trace("stable", 8, 4, seed=0, spike_rate=0.1)
    with pytest.raises(ValueError, match="unknown scenario"):
        make_trace("nope", 8, 4)
    # knobs that DO belong still pass through
    tr = make_trace("dropout", 30, 4, seed=0, dropout_rate=0.5)
    assert len(tr.events) > 0


# -------------------------------------------------- similarity memory path
@pytest.mark.parametrize("lam", [R.LAMBDA_EQUAL, R.LAMBDA_PAPER])
@pytest.mark.parametrize("table", [R.TABLE_I, R.TABLE_III])
def test_similarity_matrix_bit_compatible_with_einsum(table, lam):
    """The per-column accumulation (3× lower peak memory) must keep the
    einsum result bit-for-bit — the Dunn anchors depend on exact ties."""
    Vb = R.unit_normalize(table)
    diff = Vb[:, None, :] - Vb[None, :, :]
    ref = np.sqrt(np.einsum("ijd,d->ij", diff * diff, np.asarray(lam)))
    got = R.similarity_matrix(Vb, lam)
    assert np.array_equal(got, ref)


# -------------------------------------------------- fleet Procedure 1
def test_fleet_procedure1_matches_exact_on_table_i():
    """With full samples, fleet Procedure 1 reduces to the exact path:
    Table I must give the paper's k=3 with identical labels."""
    exact = C.optimal_clusters(R.TABLE_I, R.LAMBDA_EQUAL, seed=0)
    fleet = C.fleet_optimal_clusters(R.TABLE_I, R.LAMBDA_EQUAL, seed=0,
                                     k_cap=3)
    assert fleet.k == exact.k == 3
    assert np.array_equal(fleet.labels, exact.labels)


@pytest.mark.parametrize("lam,k_exp", [(R.LAMBDA_EQUAL, 5),
                                       (R.LAMBDA_PAPER, 6)])
def test_fleet_procedure1_matches_exact_on_table_iii(lam, k_exp):
    exact = C.optimal_clusters(R.TABLE_III, lam, seed=0)
    fleet = C.fleet_optimal_clusters(R.TABLE_III, lam, seed=0, k_cap=6)
    assert fleet.k == exact.k == k_exp
    assert np.array_equal(fleet.labels, exact.labels)
    for k in fleet.di_values:
        assert fleet.di_values[k] == pytest.approx(exact.di_values[k],
                                                   abs=1e-9)


def test_fleet_procedure1_large_no_quadratic():
    """20k participants: runs fast, labels cover every cluster, and the
    frozen (lo, span) lets drift re-placement reproduce the labels."""
    V = sample_profiles(20_000, seed=1)
    res = C.fleet_optimal_clusters(V, R.LAMBDA_PAPER, seed=0)
    assert 2 <= res.k <= 8
    assert len(res.labels) == 20_000
    assert set(np.unique(res.labels)) == set(range(res.k))
    from repro.core.assignment import reassign_by_centroids
    again = reassign_by_centroids(V, res)
    assert np.array_equal(again, res.labels)


@given(st.integers(0, 10_000), st.integers(2, 5), st.integers(2, 30))
@settings(max_examples=20, deadline=None)
def test_sampled_dunn_bounds_exact_dunn(seed, k, sample):
    """Subsampling the inter-cluster minimum can only MISS the true min, so
    sampled Dunn ≥ exact Dunn; with every cluster inside ``sample`` the two
    are equal (diameters are exact either way)."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(60, 3))
    labels = rng.integers(0, k, size=60)
    if len(np.unique(labels)) < 2:
        return
    S = np.sqrt(((X[:, None, :] - X[None, :, :]) ** 2).sum(-1))
    exact = C.dunn_index(S, labels)
    sampled = C.sampled_dunn_index(X, labels, sample=sample, seed=seed)
    assert sampled >= exact - 1e-9
    full = C.sampled_dunn_index(X, labels, sample=60, seed=seed)
    assert full == pytest.approx(exact, rel=1e-9, abs=1e-12)


@pytest.mark.parametrize("seed,k,sample",
                         [(0, 3, 4), (1, 2, 2), (7, 4, 10), (123, 5, 25),
                          (42, 2, 3), (9, 3, 60)])
def test_sampled_dunn_bounds_exact_dunn_seeded(seed, k, sample):
    """Seeded instances of the property above — run even without
    hypothesis installed."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(60, 3))
    labels = rng.integers(0, k, size=60)
    S = np.sqrt(((X[:, None, :] - X[None, :, :]) ** 2).sum(-1))
    exact = C.dunn_index(S, labels)
    sampled = C.sampled_dunn_index(X, labels, sample=sample, seed=seed)
    assert sampled >= exact - 1e-9
    full = C.sampled_dunn_index(X, labels, sample=60, seed=seed)
    assert full == pytest.approx(exact, rel=1e-9, abs=1e-12)


# -------------------------------------------------- Fleet views
def test_fleet_row_views_write_through():
    fleet = R.Fleet.from_matrix(R.TABLE_I.copy(), n_data=range(10, 20))
    p = fleet.participant(3)
    assert (p.pid, p.s, p.n_data) == (3, R.TABLE_I[3, 0], 13)
    p.s = 999.0
    p.n_data = 7
    assert fleet.V[3, 0] == 999.0 and fleet.n_data[3] == 7
    fleet.V[3, 1] = 123.0                      # array write visible via view
    assert p.r == 123.0
    d = p.detach()
    d.s = 1.0                                  # detached copy doesn't write
    assert fleet.V[3, 0] == 999.0
    assert fleet.participant(3) is p           # cached view object


def test_fleet_round_trips_through_participants():
    parts = R.participants_from_matrix(R.TABLE_I, n_data=range(10))
    fleet = R.Fleet.from_participants(parts)
    assert np.array_equal(R.resource_matrix(fleet), R.TABLE_I)
    back = fleet.participants()
    assert [(q.pid, q.s, q.r, q.a, q.n_data) for q in back] == \
           [(q.pid, q.s, q.r, q.a, q.n_data) for q in parts]


# -------------------------------------------------- FedCS selection
def _fleet_sim(n=800, rounds=3, **cfg_kw):
    fleet = R.Fleet.from_matrix(sample_profiles(n, seed=0))
    trace = make_fleet_trace("mixed", n, rounds, seed=0)
    return FleetSim(fleet, trace, FleetSimConfig(rounds=rounds, seed=0,
                                                 **cfg_kw))


def test_fedcs_selected_never_violate_mar():
    """Every FedCS-admitted member satisfies T_i ≤ Θ ≤ MAR, so a fedcs run
    records zero MAR violations; unconstrained 'all' does not."""
    rep = _fleet_sim(select="fedcs").run()
    assert rep.summary()["mar_violations"] == 0
    assert rep.summary()["unselected_total"] > 0
    rep_all = _fleet_sim(select="all").run()
    assert rep_all.summary()["mar_violations"] > 0


def test_fedcs_budget_caps_every_cluster_round():
    budget = 5
    rep = _fleet_sim(select="fedcs", select_budget=budget).run()
    for row in rep.rows:
        sel = row.active + row.masked + row.dropped + row.banked
        assert (sel <= budget).all()
    assert rep.summary()["participation_rate"] > 0


@pytest.mark.parametrize("policy", ["drop", "mask", "wait", "buffer"])
def test_fedcs_composes_with_all_mar_policies(policy):
    rep = _fleet_sim(select="fedcs", select_budget=8,
                     mar_policy=policy).run()
    s = rep.summary()
    assert s["rounds"] == 3 and s["mar_violations"] == 0
    if policy == "buffer":
        assert s["banked_total"] == s["flushed_total"]  # terminal flush


def test_fedcs_in_training_engine_renormalizes_weights():
    """HeterogeneitySim + FedCS: unselected members contribute zero weight,
    the round proceeds on the admitted prefix, and under 'drop' nobody
    admitted is dropped for the deadline (Θ ≤ MAR ⇒ no violations)."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.core import server as srv
    from repro.core.families import cnn_family
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import make_classification, train_test_split
    ds = make_classification("synth-mnist", 400, seed=0)
    train, test = train_test_split(ds)
    idx = dirichlet_partition(train.y, 8, alpha=2.0, seed=0)
    parts = R.participants_from_matrix(sample_profiles(8, seed=0),
                                       n_data=[len(p) for p in idx])
    cd = [{"x": train.x[p], "y": train.y[p]} for p in idx]
    fam = cnn_family(classes=10, in_channels=1, base_width=0.125)
    eng = srv.FedRAC(parts, cd, fam,
                     srv.FLConfig(steps_per_round=2, lr=0.08, seed=0,
                                  local_batch=8, compact_to=2),
                     classes=10).setup()
    sim = HeterogeneitySim(eng, make_trace("stable", 8, 2),
                           SimConfig(rounds=2, select="fedcs",
                                     select_budget=2, eval_every=10))
    rep = sim.run({"x": jnp.asarray(test.x), "y": jnp.asarray(test.y)})
    for row in rep.rows:
        for c in row.clusters:
            assert len(c.active) + len(c.masked) <= 2
            assert not c.dropped               # FedCS ⇒ no deadline drops
    assert sum(len(c.unselected) for row in rep.rows
               for c in row.clusters) > 0


def test_fleetsim_rejects_bad_config():
    with pytest.raises(ValueError):
        _fleet_sim(select="best-effort")
    with pytest.raises(ValueError):
        _fleet_sim(mar_policy="retry")


# -------------------------------------------------- fleet smoke at 10^5
def test_fleet_smoke_100k():
    """10⁵ participants × 3 rounds end-to-end (trace → Procedure 1 → sim):
    every slot accounted for each round, telemetry self-consistent."""
    n = 100_000
    rep = _fleet_sim(n=n, select="fedcs").run()
    assert rep.n == n and 2 <= rep.k <= 8
    assert len(rep.levels) == n
    for row in rep.rows:
        accounted = (row.active + row.masked + row.dropped + row.offline
                     + row.unselected + row.banked).sum()
        assert accounted == n
        assert row.duration >= 0.0 and row.bytes.sum() >= 0.0
    assert rep.summary()["mar_violations"] == 0


# -------------------------------------------------- delta shard-packs
def test_delta_shard_pack_matches_full_rebuild():
    """Membership churn (one member migrates out, one in) must produce a
    pack byte-identical to a from-scratch build: the delta path permutes
    surviving rows on device and scatters only the fresh ones."""
    jax = pytest.importorskip("jax")
    from repro.core import server as srv
    from repro.core.families import cnn_family
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import make_classification, train_test_split
    ds = make_classification("synth-mnist", 400, seed=0)
    train, _ = train_test_split(ds)
    idx = dirichlet_partition(train.y, 8, alpha=2.0, seed=0)
    parts = R.participants_from_matrix(sample_profiles(8, seed=0),
                                       n_data=[len(p) for p in idx])
    cd = [{"x": train.x[p], "y": train.y[p]} for p in idx]
    fam = cnn_family(classes=10, in_channels=1, base_width=0.125)
    eng = srv.FedRAC(parts, cd, fam,
                     srv.FLConfig(steps_per_round=2, lr=0.08, seed=0,
                                  local_batch=8, compact_to=2),
                     classes=10).setup()
    members = list(eng.assignment.members[0])
    others = [p for p in range(8) if p not in members]
    assert len(members) >= 2 and others, "need churn material"
    cap = eng._capacity(len(members))
    eng._shard_pack(0, members, cap, True)             # seeds _pack_prev
    churned = [others[0]] + members[1:]                # one out, one in
    pack_delta = eng._shard_pack(0, churned, cap, True)
    assert eng._delta_h2d is not None                  # delta path taken
    eng._shard_packs.clear()                           # force full rebuild
    eng._pack_prev.clear()
    pack_full = eng._shard_pack(0, churned, cap, True)
    for a, b in zip(jax.tree.leaves(pack_delta["shards"]),
                    jax.tree.leaves(pack_full["shards"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert np.array_equal(np.asarray(pack_delta["n"]),
                          np.asarray(pack_full["n"]))
