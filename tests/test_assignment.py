"""Procedure 2 participant assignment invariants."""
import numpy as np

from repro.core import assignment as asg
from repro.core import compaction, rounds as rnd
from repro.core.resources import TABLE_III, participants_from_matrix, unit_normalize


def _specs(m=4, mar=1.0):
    c = rnd.ConvergenceConstants()
    sizes = [(4e5 * 0.5 ** l, 2e6 * 0.5 ** l) for l in range(m)]
    return asg.build_cluster_specs(sizes, c, E=2, mar=mar), c


def test_every_participant_assigned():
    parts = participants_from_matrix(TABLE_III, n_data=[60] * 40)
    specs, c = _specs()
    out = asg.assign(parts, specs, c)
    assigned = [p for mem in out.members.values() for p in mem]
    assert sorted(assigned) == list(range(40))


def test_fast_participants_reach_higher_clusters():
    parts = participants_from_matrix(TABLE_III, n_data=[60] * 40)
    specs, c = _specs()
    out = asg.assign(parts, specs, c)
    # mean transmission rate of master cluster >= of the lowest cluster
    rates = {l: np.mean([parts[p].r for p in mem]) if mem else np.nan
             for l, mem in out.members.items()}
    lvls = [l for l in sorted(rates) if rates[l] == rates[l]]
    if len(lvls) >= 2:
        assert rates[lvls[0]] > rates[lvls[-1]]


def test_tight_mar_forces_demotions():
    parts = participants_from_matrix(TABLE_III, n_data=[60] * 40)
    loose, c = _specs(mar=100.0)
    tight, _ = _specs(mar=0.3)
    out_loose = asg.assign(parts, loose, c)
    out_tight = asg.assign(parts, tight, c)
    assert out_tight.demotions >= out_loose.demotions
    assert len(out_tight.members[0]) <= len(out_loose.members[0])


def test_n_eff_never_exceeds_data():
    parts = participants_from_matrix(TABLE_III, n_data=list(range(20, 60)))
    specs, c = _specs(mar=0.5)
    out = asg.assign(parts, specs, c)
    for p in parts:
        assert out.n_eff[p.pid] <= p.n_data
        assert out.tau[p.pid] >= 1


def test_compaction_reduces_cluster_count_and_keeps_order():
    V = unit_normalize(TABLE_III)
    labels = np.random.default_rng(0).integers(0, 6, 40)
    # ensure all 6 appear
    labels[:6] = np.arange(6)
    out = compaction.compact(labels, V, 4)
    assert len(np.unique(out)) == 4
    assert set(out) == {0, 1, 2, 3}
    assert len(out) == 40
