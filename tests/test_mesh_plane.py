"""Plane-sharded aggregation OP suite (unit level).

The standalone sharded plane ops (``aggregation.aggregate_plane_sharded``
& friends) must match their single-device counterparts — including
non-divisible member counts (zero-weight-row padding), non-divisible
column counts on a 2D (data × model) mesh (zero-column padding), and
buffered merges.  The END-TO-END dispatch-path equivalence (legacy loop /
vmap / fused / mesh-sharded, all schedules) lives in
``tests/test_equivalence_matrix.py`` — this module keeps only the op-level
checks.

Coverage runs at three tiers:
  * 1-device mesh tests — always (the shard_map path itself);
  * 8-way in-process tests (``_eightway``) — skipped unless the process has
    ≥8 devices; the CI mesh lanes provide them via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``;
  * one slow subprocess test re-running the ``_eightway`` tests under the
    forced-device flag, so tier-1 exercises real multi-device execution
    without polluting this process's single device.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.core import server as srv
from repro.core.families import mlp_family
from repro.core.plane import pad_member_rows
from repro.core.resources import participants_from_matrix
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_classification, train_test_split
from repro.launch.mesh import make_sim_mesh
from repro.sim import sample_profiles

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

eightway = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 forced host devices (CI mesh lane or the slow "
           "subprocess wrapper below)")


def _setup(mesh, n=6, samples=400, seed=0, fam=None, **cfg_kw):
    ds = make_classification("synth-mnist", samples, seed=seed)
    train, test = train_test_split(ds)
    idx = dirichlet_partition(train.y, n, alpha=2.0, seed=seed)
    parts = participants_from_matrix(sample_profiles(n, seed=seed),
                                     n_data=[len(p) for p in idx])
    cd = [{"x": train.x[p], "y": train.y[p]} for p in idx]
    cfg = srv.FLConfig(steps_per_round=3, lr=0.08, seed=seed, local_batch=8,
                       **({"compact_to": 1, "mar": 1e9,
                           "rounds_per_dispatch": 4} | cfg_kw))
    eng = srv.FedRAC(parts, cd, fam or mlp_family(), cfg, classes=10,
                     mesh=mesh).setup()
    testb = {"x": jnp.asarray(test.x), "y": jnp.asarray(test.y)}
    return eng, testb


def _allclose_trees(a, b, rtol=2e-4, atol=1e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


# ------------------------------------------------------------ unit invariant
def test_pad_member_rows_zero_weight_invariant():
    """Zero-weight padding rows leave every weighted contraction untouched
    — the invariant that lets non-divisible C ride any mesh axis."""
    key = jax.random.PRNGKey(0)
    plane = jax.random.normal(key, (5, 128))
    w = agg.normalized_weights([3, 1, 4, 1, 5])
    pp, pw = pad_member_rows(plane, w, 8)
    assert pp.shape == (8, 128) and pw.shape == (8,)
    np.testing.assert_allclose(np.asarray(agg.aggregate_plane(pp, pw)),
                               np.asarray(agg.aggregate_plane(plane, w)),
                               rtol=1e-6)
    with pytest.raises(ValueError, match="cannot pad"):
        pad_member_rows(plane, w, 3)


# ------------------------------------------------------------ 1-device mesh
def test_plane_sharded_ops_match_single_device():
    """shard_map plane path on a 1×1 mesh ≡ single-device aggregate_plane /
    fedavg_delta_plane / merge_buffered_plane (the multi-device equivalence
    runs in the eightway tests below)."""
    mesh = make_sim_mesh(1)
    key = jax.random.PRNGKey(1)
    plane = jax.random.normal(key, (5, 256))
    w = agg.normalized_weights([3, 1, 4, 1, 5])
    want = agg.aggregate_plane(plane, w)
    np.testing.assert_allclose(
        np.asarray(agg.aggregate_plane_sharded(mesh, plane, w)),
        np.asarray(want), rtol=1e-6)
    g = plane[0]
    np.testing.assert_allclose(
        np.asarray(agg.fedavg_delta_plane_sharded(mesh, g, plane, w)),
        np.asarray(want - g), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(agg.merge_buffered_plane_sharded(
            mesh, want * 0.5, plane, w * 0.5)),
        np.asarray(want), rtol=1e-5, atol=1e-6)
    # zero-total guard carries over to the sharded delta
    dz = agg.fedavg_delta_plane_sharded(mesh, g, plane, jnp.zeros((5,)))
    np.testing.assert_array_equal(np.asarray(dz), 0.0)


def test_mesh_requires_dispatch_pipeline():
    with pytest.raises(ValueError, match="rounds_per_dispatch"):
        _setup(make_sim_mesh(1), rounds_per_dispatch=1)


# ------------------------------------------------------- 8-way (in-process)
@eightway
def test_plane_sharded_ops_eightway_non_divisible():
    """13 member rows on an 8-way mesh: zero-weight padding (not a
    divisibility assert) keeps the sharded plane ops equal to the
    single-device contraction — and the pytree aggregate_sharded accepts
    the same non-divisible client count."""
    mesh = make_sim_mesh(8)
    key = jax.random.PRNGKey(2)
    C = 13
    plane = jax.random.normal(key, (C, 384))
    w = agg.normalized_weights(np.arange(1, C + 1))
    want = agg.aggregate_plane(plane, w)
    np.testing.assert_allclose(
        np.asarray(agg.aggregate_plane_sharded(mesh, plane, w)),
        np.asarray(want), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(agg.merge_buffered_plane_sharded(
            mesh, want * 0.25, plane, w * 0.75)),
        np.asarray(want), rtol=1e-5, atol=1e-6)
    stack = {"w": jax.random.normal(key, (C, 33)),
             "b": jax.random.normal(key, (C, 5, 3))}
    _allclose_trees(agg.aggregate_sharded(mesh, stack, w),
                    agg.aggregate(stack, w), rtol=1e-5)


@eightway
def test_plane_sharded_ops_eightway_2d_model_axis():
    """2D (data × model) subgrid contraction on a ``4x2`` mesh: member rows
    split 4-way, plane columns 2-way, one psum over ``data`` only — equal
    to the single-device contraction for aligned AND non-divisible column
    counts (zero-column padding), with the delta/buffered forms and the
    zero-total guard riding along."""
    mesh = make_sim_mesh("4x2")
    key = jax.random.PRNGKey(4)
    for C, D in ((13, 512), (5, 257)):      # D=257: column-padding path
        plane = jax.random.normal(key, (C, D))
        w = agg.normalized_weights(np.arange(1, C + 1))
        want = agg.aggregate_plane(plane, w)
        got = agg.aggregate_plane_sharded(mesh, plane, w,
                                          model_axis="model")
        assert got.shape == (D,)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)
        g = plane[0]
        np.testing.assert_allclose(
            np.asarray(agg.fedavg_delta_plane_sharded(
                mesh, g, plane, w, model_axis="model")),
            np.asarray(want - g), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(agg.merge_buffered_plane_sharded(
                mesh, want * 0.5, plane, w * 0.5, model_axis="model")),
            np.asarray(want), rtol=1e-5, atol=1e-6)
        dz = agg.fedavg_delta_plane_sharded(mesh, g, plane,
                                            jnp.zeros((C,)),
                                            model_axis="model")
        np.testing.assert_array_equal(np.asarray(dz), 0.0)


# ------------------------------------------------------ subprocess (tier-1)
@pytest.mark.slow
def test_mesh_suite_under_forced_host_devices():
    """Tier-1 multi-device coverage: rerun the ``_eightway`` tests above in
    a subprocess with 8 forced host devices (this process keeps 1)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.abspath(__file__), "-k", "eightway"],
        capture_output=True, text=True, timeout=560, env=env, cwd=REPO)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr[-3000:]
    assert "2 passed" in r.stdout, r.stdout
