"""End-to-end behaviour: the train/serve drivers run and learn, and the
Fed-RAC LM family distills across α-compressed transformer levels."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_train_driver_loss_decreases():
    from repro.launch import train as train_mod
    losses = train_mod.main([
        "--arch", "olmo-1b", "--smoke", "--steps", "40", "--batch", "8",
        "--seq", "64", "--lr", "3e-3", "--log-every", "20"])
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_serve_driver_generates():
    from repro.launch import serve as serve_mod
    toks = serve_mod.main([
        "--arch", "olmo-1b", "--smoke", "--batch", "2", "--prompt-len", "8",
        "--gen", "8"])
    assert toks.shape == (2, 8)
    cfg_vocab = 512
    assert (toks >= 0).all() and (toks < cfg_vocab).all()


def test_serve_cluster_level_compression():
    """Fed-RAC serving: a level-2 compressed model is smaller but serves the
    same vocab."""
    from repro.configs import get_config
    from repro.core.scaling import compress_config, param_count
    cfg = get_config("olmo-1b", smoke=True)
    c2 = compress_config(cfg, 0.5, 2)
    assert param_count(c2) < param_count(cfg)
    assert c2.vocab_size == cfg.vocab_size


def test_lm_family_kd_end_to_end(key):
    """Tiny federated LM: master (level-0) trains by FedAvg; the level-1
    slave distills from it — the LM analogue of the paper's CNN pipeline."""
    from repro.configs.base import ModelConfig
    from repro.core import server as srv
    from repro.core.families import lm_family
    from repro.core.resources import TABLE_III, participants_from_matrix
    from repro.data.synthetic import make_lm_corpus, lm_batches

    base = ModelConfig(name="tiny-lm", family="dense", n_layers=2,
                       d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
                       d_ff=256, vocab_size=128, rope_theta=1e4)
    fam = lm_family(base, alpha=0.5)
    corpus = make_lm_corpus(128, 30_000, seed=0)
    n_cl = 8
    parts = participants_from_matrix(TABLE_III[:n_cl], n_data=[64] * n_cl)
    chunks = np.array_split(corpus, n_cl)
    client_data = [{"tokens": lm_batches(ch, 64, 33, 1, seed=i)[0]}
                   for i, ch in enumerate(chunks)]

    class LMFedRAC(srv.FedRAC):
        def _client_batches(self, pid, r, balanced):
            d = self.client_data[pid]
            rng = np.random.default_rng(pid * 31 + r)
            idx = rng.integers(0, d["tokens"].shape[0],
                               (self.cfg.steps_per_round, 8))
            t = d["tokens"][idx]
            return {"tokens": t, "y": t[:, :, -1]}

        def evaluate(self, level, params, test):
            loss, _ = self.family.loss_and_logits(level, params, test)
            return -float(loss)                     # higher is better

    cfg = srv.FLConfig(rounds=3, steps_per_round=4, lr=0.1, compact_to=2,
                       seed=3, class_balanced=False)
    eng = LMFedRAC(parts, client_data, fam, cfg, classes=128).setup()
    test_toks = lm_batches(corpus, 32, 33, 1, seed=99)[0]
    res = eng.train({"tokens": jnp.asarray(test_toks), "y": None})
    h = res.history[0]
    assert len(h) == 3 and h[-1] > h[0]             # master LM improves
    assert eng.m == 2
