"""Property-based tests for the weight-math invariants PRs 2–4 fixed by
hand — randomized statements of what used to be single-example regressions:

  * zero-weight padding rows (``core.plane.pad_member_rows``) leave the
    renormalized FedAvg exactly unchanged (the invariant behind capacity
    buckets AND mesh-axis divisibility);
  * ``normalized_weights`` never emits NaN — a zero total yields zeros;
  * ``staleness_weights`` discounts are monotone in age and clamp age ≥ 1;
  * bank-overflow compression (``aggregation.compress_bank_rows``)
    preserves Σu and Σu·p exactly;
  * plane flatten/unflatten round-trips bit-exactly across every model
    family and 2D-mesh column count (``make_plane_spec(model_size=…)``);
  * the class-balanced sampler (``device_sampler.balanced_indices`` over
    ``build_class_table`` tables) realizes the round-robin quota scheme of
    the host-side numpy reference under arbitrary class skew: every batch
    slot draws from exactly the class the reference assigns it, and narrow
    tables never leak out-of-class or out-of-window indices.

Runs through the optional-hypothesis shim: with hypothesis installed (the
``[dev]`` extra — CI), each property fuzzes; without it the ``@given``
tests skip, and the seeded ``*_examples`` smoke paths below keep every
checker executable anyway.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import ModelConfig
from repro.core import aggregation as agg
from repro.core.families import cnn_family, lm_family, mlp_family
from repro.core.plane import PLANE_ALIGN, make_plane_spec, pad_member_rows
from repro.data.device_sampler import (balanced_indices, build_class_table,
                                       round_key)


# ------------------------------------------------------------ checkers
def check_pad_rows_fedavg_exact(values, weights, extra):
    """Padding (C, D) member rows with zero-weight rows up to C+extra rows
    leaves the RENORMALIZED FedAvg exactly where it was."""
    C = len(weights)
    D = max(1, len(values) // C)
    plane = jnp.asarray(np.resize(np.asarray(values, np.float32), (C, D)))
    w = agg.normalized_weights(weights)
    pp, pw = pad_member_rows(plane, w, plane.shape[0] + extra)
    assert pp.shape[0] == pw.shape[0] == plane.shape[0] + extra
    np.testing.assert_allclose(
        np.asarray(agg.aggregate_plane(pp, agg.normalized_weights(pw))),
        np.asarray(agg.aggregate_plane(plane, w)), rtol=1e-6, atol=1e-6)


def check_normalized_weights_guard(weights):
    w = np.asarray(agg.normalized_weights(weights))
    assert np.isfinite(w).all(), f"NaN/inf from {weights}"
    total = float(np.asarray(weights, np.float32).sum())
    if total > 0.0:
        np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-5)
    else:
        np.testing.assert_array_equal(w, 0.0)


def check_staleness_monotone(n_list, discount):
    """Older banked updates never weigh more; age 0 is clamped to age 1."""
    ages = list(range(len(n_list)))
    w0 = agg.staleness_weights(n_list, ages, discount)
    w1 = agg.staleness_weights(n_list, [a + 1 for a in ages], discount)
    for n, a, wa, wb in zip(n_list, ages, w0, w1):
        if a >= 1:
            assert wb <= wa + 1e-12, (n, a, wa, wb)
    assert agg.staleness_weights([5.0], [0], discount) == \
        agg.staleness_weights([5.0], [1], discount)


def check_compress_preserves_mass(rows_values, us, cap):
    """Compression into ``cap`` slots preserves Σu and Σu·p exactly — the
    only two quantities the bank merge ever reads."""
    rows = [jnp.asarray(np.asarray(r, np.float32)) for r in rows_values]
    out_rows, out_us = agg.compress_bank_rows(rows, us, cap)
    assert len(out_rows) == len(out_us) <= max(cap, len(rows) and 1)
    if len(rows) <= cap:
        assert out_rows is rows and out_us is us      # untouched
        return
    assert len(out_rows) == 1
    np.testing.assert_allclose(sum(out_us), sum(us), rtol=1e-6)
    want = sum(float(u) * np.asarray(r) for u, r in zip(us, rows))
    got = sum(float(u) * np.asarray(r) for u, r in zip(out_us, out_rows))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


_LM_CFG = ModelConfig(name="prop-lm", family="dense", n_layers=1, d_model=16,
                      n_heads=1, n_kv_heads=1, head_dim=16, d_ff=32,
                      vocab_size=16, rope_theta=1e4)
FAMILIES = {
    "mlp": lambda: mlp_family(),
    "cnn": lambda: cnn_family(classes=10, in_channels=1, base_width=0.125),
    "lm": lambda: lm_family(_LM_CFG, alpha=0.5),
}


def check_balanced_sampler_quota(seed, C, classes, batch, steps, m):
    """``balanced_indices`` vs the numpy reference quota scheme, under a
    random class skew per member: (1) slot b of member i draws from class
    ``present_i[b % |present_i|]`` (present classes ascending — the
    round-robin ⌈batch/n⌉ quota split), verified by mapping drawn indices
    back through each member's labels; (2) every drawn index lies in the
    class's first ``min(count, m)`` sample positions (the narrow-table
    uniformity window), so table padding is never drawn."""
    rng = np.random.default_rng(seed)
    ys = []
    for _ in range(C):
        present = rng.permutation(classes)[:int(rng.integers(1, classes + 1))]
        # skewed populations: some present classes rare, some dominant
        ys.append(np.asarray(rng.choice(
            present, size=int(rng.integers(3, 40)),
            p=rng.dirichlet(np.full(len(present), 0.5)))))
    if m is None:  # shared cluster-wide width, like FedRAC's table build
        m = max(1, max(int((y == c).sum()) for y in ys
                       for c in range(classes)))
    tables, counts = map(np.stack, zip(*(build_class_table(y, classes, m)
                                         for y in ys)))
    idx = np.asarray(balanced_indices(round_key(seed, 0), steps, batch,
                                      jnp.asarray(tables),
                                      jnp.asarray(counts)))
    assert idx.shape == (C, steps, batch)
    width = tables.shape[-1]
    for i in range(C):
        y = ys[i]
        present = np.where(counts[i] > 0)[0]            # ascending order
        ref_cls = present[np.arange(batch) % len(present)]   # numpy quota
        # (1) drawn sample's label == reference class, every slot and step
        np.testing.assert_array_equal(
            y[idx[i]], np.broadcast_to(ref_cls, (steps, batch)),
            err_msg=f"member {i}: quota/class assignment diverged")
        # (2) draws stay inside each class's uniform window
        for cls in np.unique(ref_cls):
            window = np.where(y == cls)[0][:min(int(counts[i][cls]), width)]
            drawn = idx[i][:, ref_cls == cls].ravel()
            assert np.isin(drawn, window).all(), \
                f"member {i} class {cls}: draw outside first-{len(window)} " \
                f"window"


def check_plane_roundtrip(family_name, level, model_size, seed):
    """to_params(to_plane(p)) is bit-exact for every family/level, and the
    padded length divides by model_size × PLANE_ALIGN (the 2D-mesh column
    alignment that keeps the per-device Pallas fedagg grid whole)."""
    fam = FAMILIES[family_name]()
    params = fam.init(jax.random.PRNGKey(seed), level)
    spec = make_plane_spec(params, model_size=model_size)
    assert spec.d_pad % (model_size * PLANE_ALIGN) == 0
    assert spec.d_pad >= spec.d
    plane = spec.to_plane(params)
    assert plane.shape == (spec.d_pad,) and plane.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(plane[spec.d:]), 0.0)
    back = spec.to_params(plane)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ------------------------------------------------------------ hypothesis
@given(st.lists(st.floats(-1e3, 1e3, width=32), min_size=4, max_size=24),
       st.lists(st.floats(0.0, 1e4, width=32), min_size=2, max_size=6),
       st.integers(0, 9))
@settings(max_examples=30, deadline=None)
def test_prop_pad_rows_fedavg_exact(values, weights, extra):
    check_pad_rows_fedavg_exact(values, weights, extra)


@given(st.lists(st.floats(0.0, 1e6, width=32), min_size=1, max_size=12))
@settings(max_examples=50, deadline=None)
def test_prop_normalized_weights_guard(weights):
    check_normalized_weights_guard(weights)


@given(st.lists(st.floats(0.1, 1e3, width=32), min_size=1, max_size=8),
       st.floats(0.05, 1.0, width=32))
@settings(max_examples=30, deadline=None)
def test_prop_staleness_monotone(n_list, discount):
    check_staleness_monotone(n_list, discount)


@given(st.integers(1, 6), st.integers(1, 8), st.integers(0, 99))
@settings(max_examples=25, deadline=None)
def test_prop_compress_preserves_mass(cap, n_rows, seed):
    rng = np.random.default_rng(seed)
    rows = rng.normal(size=(n_rows, 32)).astype(np.float32)
    us = rng.uniform(0.1, 5.0, size=n_rows).tolist()
    check_compress_preserves_mass(list(rows), us, cap)


@given(st.sampled_from(sorted(FAMILIES)), st.integers(0, 2),
       st.sampled_from([1, 2, 4, 8]), st.integers(0, 99))
@settings(max_examples=12, deadline=None)
def test_prop_plane_roundtrip(family_name, level, model_size, seed):
    check_plane_roundtrip(family_name, level, model_size, seed)


@given(st.integers(0, 9999), st.integers(1, 5), st.integers(2, 8),
       st.integers(1, 12), st.integers(1, 3),
       st.one_of(st.none(), st.integers(1, 6)))
@settings(max_examples=20, deadline=None)
def test_prop_balanced_sampler_quota(seed, C, classes, batch, steps, m):
    check_balanced_sampler_quota(seed, C, classes, batch, steps, m)


# ---------------------------------------------------- seeded smoke paths
# Executable without hypothesis (the shim skips the @given tests): a few
# seeded draws through the same checkers keep the invariants enforced on
# bare installs and double as known-edge-case regressions.
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_pad_rows_examples(seed):
    rng = np.random.default_rng(seed)
    C = int(rng.integers(2, 7))
    check_pad_rows_fedavg_exact(
        rng.normal(size=(C * 16,)).astype(np.float32),
        rng.uniform(0.0, 10.0, size=C).tolist(), int(rng.integers(0, 8)))


@pytest.mark.parametrize("weights", [[0.0], [0.0, 0.0, 0.0], [3.0, 1.0],
                                     [1e-30, 0.0], [0.0, 7.0, 0.0]])
def test_normalized_weights_examples(weights):
    check_normalized_weights_guard(weights)


@pytest.mark.parametrize("discount", [0.05, 0.6, 1.0])
def test_staleness_examples(discount):
    check_staleness_monotone([1.0, 2.0, 3.0, 4.0], discount)


@pytest.mark.parametrize("cap,n_rows", [(2, 5), (1, 4), (3, 3), (4, 2)])
def test_compress_examples(cap, n_rows):
    rng = np.random.default_rng(cap * 10 + n_rows)
    check_compress_preserves_mass(
        list(rng.normal(size=(n_rows, 64)).astype(np.float32)),
        rng.uniform(0.1, 5.0, size=n_rows).tolist(), cap)


@pytest.mark.parametrize("family_name", sorted(FAMILIES))
@pytest.mark.parametrize("model_size", [1, 2, 8])
def test_plane_roundtrip_examples(family_name, model_size):
    check_plane_roundtrip(family_name, 1, model_size, seed=3)


@pytest.mark.parametrize("seed,m", [(0, None), (1, 2), (2, 4), (3, 1)])
def test_balanced_sampler_examples(seed, m):
    # m=1 and m=2 force narrow tables (< most class populations); m=None
    # lets build_class_table size the table to the largest class
    check_balanced_sampler_quota(seed, C=3, classes=6, batch=8, steps=2, m=m)
