"""Batch sampling, incl. the class-balanced resampling/reweighting of §IV-C
(the master cluster samples ~equal instances per class each round so that
KD does not bias slaves toward the master's frequent classes)."""
from __future__ import annotations

import numpy as np


def sample_batches(x: np.ndarray, y: np.ndarray, batch: int, steps: int,
                   seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(x), (steps, batch))
    return {"x": x[idx], "y": y[idx]}


def class_balanced_batches(x: np.ndarray, y: np.ndarray, batch: int,
                           steps: int, classes: int, seed: int = 0):
    """Each batch draws ⌈batch/classes⌉ per present class (resampling scheme)."""
    rng = np.random.default_rng(seed)
    by_class = [np.where(y == c)[0] for c in range(classes)]
    present = [c for c in range(classes) if len(by_class[c])]
    per = -(-batch // len(present))
    rows = []
    for _ in range(steps):
        picks = []
        for c in present:
            picks.append(rng.choice(by_class[c], per, replace=True))
        row = np.concatenate(picks)[:batch]
        rng.shuffle(row)
        rows.append(row)
    idx = np.stack(rows)
    return {"x": x[idx], "y": y[idx]}


def leave_one_out(x: np.ndarray, y: np.ndarray, leave_class: int):
    """Drop one class from training (the paper's leave-one-out metric)."""
    keep = y != leave_class
    return x[keep], y[keep]
