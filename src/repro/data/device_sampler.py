"""Seeded jax.random batch-index draws for the device-resident dispatch path.

The scan-fused multi-round pipeline draws every member's batch indices
INSIDE the program: one round key folded from (seed, absolute round index),
one batched draw covering the whole padded member axis.  Because the stream
depends only on the absolute round index (never on block boundaries or the
dispatch width R), any two widths are numerically interchangeable — R is an
execution knob, not a semantic one.  The legacy one-round-per-dispatch path
keeps its historical host-side numpy stream; the two streams are
statistically equivalent but distinct.

``balanced_indices`` realizes §IV-C class-balanced resampling as a fixed-
shape draw (round-robin class quotas over each member's present classes,
then a uniform draw within the class) so a whole cluster of members with
heterogeneous class support runs under one program.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def round_key(seed: int, r):
    """PRNG key for one communication round: folds the absolute round index
    only, so draws are invariant to dispatch-block boundaries."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), r)


def uniform_indices(key, steps: int, batch: int, n) -> jnp.ndarray:
    """(C, steps, batch) int32 draws, member i uniform over [0, n[i])."""
    n = jnp.maximum(jnp.asarray(n, jnp.int32), 1)
    return jax.random.randint(key, (n.shape[0], steps, batch), 0,
                              n[:, None, None])


def balanced_indices(key, steps: int, batch: int, tables, counts) -> jnp.ndarray:
    """Class-balanced (C, steps, batch) draws from per-member class tables.

    ``tables``: (C, classes, m) int32 — per member and class, the member's
    sample indices (rows padded arbitrarily past ``counts``); ``counts``:
    (C, classes) int32.  Batch slots are assigned round-robin over each
    member's PRESENT classes (equal ⌈batch/n_present⌉ quotas — the numpy
    resampling scheme; slot order is irrelevant to an averaged loss, so no
    shuffle), then each slot draws uniformly within its class.
    """
    counts = jnp.asarray(counts, jnp.int32)
    C, classes = counts.shape
    present = counts > 0
    n_present = jnp.maximum(jnp.sum(present.astype(jnp.int32), -1), 1)  # (C,)
    # per member: present classes first, in ascending class order
    order = jnp.argsort(jnp.where(present, 0, 1) * classes
                        + jnp.arange(classes)[None, :], axis=-1)
    slot_cls = jnp.arange(batch)[None, :] % n_present[:, None]      # (C, B)
    cls = jnp.take_along_axis(order, slot_cls, axis=1)              # (C, B)
    cnt = jnp.maximum(jnp.take_along_axis(counts, cls, axis=1), 1)  # (C, B)
    inst = jax.random.randint(key, (C, steps, batch), 0, cnt[:, None, :])
    return jax.vmap(lambda t, c, i: t[c[None, :], i])(
        jnp.asarray(tables), cls, inst)


def build_class_table(y: np.ndarray, classes: int, m: int | None = None):
    """Host-side: (classes, m) index table + (classes,) counts for one shard.
    Rows are padded by repeating the class's indices (padding is never drawn:
    the instance draw is bounded by counts)."""
    y = np.asarray(y)
    cols = [np.where(y == c)[0].astype(np.int32) for c in range(classes)]
    counts = np.array([len(c) for c in cols], np.int32)
    m = int(m if m is not None else max(1, counts.max(initial=1)))
    table = np.zeros((classes, m), np.int32)
    for c, col in enumerate(cols):
        if len(col):
            reps = -(-m // len(col))
            table[c] = np.tile(col, reps)[:m]
    return table, counts
