"""Seeded jax.random batch-index draws for the device-resident dispatch path.

The scan-fused multi-round pipeline draws every member's batch indices
INSIDE the program: one round key folded from (seed, absolute round index),
then one key per member folded from the member's GLOBAL slot index.  Because
the stream depends only on (absolute round, global member slot) — never on
block boundaries, the dispatch width R, or how the member axis is sharded
over a mesh — any two widths are numerically interchangeable AND a
mesh-sharded program (each device passing its slice start as ``offset``)
draws bit-identically to the single-device program.  The legacy
one-round-per-dispatch path keeps its historical host-side numpy stream; the
two streams are statistically equivalent but distinct.

``balanced_indices`` realizes §IV-C class-balanced resampling as a fixed-
shape draw (round-robin class quotas over each member's present classes,
then a uniform draw within the class) so a whole cluster of members with
heterogeneous class support runs under one program.
"""
from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp
import numpy as np


def stream_fingerprint(seed: int, r: int, probe: int = 4) -> int:
    """CRC32 of a canonical probe draw for round ``r`` under ``seed``.

    Because every dispatch-path draw is a pure function of (seed, absolute
    round, global member slot), this fingerprint written into a run-state
    checkpoint and recomputed at resume proves the resumed process will
    generate the *same* sampler stream the checkpoint was trained under —
    a changed seed or sampler implementation fails loudly instead of
    silently diverging."""
    idx = uniform_indices(round_key(seed, r), 2, probe,
                          np.full(probe, 1 << 20, np.int32))
    return zlib.crc32(np.asarray(idx, np.int32).tobytes()) & 0xFFFFFFFF


def round_key(seed: int, r):
    """PRNG key for one communication round: folds the absolute round index
    only, so draws are invariant to dispatch-block boundaries."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), r)


def _member_keys(key, C: int, offset) -> jnp.ndarray:
    """One key per member, folded from the GLOBAL member slot index
    ``offset + i``.  Because each member's stream depends only on (round
    key, global slot), a mesh-sharded program — where each device sees a
    contiguous slice of the member axis and passes its slice start as
    ``offset`` — draws bit-identical indices to the unsharded program."""
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.asarray(offset, jnp.int32) + jnp.arange(C, dtype=jnp.int32))


def uniform_indices(key, steps: int, batch: int, n, offset=0) -> jnp.ndarray:
    """(C, steps, batch) int32 draws, member i uniform over [0, n[i]).
    ``offset`` is the members' global slot base (nonzero inside mesh-sharded
    programs)."""
    n = jnp.maximum(jnp.asarray(n, jnp.int32), 1)
    keys = _member_keys(key, n.shape[0], offset)
    return jax.vmap(lambda k, ni: jax.random.randint(
        k, (steps, batch), 0, ni))(keys, n)


def balanced_indices(key, steps: int, batch: int, tables, counts,
                     offset=0) -> jnp.ndarray:
    """Class-balanced (C, steps, batch) draws from per-member class tables.

    ``tables``: (C, classes, m) int32 — per member and class, the member's
    sample indices (rows padded arbitrarily past ``counts``); ``counts``:
    (C, classes) int32.  Batch slots are assigned round-robin over each
    member's PRESENT classes (equal ⌈batch/n_present⌉ quotas — the numpy
    resampling scheme; slot order is irrelevant to an averaged loss, so no
    shuffle), then each slot draws uniformly within its class.  ``offset``
    is the members' global slot base (see ``uniform_indices``).

    The instance draw is clamped to the table width m: a caller that built
    its tables narrower than ``counts.max()`` gets uniform draws over each
    class's first m indices instead of silently-clamped gathers that
    over-weight the last column.
    """
    counts = jnp.asarray(counts, jnp.int32)
    C, classes = counts.shape
    tables = jnp.asarray(tables)
    present = counts > 0
    n_present = jnp.maximum(jnp.sum(present.astype(jnp.int32), -1), 1)  # (C,)
    # per member: present classes first, in ascending class order
    order = jnp.argsort(jnp.where(present, 0, 1) * classes
                        + jnp.arange(classes)[None, :], axis=-1)
    slot_cls = jnp.arange(batch)[None, :] % n_present[:, None]      # (C, B)
    cls = jnp.take_along_axis(order, slot_cls, axis=1)              # (C, B)
    cnt = jnp.maximum(jnp.take_along_axis(counts, cls, axis=1), 1)  # (C, B)
    cnt = jnp.minimum(cnt, tables.shape[-1])
    keys = _member_keys(key, C, offset)
    inst = jax.vmap(lambda k, c: jax.random.randint(
        k, (steps, batch), 0, c[None, :]))(keys, cnt)
    return jax.vmap(lambda t, c, i: t[c[None, :], i])(tables, cls, inst)


def build_class_table(y: np.ndarray, classes: int, m: int | None = None):
    """Host-side: (classes, m) index table + (classes,) counts for one shard.

    Rows shorter than m are padded by repeating the class's indices (padding
    is never drawn: the instance draw is bounded by counts).  Contract for
    narrow tables: m MAY be smaller than ``counts.max()`` — each class row
    then holds its first m sample indices, and ``balanced_indices`` clamps
    its draw bound to m, so the drawn distribution stays uniform over those
    m samples (never skewed toward a repeated last column).  counts is
    returned UNclamped (it still reports true per-class populations)."""
    y = np.asarray(y)
    cols = [np.where(y == c)[0].astype(np.int32) for c in range(classes)]
    counts = np.array([len(c) for c in cols], np.int32)
    m = int(m if m is not None else max(1, counts.max(initial=1)))
    assert m >= 1, f"class table width must be ≥ 1, got {m}"
    table = np.zeros((classes, m), np.int32)
    for c, col in enumerate(cols):
        if len(col):
            reps = -(-m // len(col))
            table[c] = np.tile(col, reps)[:m]
    return table, counts
