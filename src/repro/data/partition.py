"""Federated data partitioner: iid and Dirichlet non-iid splits."""
from __future__ import annotations

import numpy as np


def iid_partition(n_items: int, n_clients: int, seed: int = 0,
                  sizes=None) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n_items)
    if sizes is None:
        return [np.sort(a) for a in np.array_split(idx, n_clients)]
    sizes = np.asarray(sizes)
    assert sizes.sum() <= n_items
    out, pos = [], 0
    for s in sizes:
        out.append(np.sort(idx[pos:pos + s]))
        pos += s
    return out


def dirichlet_partition(labels: np.ndarray, n_clients: int,
                        alpha: float = 0.5, seed: int = 0,
                        min_per_client: int = 8) -> list[np.ndarray]:
    """Label-skew non-iid: per-class Dirichlet proportions across clients."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    shares = [[] for _ in range(n_clients)]
    for c in classes:
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        p = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(p) * len(idx)).astype(int)[:-1]
        for cl, part in enumerate(np.split(idx, cuts)):
            shares[cl].append(part)
    out = [np.sort(np.concatenate(s)) if s else np.array([], int) for s in shares]
    # ensure every client can form a batch
    pool = np.concatenate(out)
    rng.shuffle(pool)
    for i, o in enumerate(out):
        if len(o) < min_per_client:
            extra = pool[: min_per_client - len(o)]
            out[i] = np.sort(np.concatenate([o, extra]))
    return out


def partition_sizes(parts: list[np.ndarray]) -> np.ndarray:
    return np.array([len(p) for p in parts])
