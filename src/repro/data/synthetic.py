"""Offline synthetic datasets (container has no internet; DESIGN.md §7).

* ``make_classification`` — class-prototype images + noise; linearly separable
  enough for the paper's CNN to learn, hard enough that accuracy curves have
  the two-phase shape of Fig. 2.  Stand-ins: synth-mnist (28×28×1, 10c),
  synth-har (9×32×1 sensor windows, 6c), synth-cifar (32×32×3, 10c),
  synth-shl (16×32×1, 8c).
* ``make_lm_corpus`` — order-2 Markov token stream with per-class transition
  structure so next-token loss is learnable by small LMs.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Dataset:
    name: str
    x: np.ndarray        # (N, H, W, C) float32
    y: np.ndarray        # (N,) int32
    classes: int

    def __len__(self):
        return len(self.x)


SPECS = {
    "synth-mnist": ((14, 14, 1), 10),
    "synth-har":   ((9, 16, 1), 6),
    "synth-cifar": ((16, 16, 3), 10),
    "synth-shl":   ((8, 16, 1), 8),
}


def make_classification(name: str, n: int, seed: int = 0,
                        noise: float = 0.35) -> Dataset:
    shape, classes = SPECS[name]
    rng = np.random.default_rng(seed)
    protos = rng.normal(0, 1, (classes,) + shape).astype(np.float32)
    y = rng.integers(0, classes, n).astype(np.int32)
    x = protos[y] + rng.normal(0, noise, (n,) + shape).astype(np.float32)
    # mild per-sample distortions so the task is not trivially nearest-proto
    gains = rng.uniform(0.7, 1.3, (n, 1, 1, 1)).astype(np.float32)
    return Dataset(name, x * gains, y, classes)


def train_test_split(ds: Dataset, test_frac: float = 0.2, seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(ds))
    cut = int(len(ds) * (1 - test_frac))
    tr, te = idx[:cut], idx[cut:]
    return (Dataset(ds.name, ds.x[tr], ds.y[tr], ds.classes),
            Dataset(ds.name, ds.x[te], ds.y[te], ds.classes))


def make_lm_corpus(vocab: int, length: int, seed: int = 0,
                   n_states: int = 8) -> np.ndarray:
    """Markov chain over vocab with low-entropy per-state emissions."""
    rng = np.random.default_rng(seed)
    trans = rng.dirichlet(np.ones(n_states) * 0.3, size=n_states)
    emit = rng.dirichlet(np.ones(vocab) * 0.05, size=n_states)
    toks = np.empty(length, np.int32)
    s = 0
    for i in range(length):
        toks[i] = rng.choice(vocab, p=emit[s])
        s = rng.choice(n_states, p=trans[s])
    return toks


def lm_batches(tokens: np.ndarray, batch: int, seq: int, steps: int,
               seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    starts = rng.integers(0, len(tokens) - seq - 1, (steps, batch))
    return np.stack([[tokens[s:s + seq] for s in row] for row in starts])
