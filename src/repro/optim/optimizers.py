"""Optimizers as (init, update) pairs over parameter pytrees.

``update(grads, state, params, lr)`` -> (new_params, new_state).  AdamW is
the dry-run/train-step optimizer (moments in fp32, ZeRO-1-shardable); SGD /
momentum serve the FL clients (the paper trains clients with SGD).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    if max_norm <= 0:
        return grads
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (g + 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), grads)


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        new = jax.tree.map(lambda w, g: w - lr * g.astype(w.dtype), params, grads)
        return new, state

    return Optimizer(init, update)


def momentum(beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda w: jnp.zeros_like(w, jnp.float32), params)

    def update(grads, state, params, lr):
        new_m = jax.tree.map(lambda m, g: beta * m + g.astype(jnp.float32),
                             state, grads)
        new_p = jax.tree.map(lambda w, m: w - (lr * m).astype(w.dtype),
                             params, new_m)
        return new_p, new_m

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(lambda w: jnp.zeros(w.shape, jnp.float32), params),
            "v": jax.tree.map(lambda w: jnp.zeros(w.shape, jnp.float32), params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(w, m_, v_):
            step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            return w - (lr * (step + weight_decay * w.astype(jnp.float32))).astype(w.dtype)

        return (jax.tree.map(upd, params, m, v),
                {"m": m, "v": v, "t": t})

    return Optimizer(init, update)


def get(name: str, **kw) -> Optimizer:
    return {"sgd": sgd, "momentum": momentum, "adamw": adamw}[name](**kw)
