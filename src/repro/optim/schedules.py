"""Learning-rate schedules: constant, cosine, and MiniCPM's WSD
(Warmup-Stable-Decay) [arXiv:2404.06395 §4]."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine(lr: float, total_steps: int, warmup: int = 0, min_frac: float = 0.1):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        w = jnp.clip(step / jnp.maximum(warmup, 1), 0, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1), 0, 1)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return lr * jnp.where(step < warmup, w, cos)
    return f


def wsd(lr: float, total_steps: int, warmup_frac: float = 0.1,
        decay_frac: float = 0.1, min_frac: float = 0.1):
    """Warmup → Stable (flat lr) → exponential Decay over the last
    decay_frac of training."""
    warmup = max(1, int(total_steps * warmup_frac))
    decay_start = int(total_steps * (1 - decay_frac))

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        w = jnp.clip(step / warmup, 0, 1)
        d_prog = jnp.clip((step - decay_start)
                          / jnp.maximum(total_steps - decay_start, 1), 0, 1)
        decay = min_frac ** d_prog              # exponential anneal to min_frac
        val = jnp.where(step < warmup, w,
                        jnp.where(step < decay_start, 1.0, decay))
        return lr * val
    return f


def get(name: str, lr: float, total_steps: int, warmup: int = 0):
    if name == "constant":
        return constant(lr)
    if name == "cosine":
        return cosine(lr, total_steps, warmup)
    if name == "wsd":
        return wsd(lr, total_steps, warmup_frac=warmup / max(total_steps, 1) or 0.1)
    raise ValueError(name)
