"""Validate observability artifacts (the CI metrics-smoke gate).

Checks, in order:

1. **metrics JSONL schema** — every line is a JSON object with a known
   ``kind`` (counter/gauge/histogram/table/row) and the per-kind required
   fields; every ``row`` names a previously declared table and carries
   exactly that table's columns.
2. **trace schema + coverage** — the trace file is loadable Chrome-trace
   JSON and (when ``--coverage-root`` is given) the union of spans nested
   inside the root covers at least ``--min-coverage`` of its duration.
3. **summary parity** (``--report report.json``) — per-round bytes /
   violations / banked / flushed / dropped totals recomputed from the
   JSONL table rows reproduce ``SimReport.summary()`` exactly.

Exit 0 on success; prints the first failure and exits 1 otherwise.

Usage::

    python -m repro.obs.validate --metrics metrics.jsonl \
        --trace trace.json --coverage-root sim.run \
        --report report.json
"""
from __future__ import annotations

import argparse
import json
import sys

from .trace import span_coverage

_SCALAR_KINDS = {"counter", "gauge", "histogram"}


def validate_metrics_jsonl(path) -> dict:
    """Parse + schema-check a metrics JSONL file.

    Returns ``{"lines": n, "counters": {...}, "gauges": {...},
    "tables": {name: [row, ...]}, "dropped": {name: n}}``.
    """
    counters, gauges, tables, dropped = {}, {}, {}, {}
    schemas = {}
    n = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            n += 1
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{lineno}: not JSON: {e}") from None
            if not isinstance(rec, dict) or "kind" not in rec:
                raise ValueError(f"{path}:{lineno}: missing 'kind'")
            kind = rec["kind"]
            if kind in _SCALAR_KINDS:
                if "name" not in rec:
                    raise ValueError(f"{path}:{lineno}: {kind} without name")
                if kind == "counter":
                    counters[rec["name"]] = rec["value"]
                elif kind == "gauge":
                    gauges[rec["name"]] = rec["value"]
            elif kind == "table":
                schemas[rec["name"]] = set(rec["columns"])
                tables.setdefault(rec["name"], [])
                dropped[rec["name"]] = int(rec.get("dropped", 0))
            elif kind == "row":
                t = rec.get("table")
                if t not in schemas:
                    raise ValueError(
                        f"{path}:{lineno}: row for undeclared table {t!r}")
                got = set(rec) - {"kind", "table"}
                if got != schemas[t]:
                    raise ValueError(
                        f"{path}:{lineno}: row columns {sorted(got)} != "
                        f"declared {sorted(schemas[t])}")
                tables[t].append(rec)
            else:
                raise ValueError(f"{path}:{lineno}: unknown kind {kind!r}")
    return {"lines": n, "counters": counters, "gauges": gauges,
            "tables": tables, "dropped": dropped}


def validate_trace(path, *, coverage_root=None, min_coverage=0.95) -> dict:
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError(f"{path}: no traceEvents list")
    for i, e in enumerate(events):
        if not isinstance(e, dict) or "ph" not in e or "name" not in e:
            raise ValueError(f"{path}: event {i} missing ph/name")
        if e["ph"] == "X" and ("ts" not in e or "dur" not in e):
            raise ValueError(f"{path}: span {e['name']!r} missing ts/dur")
    out = {"events": len(events)}
    if coverage_root is not None:
        cov = span_coverage(events, coverage_root)
        out["coverage"] = cov
        if cov < min_coverage:
            raise ValueError(
                f"{path}: spans cover {cov:.1%} of {coverage_root!r}, "
                f"need >= {min_coverage:.0%}")
    return out


def check_summary_parity(metrics: dict, report_path) -> dict:
    """Totals recomputed from the JSONL cluster-round rows must reproduce
    the engine's ``SimReport.summary()`` exactly (same floats: the export
    round-trips float64 through repr)."""
    with open(report_path) as f:
        summary = json.load(f)
    if "summary" in summary:            # allow a full to_dict() report file
        summary = summary["summary"]
    rows = metrics["tables"].get("sim/cluster_rounds")
    if rows is None:
        raise ValueError("metrics JSONL has no sim/cluster_rounds table")
    if metrics["dropped"].get("sim/cluster_rounds"):
        raise ValueError("sim/cluster_rounds ring wrapped; totals would be "
                         "partial — raise the table max_rows for this run")
    totals = {
        "total_bytes": sum(r["bytes"] for r in rows),
        "mar_violations": sum(r["violations"] for r in rows),
        "banked_total": sum(r["banked"] for r in rows),
        "flushed_total": sum(r["flushed"] for r in rows),
        "dropped_total": sum(r["dropped"] for r in rows),
    }
    for k, v in totals.items():
        if k not in summary:
            raise ValueError(f"report summary missing {k!r}")
        if summary[k] != v:
            raise ValueError(
                f"parity mismatch on {k}: metrics={v!r} report={summary[k]!r}")
    return totals


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.obs.validate",
        description="Validate metrics JSONL / trace JSON artifacts")
    ap.add_argument("--metrics", help="metrics JSONL path")
    ap.add_argument("--trace", help="Chrome-trace JSON path")
    ap.add_argument("--coverage-root", default=None,
                    help="span name whose children must cover the run "
                         "(e.g. sim.run)")
    ap.add_argument("--min-coverage", type=float, default=0.95)
    ap.add_argument("--report", default=None,
                    help="SimReport summary/to_dict JSON to check parity "
                         "against (requires --metrics)")
    args = ap.parse_args(argv)
    if not args.metrics and not args.trace:
        ap.error("nothing to validate: pass --metrics and/or --trace")
    try:
        if args.metrics:
            m = validate_metrics_jsonl(args.metrics)
            print(f"metrics ok: {m['lines']} lines, "
                  f"{len(m['counters'])} counters, "
                  f"{len(m['tables'])} tables")
            if args.report:
                totals = check_summary_parity(m, args.report)
                print("summary parity ok: " +
                      ", ".join(f"{k}={v}" for k, v in totals.items()))
        if args.trace:
            t = validate_trace(args.trace, coverage_root=args.coverage_root,
                               min_coverage=args.min_coverage)
            cov = (f", coverage {t['coverage']:.1%}"
                   if "coverage" in t else "")
            print(f"trace ok: {t['events']} events{cov}")
    except (ValueError, OSError) as e:
        print(f"FAIL: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
