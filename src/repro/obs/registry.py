"""Low-overhead metrics registry: counters, gauges, histograms, and
struct-of-arrays ring-buffer tables with numpy columnar export.

This is the engine's telemetry sink.  ``SimReport`` keeps its public API but
derives its numeric ``summary()`` from the registry's columnar tables
instead of Python-object iteration, so per-participant/round metrics scale
past per-event list appends (the ROADMAP item-1 fleet-simulator blocker).

Design constraints:

* **Append cost is O(1) numpy scalar stores** — a ``Table`` preallocates one
  numpy column per field, doubles capacity up to ``max_rows``, then wraps as
  a ring (overwritten rows are COUNTED in ``dropped`` and surfaced in every
  export — no silent truncation).
* **No jax dependency** — the registry is importable from host-only tooling
  (CI validators, benchmark harnesses) without touching a backend.
* **Exact export** — ``to_jsonl`` writes float64 values through Python's
  ``repr`` round-trip, so sums recomputed from the JSONL reproduce sums over
  the live columns bit-exactly (the summary-parity contract the CI smoke
  step checks).
"""
from __future__ import annotations

import json
import math

import numpy as np

# default histogram bounds: exponential decades covering µs..hours (seconds)
# and bytes..GBs equally well
_DEFAULT_BOUNDS = tuple(10.0 ** e for e in range(-7, 11))


class Counter:
    """Monotone float counter."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    """Last-value-wins float gauge (NaN until first set)."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = float("nan")

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bound histogram: per-bucket counts plus count/sum/min/max."""
    __slots__ = ("bounds", "buckets", "count", "total", "min", "max")

    def __init__(self, bounds=_DEFAULT_BOUNDS):
        self.bounds = tuple(float(b) for b in bounds)
        self.buckets = np.zeros(len(self.bounds) + 1, np.int64)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.buckets[int(np.searchsorted(self.bounds, v, side="left"))] += 1
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def summary(self) -> dict:
        return {"count": self.count, "sum": self.total,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None,
                "buckets": [[("inf" if i == len(self.bounds)
                              else self.bounds[i]), int(n)]
                            for i, n in enumerate(self.buckets.tolist())
                            if n]}


class Table:
    """Struct-of-arrays ring buffer: one preallocated numpy column per
    field.  Appends are scalar stores; reads return columnar numpy views in
    insertion order (oldest retained row first).  Beyond ``max_rows`` the
    buffer wraps and ``dropped`` counts the overwritten rows."""

    def __init__(self, name: str, columns: dict, *, capacity: int = 256,
                 max_rows: int = 1 << 20, defaults: dict | None = None):
        self.name = name
        self._defaults = dict(defaults or {})
        cap = max(1, min(capacity, max_rows))
        self._cols = {c: np.zeros(cap, dt) for c, dt in columns.items()}
        self._cap = cap
        self._max = max(1, max_rows)
        self._n = 0               # total rows ever appended (monotone)
        self.dropped = 0          # rows overwritten after the ring wrapped

    def __len__(self) -> int:
        return min(self._n, self._cap)

    @property
    def columns(self) -> tuple:
        return tuple(self._cols)

    def append(self, **vals) -> None:
        i = self._n
        if i >= self._cap and self._cap < self._max:
            new_cap = min(self._cap * 2, self._max)
            self._cols = {c: np.concatenate(
                [col, np.zeros(new_cap - self._cap, col.dtype)])
                for c, col in self._cols.items()}
            self._cap = new_cap
        slot = i % self._cap
        if i >= self._cap:
            self.dropped += 1
        dflt = self._defaults
        for c, col in self._cols.items():
            col[slot] = vals.get(c, dflt.get(c, 0))
        self._n = i + 1

    def column(self, name: str) -> np.ndarray:
        """One column, insertion-ordered (oldest retained first)."""
        col, n = self._cols[name], self._n
        if n <= self._cap:
            return col[:n]
        s = n % self._cap
        return np.concatenate([col[s:], col[:s]])

    def rows(self):
        cols = {c: self.column(c) for c in self._cols}
        for i in range(len(self)):
            yield {c: v[i].item() for c, v in cols.items()}

    def reset(self) -> None:
        """Drop all retained rows (capacity is kept).  Used by owners whose
        lifetime is one run (e.g. ``SimReport``) when they re-claim a table
        from a shared registry, so exports never mix two runs' rows."""
        self._n = 0
        self.dropped = 0

    def state(self) -> tuple[dict, dict]:
        """(JSON-safe meta, {column: ndarray}) snapshot — the retained rows
        in insertion order plus the ring counters, so ``load_state`` restores
        ``column()``/``dropped``/``_n`` bit-exactly."""
        meta = {"n": int(self._n), "cap": int(self._cap),
                "max": int(self._max), "dropped": int(self.dropped)}
        return meta, {c: self.column(c).copy() for c in self._cols}

    def load_state(self, meta: dict, columns: dict) -> None:
        """Inverse of ``state``: rebuilds the ring in place (object identity
        is preserved — holders like ``SimReport`` keep their reference)."""
        self._cap = int(meta["cap"])
        self._max = int(meta["max"])
        self._n = int(meta["n"])
        self.dropped = int(meta["dropped"])
        length = min(self._n, self._cap)
        cols = {}
        for c, arr in columns.items():
            col = np.zeros(self._cap, arr.dtype)
            if length:
                # i-th oldest retained row lives at slot (n - length + i)
                idx = (np.arange(length) + self._n - length) % self._cap
                col[idx] = arr[:length]
            cols[c] = col
        self._cols = cols

    def bump_last(self, col: str, delta, match: dict | None = None) -> bool:
        """In-place add ``delta`` to ``col`` of the newest retained row
        matching ``match`` (column -> value); returns False when no row
        matches.  The post-run edit hook (terminal bank flushes land in the
        final round's already-appended row)."""
        n = len(self)
        for back in range(1, n + 1):
            slot = (self._n - back) % self._cap
            if all(self._cols[c][slot] == v for c, v in (match or {}).items()):
                self._cols[col][slot] += delta
                return True
        return False


class MetricsRegistry:
    """Get-or-create namespace of counters, gauges, histograms and tables."""

    def __init__(self):
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}
        self.tables: dict[str, Table] = {}

    # ------------------------------------------------------------ factories
    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        return g

    def histogram(self, name: str, bounds=_DEFAULT_BOUNDS) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(bounds)
        return h

    def table(self, name: str, columns: dict | None = None, **kw) -> Table:
        t = self.tables.get(name)
        if t is None:
            if columns is None:
                raise KeyError(f"table {name!r} does not exist yet and no "
                               "column schema was given")
            t = self.tables[name] = Table(name, columns, **kw)
        return t

    # ------------------------------------------------------------ checkpoint
    def state(self) -> tuple[dict, dict]:
        """(meta, arrays) for the whole registry: counters/gauges/histogram
        scalars in ``meta`` (non-finite floats survive — this feeds our own
        JSON reader, not strict exporters), bucket counts and table columns
        in ``arrays`` under ``hist/<name>/buckets`` and
        ``table/<name>/<column>`` keys."""
        meta = {
            "counters": {k: c.value for k, c in sorted(self.counters.items())},
            "gauges": {k: g.value for k, g in sorted(self.gauges.items())},
            "histograms": {k: {"bounds": list(h.bounds), "count": h.count,
                               "total": h.total, "min": h.min, "max": h.max}
                           for k, h in sorted(self.histograms.items())},
            "tables": {},
        }
        arrays = {}
        for k, h in sorted(self.histograms.items()):
            arrays[f"hist/{k}/buckets"] = h.buckets.copy()
        for k, t in sorted(self.tables.items()):
            t_meta, t_cols = t.state()
            t_meta["columns"] = list(t.columns)
            meta["tables"][k] = t_meta
            for c, arr in t_cols.items():
                arrays[f"table/{k}/{c}"] = arr
        return meta, arrays

    def load_state(self, meta: dict, arrays: dict) -> None:
        """Inverse of ``state``.  Existing metric objects are updated in
        place (shared holders keep their references); missing ones are
        created.  Restored compile/transfer counters keep counting from the
        checkpointed totals — a resumed process recompiles, so those exceed
        an uninterrupted run's; the per-round *tables* are what resume
        bit-exactly."""
        for k, v in meta.get("counters", {}).items():
            self.counter(k).value = float(v)
        for k, v in meta.get("gauges", {}).items():
            self.gauge(k).value = float(v)
        for k, hm in meta.get("histograms", {}).items():
            h = self.histogram(k, bounds=tuple(hm["bounds"]))
            h.bounds = tuple(float(b) for b in hm["bounds"])
            h.buckets = np.asarray(arrays[f"hist/{k}/buckets"],
                                   np.int64).copy()
            h.count = int(hm["count"])
            h.total = float(hm["total"])
            h.min = float(hm["min"])
            h.max = float(hm["max"])
        for k, tm in meta.get("tables", {}).items():
            cols = {c: arrays[f"table/{k}/{c}"] for c in tm["columns"]}
            t = self.tables.get(k)
            if t is None:
                t = self.tables[k] = Table(
                    k, {c: arr.dtype for c, arr in cols.items()})
            t.load_state(tm, cols)

    # ------------------------------------------------------------ export
    def snapshot(self) -> dict:
        """JSON-ready point-in-time view (the serve.py /metrics payload)."""
        return {
            "counters": {k: c.value for k, c in sorted(self.counters.items())},
            "gauges": {k: g.value for k, g in sorted(self.gauges.items())},
            "histograms": {k: h.summary()
                           for k, h in sorted(self.histograms.items())},
            "tables": {k: {"rows": len(t), "dropped": t.dropped,
                           "columns": list(t.columns)}
                       for k, t in sorted(self.tables.items())},
        }

    def render_text(self) -> str:
        """Prometheus-style text exposition of the scalar metrics."""
        lines = []
        for k, c in sorted(self.counters.items()):
            lines.append(f"# TYPE {_prom_name(k)} counter")
            lines.append(f"{_prom_name(k)} {c.value:.17g}")
        for k, g in sorted(self.gauges.items()):
            lines.append(f"# TYPE {_prom_name(k)} gauge")
            lines.append(f"{_prom_name(k)} {g.value:.17g}")
        for k, h in sorted(self.histograms.items()):
            n = _prom_name(k)
            lines.append(f"# TYPE {n} histogram")
            cum = 0
            for le, cnt in h.summary()["buckets"]:
                cum += cnt
                le_txt = "+Inf" if le == "inf" else f"{le:g}"
                lines.append(f'{n}_bucket{{le="{le_txt}"}} {cum}')
            lines.append(f"{n}_sum {h.total:.17g}")
            lines.append(f"{n}_count {h.count}")
        return "\n".join(lines) + "\n"

    def to_jsonl(self, path) -> int:
        """Write the whole registry as JSON Lines; returns the line count.

        Line kinds: ``counter`` / ``gauge`` / ``histogram`` scalar records,
        one ``row`` record per retained table row (with its table name), and
        a ``table`` meta record per table (schema + dropped-row count, so a
        wrapped ring is never mistaken for full history)."""
        n = 0
        with open(path, "w") as f:
            for k, c in sorted(self.counters.items()):
                f.write(json.dumps({"kind": "counter", "name": k,
                                    "value": c.value}) + "\n")
                n += 1
            for k, g in sorted(self.gauges.items()):
                f.write(json.dumps({"kind": "gauge", "name": k,
                                    "value": _json_float(g.value)}) + "\n")
                n += 1
            for k, h in sorted(self.histograms.items()):
                f.write(json.dumps({"kind": "histogram", "name": k,
                                    **h.summary()}) + "\n")
                n += 1
            for k, t in sorted(self.tables.items()):
                f.write(json.dumps({"kind": "table", "name": k,
                                    "columns": list(t.columns),
                                    "rows": len(t),
                                    "dropped": t.dropped}) + "\n")
                n += 1
                for row in t.rows():
                    f.write(json.dumps(
                        {"kind": "row", "table": k,
                         **{c: _json_float(v) for c, v in row.items()}})
                        + "\n")
                    n += 1
        return n


def _prom_name(name: str) -> str:
    out = "".join(ch if (ch.isalnum() or ch == "_") else "_" for ch in name)
    return out if not out[:1].isdigit() else "_" + out


def _json_float(v):
    """JSON has no NaN/inf literals; export them as null (validators treat
    null as 'not measured')."""
    if isinstance(v, float) and not math.isfinite(v):
        return None
    return v
