"""Structured observability: metrics registry + round-pipeline tracing.

Everything downstream (engine, FedRAC, CLIs, benchmarks) takes an
``Observability`` bundle.  ``NULL_OBS`` is the disabled singleton whose
tracer spans and registry lookups cost one branch — safe to thread through
hot loops unconditionally.
"""
from __future__ import annotations

from .registry import (Counter, Gauge, Histogram, MetricsRegistry, Table)
from .trace import (NULL_TRACER, NullTracer, Tracer, span_coverage)


class Observability:
    """Bundle of a metrics registry and a tracer.  ``on`` gates the
    instrumented slow paths at call sites with a single branch."""
    __slots__ = ("registry", "tracer", "on")

    def __init__(self, registry=None, tracer=None, *, on=True):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.on = on


NULL_OBS = Observability(registry=MetricsRegistry(), tracer=NULL_TRACER,
                         on=False)


def make_observability(*, trace: bool = True, fence: bool = False
                       ) -> Observability:
    """Fresh enabled bundle; ``fence=True`` turns on ``block_until_ready``
    span fencing (honest device timings, serialized pipeline)."""
    return Observability(MetricsRegistry(),
                         Tracer(fence=fence) if trace else NULL_TRACER,
                         on=True)


__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Table",
    "Tracer", "NullTracer", "NULL_TRACER", "span_coverage",
    "Observability", "NULL_OBS", "make_observability",
]
