"""Span-based tracing of the round pipeline, exported as Chrome-trace JSON
(loadable in ``chrome://tracing`` and Perfetto).

The tracer is deliberately tiny: a span is one appended tuple on exit, and
call sites hold a tracer reference that defaults to ``NULL_TRACER`` — whose
``span()`` returns a shared no-op context manager, so the disabled fast
path costs a single attribute lookup + two empty calls per span.

**Fencing.**  jax dispatch is asynchronous: a span closing right after a
jitted call measures *submission*, not execution.  ``Tracer(fence=True)``
makes ``tracer.fence(x)`` call ``jax.block_until_ready`` on ``x`` so span
timings are honest on device, at the cost of serializing the pipeline —
opt-in, off by default, and a no-op identity on the null tracer.
"""
from __future__ import annotations

import json
import time


class _Span:
    __slots__ = ("_tracer", "name", "cat", "args", "_t0")

    def __init__(self, tracer, name, cat, args):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        self._tracer._events.append(
            (self.name, self.cat, self._t0, t1 - self._t0, self.args))
        return False


class _NullSpan:
    """Shared no-op span: the single-branch disabled fast path."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: every op is a no-op, ``fence`` is identity."""
    __slots__ = ()
    enabled = False
    fencing = False

    def span(self, name, cat="sim", **args):
        return _NULL_SPAN

    def instant(self, name, cat="sim", **args):
        pass

    def complete(self, name, t0_ns, dur_ns, cat="sim", **args):
        pass

    def fence(self, value):
        return value

    def events(self):
        return []


NULL_TRACER = NullTracer()


class Tracer:
    """Recording tracer.  ``span(name)`` is a context manager; nesting is
    implied by interval containment (all spans are synchronous on one
    host thread, so Chrome/Perfetto reconstruct the stack from overlap)."""
    __slots__ = ("_events", "_origin_ns", "fencing")
    enabled = True

    def __init__(self, fence: bool = False):
        self._events = []          # (name, cat, t0_ns, dur_ns, args|None)
        self._origin_ns = time.perf_counter_ns()
        self.fencing = fence

    def span(self, name, cat="sim", **args):
        return _Span(self, name, cat, args or None)

    def instant(self, name, cat="sim", **args):
        self._events.append(
            (name, cat, time.perf_counter_ns(), 0, args or None))

    def complete(self, name, t0_ns, dur_ns, cat="sim", **args):
        """Record a span retroactively from caller-measured timestamps
        (``time.perf_counter_ns()``) — used where a context manager can't
        wrap the timed region, e.g. lazily-detected XLA compiles."""
        self._events.append((name, cat, t0_ns, dur_ns, args or None))

    def fence(self, value):
        """Block until ``value`` (any jax pytree) is computed when fencing
        is enabled — call inside a span to make its duration cover device
        execution, not just dispatch."""
        if self.fencing and value is not None:
            import jax
            jax.block_until_ready(value)
        return value

    # ------------------------------------------------------------ export
    def events(self) -> list[dict]:
        """Chrome-trace event dicts (ts/dur in µs from the tracer origin)."""
        o = self._origin_ns
        out = []
        for name, cat, t0, dur, args in sorted(self._events,
                                               key=lambda e: e[2]):
            ev = {"name": name, "cat": cat, "ph": "X",
                  "ts": (t0 - o) / 1e3, "dur": dur / 1e3,
                  "pid": 0, "tid": 0}
            if args:
                ev["args"] = {k: _jsonable(v) for k, v in args.items()}
            out.append(ev)
        return out

    def to_chrome(self) -> dict:
        meta = [{"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
                 "args": {"name": "fedrac"}}]
        return {"traceEvents": meta + self.events(),
                "displayTimeUnit": "ms"}

    def write(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)


def _jsonable(v):
    if isinstance(v, (int, float, str, bool)) or v is None:
        return v
    return str(v)


def span_coverage(events: list[dict], root: str) -> float:
    """Fraction of the ``root`` span's duration covered by the union of the
    other spans nested inside it (nesting = interval containment, so doubly
    counted children collapse in the union).  Used by the validator to
    assert the trace accounts for ≥95% of measured wall-clock."""
    roots = [e for e in events
             if e.get("ph") == "X" and e["name"] == root]
    if not roots:
        raise ValueError(f"no {root!r} span in trace")
    r = roots[0]
    lo, hi = r["ts"], r["ts"] + r["dur"]
    if r["dur"] <= 0:
        return 1.0
    ivals = sorted((max(e["ts"], lo), min(e["ts"] + e["dur"], hi))
                   for e in events
                   if e.get("ph") == "X" and e is not r
                   and e["ts"] >= lo and e["ts"] + e["dur"] <= hi)
    covered, cur_lo, cur_hi = 0.0, None, None
    for a, b in ivals:
        if cur_lo is None:
            cur_lo, cur_hi = a, b
        elif a <= cur_hi:
            cur_hi = max(cur_hi, b)
        else:
            covered += cur_hi - cur_lo
            cur_lo, cur_hi = a, b
    if cur_lo is not None:
        covered += cur_hi - cur_lo
    return covered / r["dur"]
