"""Baselines re-implemented for fair comparison (§V-B):

  * FedAvg  [McMahan et al., AISTATS'17] — single global model sized for the
    weakest participant (the paper runs the smallest slave model on all 40).
  * FedProx [Li et al., MLSys'20] — FedAvg + proximal term μ/2·||w - w_g||².
  * Oort    [Lai et al., OSDI'21] — guided participant selection by
    statistical utility × system-speed penalty.
  * HeteroFL[Diao et al., ICLR'21] — width-sliced submodels per client
    capacity; server aggregates overlapping slices.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, cost_model
from repro.core.client import local_update
from repro.core.resources import Participant
from repro.data.sampler import sample_batches
from repro.models import cnn


@dataclass
class BaselineConfig:
    rounds: int = 20
    lr: float = 0.05
    local_batch: int = 16
    steps_per_round: int = 4
    seed: int = 0
    prox_mu: float = 0.001       # FedProx
    oort_frac: float = 0.5       # fraction of clients per round
    oort_alpha: float = 2.0      # system-utility exponent
    alpha: float = 0.5           # HeteroFL width ratio per level


def _eval(loss_fn, params, test):
    _, logits = loss_fn(params, test)
    return float(jnp.mean((jnp.argmax(logits, -1) == test["y"])))


def _run_rounds(loss_fn, params, parts, client_data, test, cfg: BaselineConfig,
                *, prox_mu: float = 0.0, select=None):
    upd = jax.jit(lambda p, b, g: local_update(
        loss_fn, p, b, cfg.lr, prox_mu=prox_mu, global_params=g))
    history = []
    losses = {p.pid: 1.0 for p in parts}
    for r in range(cfg.rounds):
        chosen = select(parts, losses, r) if select else parts
        stack, ws = [], []
        for p in chosen:
            d = client_data[p.pid]
            batches = jax.tree.map(jnp.asarray, sample_batches(
                d["x"], d["y"], cfg.local_batch, cfg.steps_per_round,
                seed=cfg.seed + 977 * p.pid + r))
            p_new, l = upd(params, batches, params)
            losses[p.pid] = float(l)
            stack.append(p_new)
            ws.append(len(d["x"]))
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *stack)
        params = aggregation.aggregate(stacked, aggregation.normalized_weights(ws))
        history.append(_eval(loss_fn, params, test))
    return params, history


def fedavg(loss_fn, init_params, parts, client_data, test, cfg: BaselineConfig):
    return _run_rounds(loss_fn, init_params, parts, client_data, test, cfg)


def fedprox(loss_fn, init_params, parts, client_data, test, cfg: BaselineConfig):
    return _run_rounds(loss_fn, init_params, parts, client_data, test, cfg,
                       prox_mu=cfg.prox_mu)


def oort(loss_fn, init_params, parts, client_data, test, cfg: BaselineConfig,
         flops_per_sample: float, model_bytes: float, mar: float = 60.0):
    k = max(1, int(len(parts) * cfg.oort_frac))

    def select(ps, losses, r):
        utils = []
        for p in ps:
            stat = len(client_data[p.pid]["x"]) ** 0.5 * (losses[p.pid] + 1e-3)
            t = cost_model.round_time(p, flops_per_sample, model_bytes, 1,
                                      cfg.local_batch * cfg.steps_per_round)
            sys_u = 1.0 if t <= mar else (mar / t) ** cfg.oort_alpha
            utils.append(stat * sys_u)
        order = np.argsort(-np.asarray(utils))
        # ε-greedy exploration as in Oort
        rng = np.random.default_rng(cfg.seed + r)
        n_exploit = max(1, int(0.8 * k))
        chosen = list(order[:n_exploit])
        rest = [i for i in order[n_exploit:]]
        if rest and k - n_exploit > 0:
            chosen += list(rng.choice(rest, min(k - n_exploit, len(rest)),
                                      replace=False))
        return [ps[i] for i in chosen]

    return _run_rounds(loss_fn, init_params, parts, client_data, test, cfg,
                       select=select)


# ------------------------------------------------------------------ HeteroFL
def _slice_like(full, small):
    """Take the leading-corner slice of ``full`` matching ``small``'s shape."""
    slices = tuple(slice(0, s) for s in small.shape)
    return full[slices]


def heterofl(parts, client_data, client_levels, test, cfg: BaselineConfig,
             *, in_channels: int, classes: int, levels: int,
             base_width: float = 0.125):
    """CNN-family HeteroFL: client at level ℓ trains the α^ℓ-width slice."""
    key = jax.random.PRNGKey(cfg.seed)
    global_params = cnn.init_params(key, in_channels=in_channels,
                                    classes=classes, alpha=1.0, level=0,
                                    base_width=base_width)
    sub_templates = [cnn.init_params(key, in_channels=in_channels,
                                     classes=classes, alpha=cfg.alpha, level=l,
                                     base_width=base_width)
                     for l in range(levels)]
    loss_fn = jax.tree_util.Partial(lambda p, b: (cnn.loss_fn(p, b)[0],
                                                  cnn.forward(p, b["x"])))
    upds = [jax.jit(lambda p, b: local_update(loss_fn, p, b, cfg.lr))
            for _ in range(levels)]

    history = []
    for r in range(cfg.rounds):
        acc = jax.tree.map(lambda x: np.zeros_like(np.asarray(x)), global_params)
        cnt = jax.tree.map(lambda x: np.zeros(np.asarray(x).shape), global_params)
        for p in parts:
            lvl = client_levels[p.pid]
            sub = jax.tree.map(_slice_like, global_params, sub_templates[lvl])
            d = client_data[p.pid]
            batches = jax.tree.map(jnp.asarray, sample_batches(
                d["x"], d["y"], cfg.local_batch, cfg.steps_per_round,
                seed=cfg.seed + 977 * p.pid + r))
            sub_new, _ = upds[lvl](sub, batches)
            flat_acc = jax.tree.leaves(acc)
            flat_cnt = jax.tree.leaves(cnt)
            for i, leaf in enumerate(jax.tree.leaves(sub_new)):
                a = np.asarray(leaf)
                sl = tuple(slice(0, s) for s in a.shape)
                flat_acc[i][sl] += a
                flat_cnt[i][sl] += 1
        tdef = jax.tree.structure(global_params)
        flat_g = jax.tree.leaves(global_params)
        new_leaves = []
        for g, a, c in zip(flat_g, jax.tree.leaves(acc), jax.tree.leaves(cnt)):
            g_np = np.asarray(g)
            new_leaves.append(jnp.asarray(np.where(c > 0, a / np.maximum(c, 1), g_np)))
        global_params = jax.tree_util.tree_unflatten(tdef, new_leaves)
        history.append(_eval(loss_fn, global_params, test))
    return global_params, history
