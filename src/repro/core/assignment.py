"""Participant assignment to clusters — Procedure 2 (§IV-B3).

Each participant is tried against clusters from the highest (master) down.
Case 1 (empty cluster): only the precision check q_o ≤ δ applies (err ≡ 0 for
a single participant).  Case 2: both q_o ≤ δ and err ≤ θ.  If the participant
cannot run M_f within the cluster's MAR, τ_i and n_i are reduced; if precision
would break, it demotes to the next cluster.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import cost_model, rounds
from repro.core.resources import Participant


@dataclass
class ClusterSpec:
    level: int                    # 0 = master
    model_bytes: float
    flops_per_sample: float
    E: int                        # local epochs E_f
    R: int                        # communication rounds R_f (Eq. 7)
    delta: float                  # precision threshold δ_f
    theta: float                  # error threshold θ_f
    mar: float                    # MAR time budget T_f for this cluster
    batch_size: int = 32


@dataclass
class Assignment:
    members: dict = field(default_factory=dict)     # level -> [pid]
    n_eff: dict = field(default_factory=dict)       # pid -> adjusted n_i
    tau: dict = field(default_factory=dict)         # pid -> adjusted τ_i
    demotions: int = 0
    diagnostics: list = field(default_factory=list)


def _tau(E: int, n: int, B: int) -> int:
    return max(1, (E * n) // B)


def _try_place(p: Participant, c: ClusterSpec,
               consts: rounds.ConvergenceConstants, eta: float,
               n_cur: list, tau_cur: list, diagnostics: list):
    """Procedure 2's per-cluster check (Case 1/2 + τ/n reduction).
    Returns the admitted n_i, or None (→ demote to the next cluster)."""
    if not cost_model.can_accommodate(p, c.model_bytes):
        diagnostics.append((p.pid, c.level, "memory"))
        return None
    n_i = p.n_data
    for _ in range(16):
        t = cost_model.round_time(p, c.flops_per_sample, c.model_bytes,
                                  c.E, n_i)
        if t > c.mar:
            n_i = max(1, int(n_i * 0.8))
            continue
        taus = tau_cur + [_tau(c.E, n_i, c.batch_size)]
        ns = np.array(n_cur + [n_i], dtype=np.float64)
        eps = ns / ns.sum()
        q = rounds.precision_bound(eps, c.E, c.R, consts)
        if q > c.delta:
            n_i = max(1, int(n_i * 0.8))
            if n_i == 1:
                return None
            continue
        if len(ns) > 1:
            err = rounds.optimization_error(eps, taus, eta, c.R, consts)
            if err > c.theta:
                return None                  # heterogeneity too high: demote
        return n_i
    return None


def assign(parts: list[Participant], clusters: list[ClusterSpec],
           consts: rounds.ConvergenceConstants,
           eta: float = 0.01) -> Assignment:
    out = Assignment(members={c.level: [] for c in clusters})
    n_cur = {c.level: [] for c in clusters}          # current members' n_i
    tau_cur = {c.level: [] for c in clusters}

    for p in parts:
        placed = False
        for c in clusters:
            n_i = _try_place(p, c, consts, eta, n_cur[c.level],
                             tau_cur[c.level], out.diagnostics)
            if n_i is not None:
                out.members[c.level].append(p.pid)
                out.n_eff[p.pid] = n_i
                out.tau[p.pid] = _tau(c.E, n_i, c.batch_size)
                n_cur[c.level].append(n_i)
                tau_cur[c.level].append(out.tau[p.pid])
                placed = True
                break
            out.demotions += 1
        if not placed:
            # last resort: smallest cluster with minimum data (paper §IV-A:
            # "sets batch-size and local epochs to continue the training")
            c = clusters[-1]
            out.members[c.level].append(p.pid)
            out.n_eff[p.pid] = max(1, p.n_data // 4)
            out.tau[p.pid] = _tau(c.E, out.n_eff[p.pid], c.batch_size)
            out.diagnostics.append((p.pid, c.level, "forced"))
    return out


def reassign(p: Participant, current: Assignment,
             clusters: list[ClusterSpec],
             consts: rounds.ConvergenceConstants,
             eta: float = 0.01) -> tuple[int | None, int]:
    """§IV-A dynamic resources: a participant whose (s, r, a) changed is
    re-evaluated against every cluster top-down and upgraded / downgraded
    in place.  Returns (old_level, new_level)."""
    old_level = None
    for lvl, mem in current.members.items():
        if p.pid in mem:
            old_level = lvl
            mem.remove(p.pid)
            break
    for c in clusters:
        n_cur = [current.n_eff[q] for q in current.members[c.level]]
        tau_cur = [current.tau[q] for q in current.members[c.level]]
        n_i = _try_place(p, c, consts, eta, n_cur, tau_cur,
                         current.diagnostics)
        if n_i is not None:
            current.members[c.level].append(p.pid)
            current.n_eff[p.pid] = n_i
            current.tau[p.pid] = _tau(c.E, n_i, c.batch_size)
            return old_level, c.level
    # smallest cluster with reduced data, as in assign()
    c = clusters[-1]
    current.members[c.level].append(p.pid)
    current.n_eff[p.pid] = max(1, p.n_data // 4)
    current.tau[p.pid] = _tau(c.E, current.n_eff[p.pid], c.batch_size)
    current.diagnostics.append((p.pid, c.level, "forced-dynamic"))
    return old_level, c.level


def reassign_by_centroids(V: np.ndarray, clustering,
                          level_of_cluster: np.ndarray | None = None
                          ) -> np.ndarray:
    """Procedure 2 at fleet scale: re-place (changed) participants with ONE
    argmin over the setup-time cluster centroids.

    ``clustering`` is a ``FleetClusteringResult`` — its frozen (lo, span, λ)
    map raw resource rows into the same normalized √λ-scaled space the
    centroids live in, so a drifted participant lands in whichever resource
    tier it now resembles, without replaying the per-cluster admission loop
    (τ/n adjustments happen lazily when the cluster next prices a round).
    ``level_of_cluster`` optionally maps centroid index → cluster level
    (after ``order_clusters_by_resources``-style relabeling); identity when
    omitted.  Returns one level per row of ``V``.
    """
    from repro.core.clustering import nearest_centroid
    V = np.atleast_2d(np.asarray(V, np.float64))
    Xw = ((V - clustering.lo) / clustering.span) * np.sqrt(clustering.lam)
    lab = nearest_centroid(Xw, clustering.centroids)
    if level_of_cluster is not None:
        lab = np.asarray(level_of_cluster)[lab]
    return lab


def build_cluster_specs(model_family_sizes: list[tuple[float, float]],
                        consts: rounds.ConvergenceConstants,
                        *, E: int = 5, q_target: float = 0.05,
                        delta: float | None = None, theta: float = 50.0,
                        mar: float = 600.0, kappa: float = 0.7,
                        batch_size: int = 32,
                        expected_F: int = 8) -> list["ClusterSpec"]:
    """Convenience: one spec per cluster level from (bytes, flops/sample).

    R_f comes from Eq. 7 with B evaluated at a uniform expected membership, so
    the Eq. 6 precision at (E, R_f) lands at ≈ q_target by construction; the
    default threshold δ = 1.25·q_target then admits participants unless their
    addition worsens B, and the real gates are memory / MAR / err (Eq. 8) —
    exactly Procedure 2's resource-driven stratification.
    MAR per level follows T_{f-1} = κ T_f (§IV-C).
    """
    m = len(model_family_sizes)
    eps_u = np.full(expected_F, 1.0 / expected_F)
    B = rounds.b_constant(eps_u, E, consts)
    R = rounds.communication_rounds(q_target, E, consts, B=B)
    delta = 1.25 * q_target if delta is None else delta
    specs = []
    for lvl, (mb, fl) in enumerate(model_family_sizes):
        specs.append(ClusterSpec(
            level=lvl, model_bytes=mb, flops_per_sample=fl, E=E, R=R,
            delta=delta, theta=theta, mar=mar * (kappa ** (m - 1 - lvl)),
            batch_size=batch_size))
    return specs
