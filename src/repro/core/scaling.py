"""α-compression of model configs → the per-cluster generic-model family
(§IV-A2: M_f = α^{f-1} M).

The paper compresses only the conv layers of its CNN; the transformer
analogue compresses the FFN width (and expert count for MoE) by α per cluster
level, keeping d_model / attention dims intact so master and slave logits are
directly KD-compatible.  Widths round to multiples of 128 (MXU alignment) —
or 16 below 256 — so compressed configs stay mesh-divisible.
"""
from __future__ import annotations

import math

from repro.configs.base import ModelConfig


def _round_mult(x: int, mult: int) -> int:
    return max(mult, int(round(x / mult)) * mult)


def compress_config(cfg: ModelConfig, alpha: float, level: int) -> ModelConfig:
    """Cluster C_{level} model: widths scaled by α^level (level 0 = master)."""
    if level == 0:
        return cfg
    s = alpha ** level
    kw = {"name": f"{cfg.name}-L{level}"}
    if cfg.d_ff:
        mult = 128 if cfg.d_ff * s >= 256 else 16
        kw["d_ff"] = _round_mult(int(cfg.d_ff * s), mult)
    if cfg.n_experts:
        kw["n_experts"] = max(cfg.experts_per_tok, int(round(cfg.n_experts * s)))
    if cfg.family == "ssm":   # xLSTM: compress the block expansion
        kw["mlstm_expand"] = cfg.mlstm_expand     # expansion ratio kept;
        # depth-preserving family: compress the sLSTM projection factor
        kw["slstm_proj"] = max(1.0, cfg.slstm_proj * s)
    c = cfg.replace(**kw)
    c.validate()
    return c


def model_family(cfg: ModelConfig, alpha: float, m: int) -> list[ModelConfig]:
    """[M_1, ..., M_m] with M_1 = M (the server's model)."""
    return [compress_config(cfg, alpha, lvl) for lvl in range(m)]


# ------------------------------------------------------- analytic size/flops
def param_count(cfg: ModelConfig) -> int:
    d, V = cfg.d_model, cfg.padded_vocab
    n = V * d                                   # embed
    if not cfg.tie_embeddings:
        n += V * d
    per_pos = []
    for j, kind in enumerate(cfg.block_pattern):
        c = 0
        if kind in ("attn", "attn_local"):
            c += d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
        elif kind == "mamba":
            di, st, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
            c += d * 2 * di + cfg.ssm_conv * di + di * (dtr + 2 * st) \
                + dtr * di + di * st + 2 * di + di * d
        elif kind == "mlstm":
            di = cfg.mlstm_expand * d
            c += d * 2 * di + 4 * di + di * di * 3 + di * 2 * cfg.n_heads + di * d + di
        elif kind == "slstm":
            hd = d // cfg.n_heads
            pf = -(-int(cfg.slstm_proj * d) // 128) * 128
            c += d * 4 * d + cfg.n_heads * hd * 4 * hd + 4 * d + 2 * d * pf + pf * d
        fk = cfg.ffn_kind(j)
        if fk == "dense":
            c += 3 * d * cfg.d_ff
        elif fk == "moe":
            c += d * cfg.n_experts + cfg.n_experts * 3 * d * cfg.d_ff
        per_pos.append(c)
    n += cfg.n_superblocks * sum(per_pos)
    if cfg.family == "encdec":
        enc = cfg.n_enc_layers * (d * cfg.q_dim + 2 * d * cfg.kv_dim
                                  + cfg.q_dim * d + 3 * d * cfg.d_ff)
        dec_cross = cfg.n_layers * (d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d)
        n += enc + dec_cross
    return int(n)


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top-k of E experts)."""
    if not cfg.n_experts:
        return param_count(cfg)
    full = param_count(cfg)
    moe_positions = sum(1 for j in range(cfg.period) if cfg.ffn_kind(j) == "moe")
    expert_p = cfg.n_superblocks * moe_positions * cfg.n_experts * 3 * cfg.d_model * cfg.d_ff
    active_e = cfg.n_superblocks * moe_positions * cfg.experts_per_tok * 3 * cfg.d_model * cfg.d_ff
    return int(full - expert_p + active_e)


def model_bytes(cfg: ModelConfig) -> int:
    bpp = 2 if cfg.dtype == "bfloat16" else 4
    return param_count(cfg) * bpp


def flops_per_token_train(cfg: ModelConfig, seq_len: int) -> float:
    """6·N_active·(1) + attention term (quadratic part) per token."""
    base = 6.0 * active_param_count(cfg)
    attn_layers = sum(1 for k in cfg.block_pattern if k.startswith("attn"))
    attn_layers = cfg.n_superblocks * attn_layers
    attn = 12.0 * attn_layers * cfg.head_dim * cfg.n_heads * seq_len / 2
    return base + attn


def analytic_step_flops(cfg: ModelConfig, kind: str, global_batch: int,
                        seq_len: int, remat: bool = False) -> float:
    """Whole-step analytic FLOPs (cross-check for the HLO numbers, which on
    the CPU backend do not multiply while-loop trip counts)."""
    if kind == "train":
        f = flops_per_token_train(cfg, seq_len) * global_batch * seq_len
        return f * (4 / 3) if remat else f          # fwd recompute in bwd
    if kind == "prefill":
        return flops_per_token_train(cfg, seq_len) / 3.0 * global_batch * seq_len
    # decode: one token; attention reads the whole cache
    base = 2.0 * active_param_count(cfg) * global_batch
    attn_layers = cfg.n_superblocks * sum(
        1 for k in cfg.block_pattern if k.startswith("attn"))
    attn = 4.0 * attn_layers * cfg.n_heads * cfg.head_dim * seq_len * global_batch
    return base + attn
