"""Cluster compaction (§IV-A2): merge the k Dunn-optimal clusters into m < k
so every cluster has enough participants, avoiding both the over-compression
of deep cluster levels and the straggler effect.

Merging policy: clusters are ordered by descending resources; we repeatedly
merge the *most similar adjacent pair* (smallest centroid distance) — the
merged cluster adopts the LOWER level's model (its weakest member must still
accommodate it).
"""
from __future__ import annotations

import numpy as np


def compact(labels: np.ndarray, V: np.ndarray, m: int) -> np.ndarray:
    """labels: resource-ordered cluster ids (0 = highest resources).
    Returns new labels in 0..m-1, still resource-ordered."""
    labels = labels.copy()
    k = len(np.unique(labels))
    assert m <= k, (m, k)
    while k > m:
        ks = np.unique(labels)
        cents = np.stack([V[labels == f].mean(axis=0) for f in ks])
        # adjacent pairs in resource order
        dists = np.linalg.norm(cents[1:] - cents[:-1], axis=1)
        j = int(np.argmin(dists))              # merge ks[j] and ks[j+1]
        labels[labels == ks[j + 1]] = ks[j]
        # re-densify labels to 0..k-2 preserving order
        ks2 = np.unique(labels)
        remap = {int(old): i for i, old in enumerate(ks2)}
        labels = np.array([remap[int(l)] for l in labels])
        k -= 1
    return labels
