"""Communication rounds and optimization error per cluster (§IV-B, Eq. 6-8).

Derived under Assumptions 1-4 (L-smooth, μ-strongly-convex, bounded gradient
variance σ², bounded gradient norm G²) from the FedAvg convergence bound
[Li et al., ICLR'20], and Assumption 5 (h1, h2) from the objective-
inconsistency analysis [Wang et al., NeurIPS'20].
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class ConvergenceConstants:
    L: float = 1.5          # smoothness
    mu: float = 0.7         # strong convexity
    sigma: float = 1.0      # grad variance bound σ_f
    G: float = 1.0          # grad norm bound G_f
    h1: float = 1.0         # Assumption 5
    h2: float = 0.5
    w_dist_sq: float = 0.0064   # E||w_1 - w*||^2  (Example 3: 0.08^2)


def b_constant(eps_weights, E: int, c: ConvergenceConstants) -> float:
    """B = Σ ε_j² σ² + 8(E-1)² G²  (below Eq. 6)."""
    eps = np.asarray(eps_weights, dtype=np.float64)
    return float(np.sum(eps ** 2) * c.sigma ** 2 + 8 * (E - 1) ** 2 * c.G ** 2)


def beta(E: int, c: ConvergenceConstants) -> float:
    return max(8 * c.L / c.mu, float(E))


def precision_bound(eps_weights, E: int, R: int, c: ConvergenceConstants,
                    B: float | None = None) -> float:
    """Eq. 6 RHS: upper bound on E[L(w^R)] - L*  with T_f = R·E total local steps."""
    B = b_constant(eps_weights, E, c) if B is None else B
    bt = beta(E, c)
    T = R * E
    return (c.L / (2 * c.mu ** 2)) / (bt + T - 1) * (4 * B + c.mu ** 2 * bt * c.w_dist_sq)


def communication_rounds(q_o: float, E: int, c: ConvergenceConstants,
                         B: float = 1.0) -> int:
    """Eq. 7: rounds R_f needed for target precision q_o at E local epochs."""
    bt = beta(E, c)
    R = (1.0 / E) * ((c.L / (2 * c.mu ** 2 * q_o)) *
                     (4 * B + c.mu ** 2 * bt * c.w_dist_sq) + 1 - bt)
    return max(1, math.ceil(R))


def optimization_error(eps_weights, taus, eta: float, R: int,
                       c: ConvergenceConstants, loss_gap: float = 1.0) -> float:
    """Eq. 8 upper bound on min_t E||∇L̄(w̄^t)||² for FedAvg-style accumulation
    (o_j = 1^{τ_j}, so ||o||₁=τ, ||o||₂²=τ, o_last=1).

    A single participant (F=1) has zero heterogeneity error by definition
    (§IV-B3 Case 1) — the h2 (dissimilarity) term vanishes.
    """
    eps = np.asarray(eps_weights, dtype=np.float64)
    taus = np.asarray(taus, dtype=np.float64)
    F = len(eps)
    if F <= 1:
        return 0.0
    tau_e = float(np.mean(taus))
    b1 = loss_gap
    b2 = F * tau_e * float(np.sum(eps ** 2 / taus))
    b3 = float(np.sum(eps * (taus - 1.0)))
    b4 = float(np.max(taus * (taus - 1.0)))
    return (4 * b1 / (eta * tau_e * R)
            + 4 * eta * c.L * c.sigma ** 2 * b2 / F
            + 6 * eta ** 2 * c.L ** 2 * c.sigma ** 2 * b3
            + 12 * eta ** 2 * c.L ** 2 * c.h2 ** 2 * b4)


def example3_constants() -> ConvergenceConstants:
    """Paper Example 3: μ=0.7, L=1.5, B=1, E||w1-w*||=0.08, E_f=20 → R_f=6
    (with q_o = 0.05, the upper end of the paper's L* ∈ [0.01,0.05])."""
    return ConvergenceConstants(L=1.5, mu=0.7, w_dist_sq=0.08 ** 2)
