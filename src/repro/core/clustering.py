"""Resource-aware clustering: jnp k-means + Dunn index + Procedure 1,
plus DBSCAN / OPTICS alternatives evaluated in the paper's Table II.

k-means runs in jnp (jit-able, multi-restart); Dunn uses the λ-weighted
similarity matrix per Eq. 3-5.  DBSCAN/OPTICS are one-shot server-side
setup computations and run in numpy.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.resources import similarity_matrix, unit_normalize


# ------------------------------------------------------------------ k-means
def _lloyd(X, centers, iters=50):
    """Lloyd iterations from given initial centers (jit/vmap-able)."""
    k = centers.shape[0]

    def step(centers, _):
        d = jnp.linalg.norm(X[:, None] - centers[None], axis=-1)
        lab = jnp.argmin(d, axis=1)
        oh = jax.nn.one_hot(lab, k)                       # (n,k)
        cnt = oh.sum(0)
        new = (oh.T @ X) / jnp.maximum(cnt, 1)[:, None]
        new = jnp.where(cnt[:, None] > 0, new, centers)
        return new, None

    centers, _ = jax.lax.scan(step, centers, None, length=iters)
    d = jnp.linalg.norm(X[:, None] - centers[None], axis=-1)
    lab = jnp.argmin(d, axis=1)
    inertia = jnp.sum(jnp.min(d, axis=1) ** 2)
    return lab, centers, inertia


def _kmeanspp_init(X: np.ndarray, k: int, rng) -> np.ndarray:
    """Seeded k-means++ seeding (D² sampling) on the host."""
    n = len(X)
    centers = [X[rng.integers(n)]]
    for _ in range(k - 1):
        d2 = np.min([((X - c) ** 2).sum(1) for c in centers], axis=0)
        total = d2.sum()
        pick = rng.choice(n, p=d2 / total) if total > 0 else rng.integers(n)
        centers.append(X[pick])
    return np.stack(centers)


def kmeans(X: np.ndarray, k: int, seed: int = 0, restarts: int = 8):
    """Multi-restart Lloyd's with k-means++ seeding; returns (labels, centers).

    Uniform-random seeding collapses Table I's smallest cluster into its
    neighbour often enough that Procedure 1 lands on k=2; D² seeding keeps
    the paper's partitions (Table I k=3, Table IV k=4/5) reachable at the
    seeds the anchors pin down.
    """
    Xn = np.asarray(X, np.float64)
    rng = np.random.default_rng(seed)
    inits = jnp.asarray(np.stack([_kmeanspp_init(Xn, k, rng)
                                  for _ in range(restarts)]))
    Xj = jnp.asarray(X)
    labs, cents, inert = jax.vmap(lambda c0: _lloyd(Xj, c0))(inits)
    best = int(jnp.argmin(inert))
    return np.asarray(labs[best]), np.asarray(cents[best])


# ------------------------------------------------------------------ Dunn
def dunn_index(S: np.ndarray, labels: np.ndarray) -> float:
    """Eq. 5: min over cluster pairs of dist(Cf,Cg) / max_f dia(Cf).

    dist = min inter-cluster pairwise similarity-distance (Eq. 3);
    dia  = centroid-based cluster diameter (Eq. 4): twice the RMS distance
    of members to the cluster mean, recovered from pairwise distances via
    the identity Σ_i ||x_i − c||² = Σ_ij d_ij² / (2n).

    The max-pairwise diameter convention lets one outlier pair dominate
    every dia(Cf) and systematically favours k=2 (it scored Table I's k=2
    above the paper's k=3); the centroid form matches the paper's reported
    optima on Tables I and IV.
    """
    ks = np.unique(labels)
    if len(ks) < 2:
        return 0.0
    dia = 0.0
    for f in ks:
        m = labels == f
        n = int(m.sum())
        if n >= 2:
            sq = float((S[np.ix_(m, m)] ** 2).sum())
            dia = max(dia, 2.0 * math.sqrt(sq / (2.0 * n * n)))
    if dia == 0.0:
        return 0.0
    dmin = np.inf
    for i, f in enumerate(ks):
        for g in ks[i + 1:]:
            mf, mg = labels == f, labels == g
            dmin = min(dmin, float(S[np.ix_(mf, mg)].min()))
    return float(dmin / dia)


@dataclass
class ClusteringResult:
    k: int
    labels: np.ndarray
    di_values: dict          # k -> Dunn index
    normalized: np.ndarray   # the normalized resource matrix used


def optimal_clusters(V: np.ndarray, lam=(1 / 3, 1 / 3, 1 / 3), *,
                     normalize: bool = True, seed: int = 0,
                     k_max: int | None = None, method: str = "kmeans",
                     restarts: int = 8) -> ClusteringResult:
    """Procedure 1: sweep k = 2..⌊√N⌋, pick argmax Dunn index."""
    N = V.shape[0]
    Vb = unit_normalize(V) if normalize else V.astype(np.float64)
    # similarity uses λ-weights; k-means operates on √λ-scaled coords so its
    # Euclidean metric matches S_ij exactly.
    lam_a = np.asarray(lam)
    Xw = Vb * np.sqrt(lam_a)
    S = similarity_matrix(Vb, lam)
    k_max = k_max or int(math.floor(math.sqrt(N)))
    di, labs = {}, {}
    for k in range(2, k_max + 1):
        if method == "kmeans":
            lab, _ = kmeans(Xw, k, seed=seed, restarts=restarts)
        elif method == "dbscan":
            lab = dbscan_at_k(Xw, k)
        elif method == "optics":
            lab = optics_at_k(Xw, k)
        else:
            raise ValueError(method)
        di[k] = dunn_index(S, lab) if lab is not None else 0.0
        labs[k] = lab
    # argmax DI; exact ties (a k+1 partition that only splits off a singleton
    # keeps both dist and dia) break toward FEWER clusters — Procedure 1
    # prefers the coarsest partition that attains the optimum.
    best = min(di, key=lambda k: (-di[k], k))
    return ClusteringResult(best, labs[best], di, Vb)


def order_clusters_by_resources(V: np.ndarray, labels: np.ndarray,
                                lam=None) -> np.ndarray:
    """Relabel clusters so C_0 has the HIGHEST mean resources (master first,
    §IV-A2: clusters arranged in descending order of available resources,
    under the same λ weighting as the similarity metric).  ``lam=None``
    weighs the resource axes equally (the pre-λ behaviour)."""
    ks = np.unique(labels)
    lam_a = (np.full(V.shape[1], 1.0 / V.shape[1]) if lam is None
             else np.asarray(lam, np.float64))
    score = np.array([(V[labels == f] * lam_a).sum(axis=1).mean() for f in ks])
    order = ks[np.argsort(-score)]
    remap = {int(old): new for new, old in enumerate(order)}
    return np.array([remap[int(l)] for l in labels])


# ------------------------------------------------------------------ DBSCAN
def dbscan(X: np.ndarray, eps: float, min_pts: int = 3) -> np.ndarray:
    n = len(X)
    D = np.linalg.norm(X[:, None] - X[None], axis=-1)
    labels = np.full(n, -1)
    cid = 0
    for i in range(n):
        if labels[i] != -1:
            continue
        nbrs = np.where(D[i] <= eps)[0]
        if len(nbrs) < min_pts:
            continue
        labels[i] = cid
        stack = list(nbrs)
        while stack:
            j = stack.pop()
            if labels[j] == -1:
                labels[j] = cid
                nb2 = np.where(D[j] <= eps)[0]
                if len(nb2) >= min_pts:
                    stack.extend([q for q in nb2 if labels[q] == -1])
        cid += 1
    # assign noise points to nearest cluster (all participants must train)
    if cid > 0:
        for i in np.where(labels == -1)[0]:
            labels[i] = labels[np.argmin(np.where(labels >= 0, D[i], np.inf))]
    return labels


def dbscan_at_k(X: np.ndarray, k: int, min_pts: int = 3):
    """Binary-search eps to produce exactly k clusters (how the paper's
    Table II evaluates DBSCAN at each k); None if unreachable."""
    lo, hi = 1e-4, float(np.linalg.norm(X.max(0) - X.min(0))) + 1e-3
    best = None
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        lab = dbscan(X, mid, min_pts)
        kk = len(np.unique(lab))
        if kk == k:
            best = lab
            break
        if kk < k:      # too few clusters -> shrink eps
            hi = mid
        else:
            lo = mid
    return best


# ------------------------------------------------------------------ OPTICS
def optics_order(X: np.ndarray, min_pts: int = 3):
    n = len(X)
    D = np.linalg.norm(X[:, None] - X[None], axis=-1)
    core = np.sort(D, axis=1)[:, min_pts - 1]
    reach = np.full(n, np.inf)
    seen = np.zeros(n, bool)
    order = []
    for start in range(n):
        if seen[start]:
            continue
        seeds = {start: np.inf}
        while seeds:
            i = min(seeds, key=seeds.get)
            del seeds[i]
            if seen[i]:
                continue
            seen[i] = True
            order.append(i)
            for j in range(n):
                if seen[j]:
                    continue
                nr = max(core[i], D[i, j])
                if nr < reach[j]:
                    reach[j] = nr
                    seeds[j] = nr
    return np.array(order), reach


def optics_at_k(X: np.ndarray, k: int, min_pts: int = 3):
    """Cut the OPTICS reachability plot at the (k-1) largest peaks."""
    order, reach = optics_order(X, min_pts)
    r = reach[order]
    r[0] = 0.0
    if k <= 1:
        return np.zeros(len(X), int)
    cut_positions = np.sort(np.argsort(-r[1:])[:k - 1] + 1)
    labels = np.zeros(len(X), int)
    cid = 0
    pos = 0
    for c in list(cut_positions) + [len(X)]:
        labels[order[pos:c]] = cid
        cid += 1
        pos = c
    return np.clip(labels, 0, k - 1)
