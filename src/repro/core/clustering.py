"""Resource-aware clustering: jnp k-means + Dunn index + Procedure 1,
plus DBSCAN / OPTICS alternatives evaluated in the paper's Table II.

k-means runs in jnp (jit-able, multi-restart); Dunn uses the λ-weighted
similarity matrix per Eq. 3-5.  DBSCAN/OPTICS are one-shot server-side
setup computations and run in numpy.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.resources import similarity_matrix, unit_normalize


# ------------------------------------------------------------------ k-means
def _lloyd(X, centers, iters=50):
    """Lloyd iterations from given initial centers (jit/vmap-able)."""
    k = centers.shape[0]

    def step(centers, _):
        d = jnp.linalg.norm(X[:, None] - centers[None], axis=-1)
        lab = jnp.argmin(d, axis=1)
        oh = jax.nn.one_hot(lab, k)                       # (n,k)
        cnt = oh.sum(0)
        new = (oh.T @ X) / jnp.maximum(cnt, 1)[:, None]
        new = jnp.where(cnt[:, None] > 0, new, centers)
        return new, None

    centers, _ = jax.lax.scan(step, centers, None, length=iters)
    d = jnp.linalg.norm(X[:, None] - centers[None], axis=-1)
    lab = jnp.argmin(d, axis=1)
    inertia = jnp.sum(jnp.min(d, axis=1) ** 2)
    return lab, centers, inertia


def _kmeanspp_init(X: np.ndarray, k: int, rng) -> np.ndarray:
    """Seeded k-means++ seeding (D² sampling) on the host."""
    n = len(X)
    centers = [X[rng.integers(n)]]
    for _ in range(k - 1):
        d2 = np.min([((X - c) ** 2).sum(1) for c in centers], axis=0)
        total = d2.sum()
        pick = rng.choice(n, p=d2 / total) if total > 0 else rng.integers(n)
        centers.append(X[pick])
    return np.stack(centers)


def kmeans(X: np.ndarray, k: int, seed: int = 0, restarts: int = 8):
    """Multi-restart Lloyd's with k-means++ seeding; returns (labels, centers).

    Uniform-random seeding collapses Table I's smallest cluster into its
    neighbour often enough that Procedure 1 lands on k=2; D² seeding keeps
    the paper's partitions (Table I k=3, Table IV k=4/5) reachable at the
    seeds the anchors pin down.
    """
    Xn = np.asarray(X, np.float64)
    rng = np.random.default_rng(seed)
    inits = jnp.asarray(np.stack([_kmeanspp_init(Xn, k, rng)
                                  for _ in range(restarts)]))
    Xj = jnp.asarray(X)
    labs, cents, inert = jax.vmap(lambda c0: _lloyd(Xj, c0))(inits)
    best = int(jnp.argmin(inert))
    return np.asarray(labs[best]), np.asarray(cents[best])


# ------------------------------------------------------------------ Dunn
def dunn_index(S: np.ndarray, labels: np.ndarray) -> float:
    """Eq. 5: min over cluster pairs of dist(Cf,Cg) / max_f dia(Cf).

    dist = min inter-cluster pairwise similarity-distance (Eq. 3);
    dia  = centroid-based cluster diameter (Eq. 4): twice the RMS distance
    of members to the cluster mean, recovered from pairwise distances via
    the identity Σ_i ||x_i − c||² = Σ_ij d_ij² / (2n).

    The max-pairwise diameter convention lets one outlier pair dominate
    every dia(Cf) and systematically favours k=2 (it scored Table I's k=2
    above the paper's k=3); the centroid form matches the paper's reported
    optima on Tables I and IV.
    """
    ks = np.unique(labels)
    if len(ks) < 2:
        return 0.0
    dia = 0.0
    for f in ks:
        m = labels == f
        n = int(m.sum())
        if n >= 2:
            sq = float((S[np.ix_(m, m)] ** 2).sum())
            dia = max(dia, 2.0 * math.sqrt(sq / (2.0 * n * n)))
    if dia == 0.0:
        return 0.0
    dmin = np.inf
    for i, f in enumerate(ks):
        for g in ks[i + 1:]:
            mf, mg = labels == f, labels == g
            dmin = min(dmin, float(S[np.ix_(mf, mg)].min()))
    return float(dmin / dia)


def nearest_centroid(X: np.ndarray, centers: np.ndarray) -> np.ndarray:
    """Labels by one argmin over centroids — squared-norm expansion
    (gemm + two rank-1 broadcasts), never an (n, k, d) temp."""
    X = np.asarray(X, np.float64)
    C = np.asarray(centers, np.float64)
    d2 = ((X * X).sum(1)[:, None] + (C * C).sum(1)[None, :]
          - 2.0 * (X @ C.T))
    return np.argmin(d2, axis=1)


def sampled_dunn_index(X: np.ndarray, labels: np.ndarray, *,
                       sample: int = 1024, seed: int = 0) -> float:
    """Eq. 5 estimated from coordinates — the fleet-scale Dunn path.

    Works on the √λ-scaled coords (where Euclidean distance equals the
    λ-weighted similarity metric) so the n×n similarity matrix is never
    materialized.  Diameters are EXACT in O(n·d): the centroid form of
    Eq. 4 is dia = 2·sqrt(Σ_i ||x_i − c||² / n) by the identity
    Σ_ij d_ij² = 2n Σ_i ||x_i − c||².  The inter-cluster minimum (Eq. 3)
    is estimated from ≤``sample`` uniformly drawn members per cluster,
    pairwise per cluster pair via squared-norm expansion — so the estimate
    can only MISS the true minimum: sampled Dunn ≥ exact Dunn, with
    equality when every cluster fits inside ``sample`` (property-tested).
    """
    X = np.asarray(X, np.float64)
    labels = np.asarray(labels)
    ks = np.unique(labels)
    if len(ks) < 2:
        return 0.0
    rng = np.random.default_rng(seed)
    dia = 0.0
    picks = []
    for f in ks:
        idx = np.flatnonzero(labels == f)
        if len(idx) >= 2:
            c = X[idx].mean(axis=0)
            dia = max(dia, 2.0 * math.sqrt(
                float(((X[idx] - c) ** 2).sum(1).mean())))
        picks.append(idx if len(idx) <= sample
                     else rng.choice(idx, size=sample, replace=False))
    if dia == 0.0:
        return 0.0
    dmin2 = np.inf
    for i in range(len(ks)):
        A = X[picks[i]]
        aa = (A * A).sum(1)
        for j in range(i + 1, len(ks)):
            B = X[picks[j]]
            d2 = aa[:, None] + (B * B).sum(1)[None, :] - 2.0 * (A @ B.T)
            dmin2 = min(dmin2, max(float(d2.min()), 0.0))
    return float(math.sqrt(dmin2) / dia)


@dataclass
class ClusteringResult:
    k: int
    labels: np.ndarray
    di_values: dict          # k -> Dunn index
    normalized: np.ndarray   # the normalized resource matrix used


@dataclass
class FleetClusteringResult:
    """Procedure-1 output at fleet scale.  Carries the cluster centroids and
    the frozen normalization (lo, span) so later drift re-placement is one
    ``nearest_centroid`` call in the same coordinate space (vectorized
    Procedure 2, see ``core.assignment.reassign_by_centroids``)."""
    k: int
    labels: np.ndarray       # (n,) int
    centroids: np.ndarray    # (k, 3) in √λ-scaled normalized coords
    di_values: dict          # k -> sampled Dunn index
    lo: np.ndarray           # (3,) per-column normalization offset
    span: np.ndarray         # (3,) per-column normalization scale
    lam: np.ndarray          # (3,) λ weights


def fleet_optimal_clusters(V: np.ndarray, lam=(1 / 3, 1 / 3, 1 / 3), *,
                           seed: int = 0, k_cap: int = 8,
                           train_sample: int = 4096,
                           dunn_sample: int = 1024,
                           restarts: int = 8) -> FleetClusteringResult:
    """Procedure 1 for 10⁴–10⁶ participants: no O(n²) array, no full-fleet
    Lloyd.  k-means fits on a ≤``train_sample`` uniform subsample (Lloyd's
    centroids are means — a few thousand points pin them to the same basins
    as the full fleet), full-fleet labels come from one ``nearest_centroid``
    argmin, and each k is scored with ``sampled_dunn_index``.  The k-sweep
    is capped at ``k_cap`` (⌊√N⌋ at N=10⁶ would sweep to 1000 — the paper's
    fleets never warrant more than a handful of resource tiers).

    With ``train_sample``/``dunn_sample`` ≥ n this reduces to the exact
    ``optimal_clusters`` path (same seeding, same restarts, same tiebreak),
    which is how the Table I/IV anchors validate it.
    """
    V = np.asarray(V, np.float64)
    N = len(V)
    lam_a = np.asarray(lam, np.float64)
    lo, hi = V.min(axis=0), V.max(axis=0)
    span = np.where(hi > lo, hi - lo, 1.0)
    Xw = ((V - lo) / span) * np.sqrt(lam_a)
    k_max = min(k_cap, int(math.floor(math.sqrt(N))))
    if k_max < 2:
        return FleetClusteringResult(1, np.zeros(N, np.int64),
                                     Xw.mean(0, keepdims=True),
                                     {}, lo, span, lam_a)
    rng = np.random.default_rng(seed)
    Xfit = (Xw if N <= train_sample
            else Xw[rng.choice(N, train_sample, replace=False)])
    di, labs, cents = {}, {}, {}
    for k in range(2, k_max + 1):
        _, centers = kmeans(Xfit, k, seed=seed, restarts=restarts)
        lab = nearest_centroid(Xw, centers)
        di[k] = sampled_dunn_index(Xw, lab, sample=dunn_sample, seed=seed)
        labs[k] = lab
        cents[k] = centers
    best = min(di, key=lambda k: (-di[k], k))
    return FleetClusteringResult(best, labs[best], cents[best], di,
                                 lo, span, lam_a)


def optimal_clusters(V: np.ndarray, lam=(1 / 3, 1 / 3, 1 / 3), *,
                     normalize: bool = True, seed: int = 0,
                     k_max: int | None = None, method: str = "kmeans",
                     restarts: int = 8) -> ClusteringResult:
    """Procedure 1: sweep k = 2..⌊√N⌋, pick argmax Dunn index."""
    N = V.shape[0]
    Vb = unit_normalize(V) if normalize else V.astype(np.float64)
    # similarity uses λ-weights; k-means operates on √λ-scaled coords so its
    # Euclidean metric matches S_ij exactly.
    lam_a = np.asarray(lam)
    Xw = Vb * np.sqrt(lam_a)
    S = similarity_matrix(Vb, lam)
    k_max = k_max or int(math.floor(math.sqrt(N)))
    di, labs = {}, {}
    for k in range(2, k_max + 1):
        if method == "kmeans":
            lab, _ = kmeans(Xw, k, seed=seed, restarts=restarts)
        elif method == "dbscan":
            lab = dbscan_at_k(Xw, k)
        elif method == "optics":
            lab = optics_at_k(Xw, k)
        else:
            raise ValueError(method)
        di[k] = dunn_index(S, lab) if lab is not None else 0.0
        labs[k] = lab
    # argmax DI; exact ties (a k+1 partition that only splits off a singleton
    # keeps both dist and dia) break toward FEWER clusters — Procedure 1
    # prefers the coarsest partition that attains the optimum.
    best = min(di, key=lambda k: (-di[k], k))
    return ClusteringResult(best, labs[best], di, Vb)


def order_clusters_by_resources(V: np.ndarray, labels: np.ndarray,
                                lam=None) -> np.ndarray:
    """Relabel clusters so C_0 has the HIGHEST mean resources (master first,
    §IV-A2: clusters arranged in descending order of available resources,
    under the same λ weighting as the similarity metric).  ``lam=None``
    weighs the resource axes equally (the pre-λ behaviour)."""
    ks = np.unique(labels)
    lam_a = (np.full(V.shape[1], 1.0 / V.shape[1]) if lam is None
             else np.asarray(lam, np.float64))
    score = np.array([(V[labels == f] * lam_a).sum(axis=1).mean() for f in ks])
    order = ks[np.argsort(-score)]
    remap = {int(old): new for new, old in enumerate(order)}
    return np.array([remap[int(l)] for l in labels])


# ------------------------------------------------------------------ DBSCAN
def dbscan(X: np.ndarray, eps: float, min_pts: int = 3) -> np.ndarray:
    n = len(X)
    D = np.linalg.norm(X[:, None] - X[None], axis=-1)
    labels = np.full(n, -1)
    cid = 0
    for i in range(n):
        if labels[i] != -1:
            continue
        nbrs = np.where(D[i] <= eps)[0]
        if len(nbrs) < min_pts:
            continue
        labels[i] = cid
        stack = list(nbrs)
        while stack:
            j = stack.pop()
            if labels[j] == -1:
                labels[j] = cid
                nb2 = np.where(D[j] <= eps)[0]
                if len(nb2) >= min_pts:
                    stack.extend([q for q in nb2 if labels[q] == -1])
        cid += 1
    # assign noise points to nearest cluster (all participants must train)
    if cid > 0:
        for i in np.where(labels == -1)[0]:
            labels[i] = labels[np.argmin(np.where(labels >= 0, D[i], np.inf))]
    return labels


def dbscan_at_k(X: np.ndarray, k: int, min_pts: int = 3):
    """Binary-search eps to produce exactly k clusters (how the paper's
    Table II evaluates DBSCAN at each k); None if unreachable."""
    lo, hi = 1e-4, float(np.linalg.norm(X.max(0) - X.min(0))) + 1e-3
    best = None
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        lab = dbscan(X, mid, min_pts)
        kk = len(np.unique(lab))
        if kk == k:
            best = lab
            break
        if kk < k:      # too few clusters -> shrink eps
            hi = mid
        else:
            lo = mid
    return best


# ------------------------------------------------------------------ OPTICS
def optics_order(X: np.ndarray, min_pts: int = 3):
    n = len(X)
    D = np.linalg.norm(X[:, None] - X[None], axis=-1)
    core = np.sort(D, axis=1)[:, min_pts - 1]
    reach = np.full(n, np.inf)
    seen = np.zeros(n, bool)
    order = []
    for start in range(n):
        if seen[start]:
            continue
        seeds = {start: np.inf}
        while seeds:
            i = min(seeds, key=seeds.get)
            del seeds[i]
            if seen[i]:
                continue
            seen[i] = True
            order.append(i)
            for j in range(n):
                if seen[j]:
                    continue
                nr = max(core[i], D[i, j])
                if nr < reach[j]:
                    reach[j] = nr
                    seeds[j] = nr
    return np.array(order), reach


def optics_at_k(X: np.ndarray, k: int, min_pts: int = 3):
    """Cut the OPTICS reachability plot at the (k-1) largest peaks."""
    order, reach = optics_order(X, min_pts)
    r = reach[order]
    r[0] = 0.0
    if k <= 1:
        return np.zeros(len(X), int)
    cut_positions = np.sort(np.argsort(-r[1:])[:k - 1] + 1)
    labels = np.zeros(len(X), int)
    cid = 0
    pos = 0
    for c in list(cut_positions) + [len(X)]:
        labels[order[pos:c]] = cid
        cid += 1
        pos = c
    return np.clip(labels, 0, k - 1)
