"""Ready-made FLModelFamily adapters: the paper's CNN and a tiny LM."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.server import FLModelFamily
from repro.models import cnn
from repro.models import transformer
from repro.configs.base import ModelConfig
from repro.core.scaling import compress_config, model_bytes, param_count
from repro.launch.sharding import tp_specs


def cnn_family(*, classes: int = 10, in_channels: int = 1, alpha: float = 0.5,
               base_width: float = 0.25, input_hw: int = 14) -> FLModelFamily:
    def init(key, level):
        return cnn.init_params(key, in_channels=in_channels, classes=classes,
                               alpha=alpha, level=level, base_width=base_width)

    def loss_and_logits(level, params, batch):
        logits = cnn.forward(params, batch["x"])
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
        return jnp.mean(lse - picked), logits

    def mb(level):
        p = cnn.init_params(jax.random.PRNGKey(0), in_channels=in_channels,
                            classes=classes, alpha=alpha, level=level,
                            base_width=base_width)
        return cnn.param_count(p) * 4.0

    def flops(level):
        fs = cnn.filters(alpha, level, base_width)
        hw = input_hw ** 2
        total, cin, cur = 0.0, in_channels, hw
        for i, f in enumerate(fs):
            total += cur * cin * f * 9 * 2
            cin = f
            if i % 2 == 1:
                cur = max(1, cur // 4)
        return total

    def param_specs(level, template, msize, axis):
        """Megatron-style conv pairing: even convs shard OUT channels (dim
        3), odd convs shard IN channels (dim 2) so the channel-sharded
        activation feeds straight in; the dense head is row-parallel (its
        input channels arrive sharded from the last — even — conv).
        Non-divisible widths are demoted to replication downstream."""
        convs = [{"w": P(None, None, None, axis) if i % 2 == 0
                  else P(None, None, axis, None),
                  "b": P(axis) if i % 2 == 0 else P()}
                 for i in range(len(template["convs"]))]
        return {"convs": convs,
                "dense": {"w": P(axis, None), "b": P()}}

    return FLModelFamily(init=init, loss_and_logits=loss_and_logits,
                         model_bytes=mb, flops_per_sample=flops,
                         param_specs=param_specs)


def mlp_family(*, classes: int = 10, in_dim: int = 14 * 14,
               hidden: int = 32, alpha: float = 0.5) -> FLModelFamily:
    """Two-layer MLP family: the small-model end of the spectrum (edge
    devices below the paper's CNN).  Its per-round XLA program is a handful
    of ops, which makes it dispatch-bound on CPU — the regime the
    device-resident round pipeline (``FLConfig.rounds_per_dispatch``) is
    built for; ``benchmarks/bench_sim.py --mode dispatch`` uses it."""
    def width(level):
        return max(4, int(hidden * alpha ** level))

    def init(key, level):
        h = width(level)
        k1, k2 = jax.random.split(key)
        return {"w1": jax.random.normal(k1, (in_dim, h)) * 0.05,
                "b1": jnp.zeros((h,)),
                "w2": jax.random.normal(k2, (h, classes)) * 0.05,
                "b2": jnp.zeros((classes,))}

    def loss_and_logits(level, params, batch):
        x = batch["x"].reshape(batch["x"].shape[0], -1)
        z = jax.nn.relu(x @ params["w1"] + params["b1"])
        logits = z @ params["w2"] + params["b2"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
        return jnp.mean(lse - picked), logits

    def mb(level):
        h = width(level)
        return 4.0 * (in_dim * h + h + h * classes + classes)

    def param_specs(level, template, msize, axis):
        # column-parallel layer 1, row-parallel layer 2: one all-reduce
        # per forward (the canonical Megatron MLP split)
        return {"w1": P(None, axis), "b1": P(axis),
                "w2": P(axis, None), "b2": P()}

    return FLModelFamily(
        init=init, loss_and_logits=loss_and_logits, model_bytes=mb,
        flops_per_sample=lambda l: 2.0 * (in_dim * width(l)
                                          + width(l) * classes),
        param_specs=param_specs)


def lm_family(base_cfg: ModelConfig, alpha: float = 0.5) -> FLModelFamily:
    """Federated LM family: per-cluster α-compressed configs (same vocab →
    KD-compatible logits).

    Batch contract: ``batch = {"tokens": (B, S)}``.  The LM loss derives its
    next-token labels from ``tokens[:, 1:]`` itself — it reads no other key.
    Under KD the engine's batches additionally carry ``"y": (B,)``, the
    last-position token id: that key is consumed by the KD wrapper in
    ``core.client`` as the hard label paired with this family's KD logits.
    KD logits convention: ``loss_and_logits`` returns the LAST-position
    distribution ``logits[:, -1]`` of shape (B, V) — the (B, classes) shape
    the CNN/MLP families emit, so master→slave distillation is
    family-uniform (teacher and student distributions align at the one
    position both predict: the next token after the full prompt)."""
    def cfg_at(level):
        return compress_config(base_cfg, alpha, level)

    def init(key, level):
        return transformer.init_params(cfg_at(level), key)

    def loss_and_logits(level, params, batch):
        cfg = cfg_at(level)
        logits, aux = transformer.forward(cfg, params, batch["tokens"])
        lg = logits[:, :-1].astype(jnp.float32)
        lbl = batch["tokens"][:, 1:]
        lse = jax.nn.logsumexp(lg, axis=-1)
        picked = jnp.take_along_axis(lg, lbl[..., None], axis=-1)[..., 0]
        ce = jnp.mean(lse - picked) + cfg.router_aux_coef * aux
        # logits for KD: last position distribution ((B,V) to match CNN API)
        return ce, logits[:, -1]

    def param_specs(level, template, msize, axis):
        # same Megatron name rules the launch stack uses (launch/sharding):
        # vocab-parallel embed/head, column-parallel wq/wk/wv/up,
        # row-parallel wo/down; non-divisible dims replicate
        return tp_specs(cfg_at(level), template, msize, axis)

    return FLModelFamily(
        init=init, loss_and_logits=loss_and_logits,
        model_bytes=lambda l: float(model_bytes(cfg_at(l))),
        flops_per_sample=lambda l: 6.0 * param_count(cfg_at(l)),
        param_specs=param_specs)
