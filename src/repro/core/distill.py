"""Master-slave knowledge distillation (§IV-C).

The master cluster's trained model M_1 guides every slave cluster's training:
L = α·CE(student, labels) + (1-α)·T²·KL(softmax(teacher/T) ‖ softmax(student/T)).

The pure-jnp path is the oracle; ``use_kernel=True`` routes through the fused
Pallas kernel (kernels/distill) which streams over vocab blocks — the KD loss
over a 150k vocab is the technique's TPU hot spot (DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kl_teacher_student(teacher_logits, student_logits, T: float = 1.0,
                       valid_mask=None):
    """KL(p_T ‖ p_S) per example, temperature-scaled logits in fp32."""
    t = teacher_logits.astype(jnp.float32) / T
    s = student_logits.astype(jnp.float32) / T
    if valid_mask is not None:
        neg = jnp.float32(-2.0 ** 30)
        t = jnp.where(valid_mask, t, neg)
        s = jnp.where(valid_mask, s, neg)
    t_lse = jax.nn.logsumexp(t, axis=-1, keepdims=True)
    s_lse = jax.nn.logsumexp(s, axis=-1, keepdims=True)
    p_t = jnp.exp(t - t_lse)
    return jnp.sum(p_t * ((t - t_lse) - (s - s_lse)), axis=-1)


def ce_loss(logits, labels, valid_mask=None):
    lg = logits.astype(jnp.float32)
    if valid_mask is not None:
        lg = jnp.where(valid_mask, lg, jnp.float32(-2.0 ** 30))
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return lse - picked


def kd_loss(student_logits, labels, teacher_logits, *, T: float = 2.0,
            alpha: float = 0.3, valid_mask=None, use_kernel: bool = False):
    """Per-example Hinton-KD loss (mean-reduced)."""
    if use_kernel:
        from repro.kernels.distill import ops as distill_ops
        return distill_ops.kd_loss(student_logits, labels, teacher_logits,
                                   T=T, alpha=alpha)
    ce = ce_loss(student_logits, labels, valid_mask)
    kl = kl_teacher_student(teacher_logits, student_logits, T, valid_mask)
    return jnp.mean(alpha * ce + (1.0 - alpha) * (T ** 2) * kl)
