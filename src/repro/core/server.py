"""Fed-RAC orchestrator (Algorithm 1): cluster → compact → assign →
train master by FedAvg → train slaves under master KD.

Model-family-agnostic via ``FLModelFamily`` (the paper's CNN and the LM
backbones both plug in); per-cluster client training runs through
``core.client`` so on a pod the whole cluster is one vmap/pjit program.
"""
from __future__ import annotations

import time
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import aggregation, assignment as asg, clustering, compaction
from repro.core import cost_model, rounds as rnd
from repro.core.client import local_update, make_cluster_update
from repro.core.plane import make_plane_spec, make_tp_plane_spec, plane_specs
from repro.core.resources import (LAMBDA_PAPER, Fleet, Participant,
                                  resource_matrix, unit_normalize)
from repro.data import device_sampler
from repro.data.sampler import class_balanced_batches, sample_batches
from repro.models.tp import tp_shard_ctx
from repro.launch.sharding import (member_specs, replicated_specs,
                                   shard_member_tree, to_named)
from repro.obs import NULL_OBS


@dataclass
class FLModelFamily:
    """init(key, level) -> params; loss_and_logits(level, params, batch)."""
    init: Callable
    loss_and_logits: Callable
    model_bytes: Callable          # level -> bytes
    flops_per_sample: Callable     # level -> flops
    # Optional tensor-parallel rules: (level, params_template, msize, axis)
    # -> PartitionSpec pytree matching the params.  When present (and the
    # engine runs on a 2D mesh with ``tp_forward``), the dispatch path
    # GSPMD-shards the member FORWARD along the model axis instead of
    # all-gathering plane columns per round — see ``core.plane.TPPlaneSpec``.
    param_specs: Callable | None = None


@dataclass
class FLConfig:
    alpha: float = 0.5
    kd_T: float = 2.0
    kd_alpha: float = 0.3
    E: int = 2
    local_batch: int = 16
    steps_per_round: int = 4
    lr: float = 0.05
    lam: tuple = LAMBDA_PAPER
    q_target: float = 0.05
    delta: float | None = None
    theta: float = 100.0
    # MAR time budget; None → auto-calibrate so the master-cluster budget
    # admits roughly the fastest ~40% of participants (the paper fixes MAR
    # externally; auto mode keeps experiments scale-free).
    mar: float | None = None
    kappa: float = 0.7
    compact_to: int | None = None
    rounds: int = 20
    seed: int = 0
    class_balanced: bool = True
    use_kd: bool = True
    # batched cluster execution: one make_cluster_update vmap call per round
    # (all members advance together; heterogeneous τ_i / stragglers enter as
    # step masks).  False falls back to the per-pid Python loop — kept for
    # equivalence testing and benchmarks/bench_sim.py.
    vmap_clusters: bool = True
    # opt-in: let a vmap_clusters=False engine still use the scan-fused
    # dispatch path when rounds_per_dispatch>1 (the per-pid loop itself
    # cannot be fused, so training routes through dispatch_rounds) — the
    # hook that lets the equivalence matrix run its independent-loop
    # column fused as well.
    allow_loop_dispatch: bool = False
    # compile-stable padding: round every cluster's member count up to a
    # capacity bucket (next power of two, then multiples of pad_max) and pad
    # batches/masks/weights with zero rows, so Procedure-2 migrations and
    # simulator dropouts/arrivals reuse the same XLA program instead of
    # retracing it at every new cardinality.  False traces at exact C.
    pad_clusters: bool = True
    pad_max: int = 64
    # aggregation schedule: "sync" is plain FedAvg over this round's
    # contributors; "buffered" additionally merges banked (late) updates
    # from earlier rounds, discounted by staleness_discount**age — the
    # sim's MAR policy "buffer" feeds this path.
    aggregation: str = "sync"
    staleness_discount: float = 0.6
    # device-resident round pipeline: >1 fuses that many communication
    # rounds into ONE jitted lax.scan program (in-program batch sampling
    # from device-resident shards, parameters carried as a flat fp32 plane,
    # plane donated between blocks).  1 keeps the legacy one-round-per-
    # dispatch path.  Within the dispatch path the batch stream depends
    # only on the absolute round index, so any two widths R are numerically
    # equivalent; the legacy path keeps its historical numpy stream.
    rounds_per_dispatch: int = 1
    # donate the parameter plane (and bank plane) into each dispatch so
    # multi-round blocks run copy-free; the caller's handle to the donated
    # buffer is dead after the call.
    donate_plane: bool = True
    # true tensor-parallel member forward on a 2D (data × model) mesh: the
    # dispatch block runs as ONE GSPMD global-view program whose plane
    # carries the TP layout (``core.plane.TPPlaneSpec``), so the member
    # forward/backward is Megatron-sharded along the model axis and the
    # full (D,) plane is never materialized per device.  Requires the
    # family to provide ``param_specs``; False keeps the legacy shard_map
    # path that transiently all-gathers plane columns every round.
    tp_forward: bool = True
    consts: rnd.ConvergenceConstants = field(default_factory=rnd.ConvergenceConstants)


@dataclass
class DispatchOut:
    """Result of one scan-fused dispatch block (``FedRAC.dispatch_rounds``)."""
    plane: object               # (D_pad,) fp32 — replaces the donated input
    losses: object              # (R, C) per-round per-member mean losses
    bank: tuple | None          # (bank_plane, bank_w) after the last round
    history: object | None      # (R, D_pad) per-round planes (want_history)


class _TimedProgram:
    """Transparent wrapper around one jitted program that detects fresh XLA
    compiles (jit cache-size delta across a call) and records them in the
    metrics registry — a per-program compile counter and wall-time gauge
    (``fl/compiles/<label>`` / ``fl/compile_s/<label>``), fleet-wide
    ``fl/compile_total`` and ``fl/compile_s`` aggregates — plus a
    ``compile`` span on the tracer.  Only installed when observability is
    enabled; the disabled path stores the raw jitted callable."""
    __slots__ = ("fn", "_obs", "_label")

    def __init__(self, fn, obs, label: str):
        self.fn = fn
        self._obs = obs
        self._label = label

    def _cache_size(self):               # compile_stats() delegate
        return self.fn._cache_size()

    def __call__(self, *args, **kw):
        before = self.fn._cache_size()
        t0 = time.perf_counter_ns()
        out = self.fn(*args, **kw)
        if self.fn._cache_size() > before:
            # a fresh trace+compile happened inside this call: make the
            # measured wall time cover it honestly
            jax.block_until_ready(out)
            dt_ns = time.perf_counter_ns() - t0
            reg = self._obs.registry
            reg.counter(f"fl/compiles/{self._label}").inc()
            reg.gauge(f"fl/compile_s/{self._label}").set(dt_ns / 1e9)
            reg.counter("fl/compile_total").inc()
            reg.histogram("fl/compile_s").observe(dt_ns / 1e9)
            self._obs.tracer.complete("compile", t0, dt_ns, cat="fl",
                                      program=self._label)
        return out


@dataclass
class FedRACResult:
    k_optimal: int
    m: int
    di_values: dict
    labels: np.ndarray
    assignment: asg.Assignment
    history: dict            # level -> [acc per round]
    final_acc: dict          # level -> acc
    global_acc: float
    rounds_used: dict


class FedRAC:
    def __init__(self, parts: "list[Participant] | Fleet",
                 client_data: list[dict],
                 family: FLModelFamily, cfg: FLConfig, classes: int, *,
                 mesh=None, mesh_axis: str = "data",
                 mesh_model_axis: str = "model"):
        if cfg.aggregation not in ("sync", "buffered"):
            raise ValueError(f"unknown aggregation {cfg.aggregation!r}")
        if (cfg.rounds_per_dispatch > 1 and not cfg.vmap_clusters
                and not cfg.allow_loop_dispatch):
            raise ValueError(
                "rounds_per_dispatch>1 (device-resident pipeline) requires "
                "vmap_clusters=True — the per-pid loop cannot be scan-fused "
                "(set allow_loop_dispatch=True to route a loop-configured "
                "engine through the fused dispatch path anyway)")
        if mesh is not None and cfg.rounds_per_dispatch == 1:
            raise ValueError(
                "a mesh shards the device-resident dispatch path — set "
                "rounds_per_dispatch>1 (the legacy one-round path would "
                "silently ignore it)")
        # a Fleet (struct-of-arrays) is the canonical fleet-scale state;
        # self.parts stays the object API either way — Fleet rows are
        # write-through views, so update_resources/sim mutations through
        # either surface agree by construction
        if isinstance(parts, Fleet):
            self.fleet = parts
            self.parts = parts.participants()
        else:
            self.fleet = None
            self.parts = parts
        self.client_data = client_data        # per pid: {"x": ..., "y": ...}
        self.family = family
        self.cfg = cfg
        self.classes = classes
        # observability bundle (metrics registry + tracer); NULL_OBS keeps
        # every instrumented site on its single-branch no-op fast path
        self.obs = NULL_OBS
        # mesh-sharded execution: the dispatch block program runs under
        # shard_map with the capacity axis split along mesh `mesh_axis` —
        # each device trains its local member rows and one psum over that
        # axis realizes the §III-B upload as an all-reduce.  A 2D
        # (data × model) mesh additionally splits every plane COLUMN-wise
        # along `mesh_model_axis`: the global plane, buffered bank and
        # per-round teacher/history stacks live distributed (member models
        # too large for one device stop replicating), parameters are
        # all-gathered transiently for the local forward, and each device
        # aggregates only its own (member rows × column slice) subgrid —
        # the model axis needs no reduction at all.  None = single-device.
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self._mesh_n = int(mesh.shape[mesh_axis]) if mesh is not None else 1
        self._mesh_m = (int(dict(mesh.shape).get(mesh_model_axis, 1))
                        if mesh is not None else 1)
        # None when the model axis is absent or trivial: every 1D code path
        # (and its compiled programs) is exactly the pre-2D one.
        self.model_axis = mesh_model_axis if self._mesh_m > 1 else None
        self._pspecs = plane_specs(mesh_axis, self.model_axis)
        # true TP forward: the 2D-mesh dispatch block runs as one GSPMD
        # global-view program over a TP-layout plane (family supplies the
        # per-leaf rules).  Families without ``param_specs`` — and engines
        # with ``tp_forward=False`` — keep the legacy column-gather path.
        self._tp = (self._mesh_m > 1 and cfg.tp_forward
                    and family.param_specs is not None)
        # (level, use_kd, capacity, want_stack, …) -> jitted round programs
        self._programs = {}
        # dispatch-path caches: level -> PlaneSpec; (level, members) ->
        # device-resident shard pack; lazily-computed global pad lengths
        self._plane_specs = {}
        self._shard_packs = {}
        # newest pack per (level, capacity, balanced) — the delta-update
        # base when membership churns (Procedure-2 migration, sim events)
        self._pack_prev = {}
        self._shard_len_pad = None
        self._class_m_pad = None
        self._class_tables = {}           # pid -> (table, counts) host arrays
        # TP dispatch normalizes a FIXED KD teacher pytree to its level-0
        # plane once per teacher identity (strong ref pins the id)
        self._t_plane_cache = None

    # ------------------------------------------------------------ setup
    def setup(self):
        cfg = self.cfg
        V = resource_matrix(self.fleet if self.fleet is not None
                            else self.parts)
        res = clustering.optimal_clusters(V, cfg.lam, seed=cfg.seed)
        labels = clustering.order_clusters_by_resources(res.normalized,
                                                        res.labels, cfg.lam)
        self.k_optimal = res.k
        self.di_values = res.di_values
        if cfg.compact_to is not None and cfg.compact_to < res.k:
            labels = compaction.compact(labels, res.normalized, cfg.compact_to)
        self.labels = labels
        self.m = len(np.unique(labels))
        sizes = [(self.family.model_bytes(l), self.family.flops_per_sample(l))
                 for l in range(self.m)]
        mar = cfg.mar
        if mar is None:
            t_master = np.array([cost_model.round_time(
                p, sizes[0][1], sizes[0][0], cfg.E) for p in self.parts])
            mar = float(np.percentile(t_master, 40)) / (cfg.kappa ** (self.m - 1))
        self.mar = mar
        self.specs = asg.build_cluster_specs(
            sizes, cfg.consts, E=cfg.E, q_target=cfg.q_target, delta=cfg.delta,
            theta=cfg.theta, mar=mar, kappa=cfg.kappa,
            batch_size=cfg.local_batch)
        self.assignment = asg.assign(self.parts, self.specs, cfg.consts, cfg.lr)
        return self

    def update_resources(self, pid: int, *, s: float | None = None,
                         r: float | None = None, a: float | None = None):
        """§IV-A dynamic resources: update a participant's (s, r, a) and
        re-run the Procedure-2 placement — the participant upgrades or
        downgrades clusters in place.  Returns (old_level, new_level)."""
        p = self.parts[pid]
        if s is not None:
            p.s = s
        if r is not None:
            p.r = r
        if a is not None:
            p.a = a
        return asg.reassign(p, self.assignment, self.specs,
                            self.cfg.consts, self.cfg.lr)

    # ------------------------------------------------------------ training
    # Batch sampling.  The legacy one-round-per-dispatch path samples on
    # host with numpy (seed + 977·pid + round — unchanged numerics).  The
    # scan-fused dispatch path draws its indices from a seeded jax.random
    # stream keyed on (seed, absolute round, member slot) INSIDE the program
    # (data/device_sampler.py) and gathers from device-resident shards, so
    # any two dispatch widths R are numerically interchangeable (the stream
    # never depends on block boundaries).
    # The two paths' streams are statistically equivalent but distinct —
    # cross-path comparisons are statistical, cross-R comparisons exact.

    def _member_shard(self, pid: int):
        """Hook: one member's full data shard (pytree, leading axis = n_i)
        for the dispatch path.  Subclasses with non-{"x","y"} data override
        this plus ``_batch_from_gathered``."""
        return self.client_data[pid]

    def _batch_from_gathered(self, gathered):
        """Hook: post-gather transform from a (steps, batch, …) shard slice
        to the loss_fn batch format (jax-traceable — it runs inside the
        dispatch scan body)."""
        return gathered

    def _class_table(self, pid: int):
        """Per-member class index table for balanced in-program sampling,
        padded to the fleet-wide max class count so the dispatch program
        shape is stable under Procedure-2 churn."""
        if self._class_m_pad is None:
            m = 1
            for q in range(len(self.parts)):
                y = np.asarray(self._member_shard(q)["y"])
                if y.size:
                    m = max(m, int(np.bincount(y, minlength=self.classes)
                                   .max()))
            self._class_m_pad = 1 << (m - 1).bit_length()
        if pid not in self._class_tables:
            self._class_tables[pid] = device_sampler.build_class_table(
                np.asarray(self._member_shard(pid)["y"]), self.classes,
                self._class_m_pad)
        return self._class_tables[pid]

    def _client_batches(self, pid: int, rng_round: int, balanced: bool):
        d = self.client_data[pid]
        steps = self.cfg.steps_per_round
        if balanced:
            return class_balanced_batches(d["x"], d["y"], self.cfg.local_batch,
                                          steps, self.classes,
                                          seed=self.cfg.seed + 977 * pid + rng_round)
        return sample_batches(d["x"], d["y"], self.cfg.local_batch, steps,
                              seed=self.cfg.seed + 977 * pid + rng_round)

    def _capacity(self, C: int) -> int:
        """Bucket a live member count to its padded capacity: next power of
        two capped at pad_max, then multiples of pad_max — a handful of
        buckets covers every cardinality Procedure-2 churn can produce.
        (The cap keeps capacities monotone for non-power-of-two pad_max.)
        On a mesh the capacity is additionally rounded up to a multiple of
        the data-axis size so every device holds the same member-row count
        — the extra rows are the same zero-weight padding the buckets use,
        so they never touch the aggregate."""
        cfg = self.cfg
        cap = C
        if cfg.pad_clusters and C > 0:
            if C >= cfg.pad_max:
                cap = -(-C // cfg.pad_max) * cfg.pad_max
            else:
                cap = min(1 << (C - 1).bit_length(), cfg.pad_max)
        if self._mesh_n > 1 and cap > 0:
            cap = -(-cap // self._mesh_n) * self._mesh_n
        return cap

    def _stacked_batches(self, members: list[int], rng_round: int, level: int,
                         capacity: int | None = None):
        """Per-member batches stacked to (capacity, steps, batch, ...) pytrees;
        slots past len(members) are zero rows (they train under a zero
        step-mask and zero weight, so their contents never matter).
        Stacks on host so each leaf is one contiguous device transfer."""
        balanced = self.cfg.class_balanced and level == 0
        per = [self._client_batches(pid, rng_round, balanced)
               for pid in members]
        pad = (capacity or len(members)) - len(members)

        def stack(*xs):
            arr = np.stack(xs)
            if pad:
                arr = np.concatenate(
                    [arr, np.zeros((pad,) + arr.shape[1:], arr.dtype)])
            return jnp.asarray(arr)

        out = jax.tree.map(stack, *per)
        if self.obs.on:
            self.obs.registry.counter("fl/h2d_bytes").inc(
                sum(x.nbytes for x in jax.tree.leaves(out)))
        return out

    # ------------------------------------------------------------ plane
    def plane_spec(self, level: int):
        """Flat-parameter-plane recipe for one level (cached; the template
        init is shape-only).  On a 2D mesh D pads to a multiple of
        ``model_size × PLANE_ALIGN`` so each device's column slice keeps the
        Pallas fedagg tile grid aligned."""
        if level not in self._plane_specs:
            template = self.family.init(jax.random.PRNGKey(0), level)
            if self._tp:
                specs = self.family.param_specs(level, template,
                                                self._mesh_m, self.model_axis)
                self._plane_specs[level] = make_tp_plane_spec(
                    template, specs, msize=self._mesh_m, axis=self.model_axis)
            else:
                self._plane_specs[level] = make_plane_spec(
                    template, model_size=self._mesh_m)
        return self._plane_specs[level]

    def plane_of(self, level: int, params) -> jnp.ndarray:
        """Ravel a params pytree into its (D_pad,) fp32 plane (committed to
        its mesh sharding, so every dispatch call sees one input sharding
        signature and block programs never retrace)."""
        return self.place_plane(self.plane_spec(level).to_plane(params))

    def place_plane(self, x):
        """Commit a (D,) plane to its mesh sharding: column-sharded along
        the model axis on a 2D mesh, replicated otherwise."""
        if self.mesh is None:
            return x
        return jax.device_put(x, NamedSharding(self.mesh,
                                               self._pspecs["plane"]))

    def place_plane_stack(self, x):
        """Commit an (R, D) teacher/history plane stack (rounds replicated,
        columns model-sharded on a 2D mesh)."""
        if self.mesh is None:
            return x
        return jax.device_put(x, NamedSharding(self.mesh,
                                               self._pspecs["stack"]))

    def place_member_plane(self, x):
        """Commit a (capacity, D) member/bank plane: rows member-sharded,
        columns model-sharded on a 2D mesh."""
        if self.mesh is None:
            return x
        return jax.device_put(x, NamedSharding(self.mesh,
                                               self._pspecs["members"]))

    def place_member_sharded(self, x):
        """Commit an array sharded along the member axis (no-op without a
        mesh) — bank carries and mask/weight rows enter dispatch programs
        pre-placed instead of being resharded per call."""
        if self.mesh is None:
            return x
        return jax.device_put(x, NamedSharding(self.mesh,
                                               P(self.mesh_axis)))

    def params_of(self, level: int, plane):
        """Unravel a plane back to a params pytree (evaluation/reporting
        boundary — the only place the dispatch path leaves the plane)."""
        return self.plane_spec(level).to_params(plane)

    def _delta_shards(self, level: int, members: list[int], capacity: int,
                      balanced: bool):
        """Delta shard-pack update on membership churn: when a previous pack
        exists at the same (level, capacity, balanced) signature, surviving
        member rows are PERMUTED on device (one gather + row-mask) and only
        genuinely new members' shards are built on host and scattered in —
        a Procedure-2 migration of one participant moves one row, not the
        whole (capacity, N_pad, …) stack.  Returns the new shards pytree, or
        None when a full rebuild is better (no base pack, > half the rows
        fresh) or a mesh is present (the base is row-sharded; a permutation
        would reshard — the full build path places rows once, correctly).
        Sets ``self._delta_h2d`` to the bytes actually transferred."""
        self._delta_h2d = None
        if self.mesh is not None:
            return None
        prev = self._pack_prev.get((level, capacity, balanced))
        if prev is None:
            return None
        prev_members, prev_shards = prev
        pos = {pid: i for i, pid in enumerate(prev_members)}
        src = np.zeros(capacity, np.int64)
        keep = np.zeros(capacity, bool)
        fresh = []
        for i, pid in enumerate(members):
            j = pos.get(pid)
            if j is None:
                fresh.append(i)
            else:
                src[i] = j
                keep[i] = True
        if len(fresh) > max(1, len(members) // 2):
            return None
        srcj, keepj = jnp.asarray(src), jnp.asarray(keep)

        def permute(a):
            g = a[srcj]
            mask = keepj.reshape((capacity,) + (1,) * (g.ndim - 1))
            return jnp.where(mask, g, jnp.zeros((), g.dtype))

        shards_j = jax.tree.map(permute, prev_shards)
        moved = 0
        if fresh:
            N = self._shard_len_pad
            rows = [self._member_shard(members[i]) for i in fresh]

            def fresh_leaf(*xs):
                first = np.asarray(xs[0])
                out = np.zeros((len(fresh), N) + first.shape[1:],
                               first.dtype)
                for i, x in enumerate(xs):
                    x = np.asarray(x)
                    out[i, :x.shape[0]] = x
                return out

            host_rows = jax.tree.map(fresh_leaf, *rows)
            idxj = jnp.asarray(np.asarray(fresh))
            shards_j = jax.tree.map(
                lambda a, f: a.at[idxj].set(jnp.asarray(f)),
                shards_j, host_rows)
            moved = sum(np.asarray(x).nbytes
                        for x in jax.tree.leaves(host_rows))
        self._delta_h2d = moved
        return shards_j

    def _shard_pack(self, level: int, members: list[int], capacity: int,
                    balanced: bool):
        """Device-resident member data for the dispatch path: every member's
        full shard stacked to (capacity, N_pad, …) once (padded rows are
        zeros and never drawn), plus lengths, pids, and — for balanced
        levels — class tables.  N_pad and the class-table width are fleet-
        wide power-of-two ceilings so the program shape is identical for
        every membership Procedure-2 churn can produce."""
        key = (level, tuple(members), capacity, balanced)
        if key in self._shard_packs:
            pack = self._shard_packs.pop(key)      # LRU: refresh on hit
            self._shard_packs[key] = pack
            return pack
        t0 = time.perf_counter_ns()
        if self._shard_len_pad is None:
            n_max = max(max((jax.tree.leaves(self._member_shard(q))[0].shape[0]
                             for q in range(len(self.parts))), default=1), 1)
            self._shard_len_pad = 1 << (n_max - 1).bit_length()
        N = self._shard_len_pad
        shards = [self._member_shard(pid) for pid in members]
        shards_j = self._delta_shards(level, members, capacity, balanced)
        delta = shards_j is not None
        if not delta:

            def pack_leaf(*xs):
                first = np.asarray(xs[0])
                out = np.zeros((capacity, N) + first.shape[1:], first.dtype)
                for i, x in enumerate(xs):
                    x = np.asarray(x)
                    out[i, :x.shape[0]] = x
                return jnp.asarray(out)

            shards_j = jax.tree.map(pack_leaf, *shards)
        pack = {"shards": shards_j,
                "n": jnp.asarray(np.concatenate(
                    [np.asarray([jax.tree.leaves(s)[0].shape[0]
                                 for s in shards], np.int32),
                     np.zeros(capacity - len(members), np.int32)])),
                "tables": None, "counts": None}
        if balanced and members:
            self._class_table(members[0])              # sizes _class_m_pad
            tables = np.zeros((capacity, self.classes, self._class_m_pad),
                              np.int32)
            counts = np.zeros((capacity, self.classes), np.int32)
            for i, pid in enumerate(members):
                tables[i], counts[i] = self._class_table(pid)
            pack["tables"] = jnp.asarray(tables)
            pack["counts"] = jnp.asarray(counts)
        if self.mesh is not None:
            # place the pack row-sharded on the mesh ONCE; cached reuse then
            # skips the implicit per-call jit reshard
            pack = shard_member_tree(self.mesh, pack, self.mesh_axis)
        if len(self._shard_packs) >= 16:               # bound device memory
            self._shard_packs.pop(next(iter(self._shard_packs)))
        self._shard_packs[key] = pack
        if self.mesh is None:
            self._pack_prev[(level, capacity, balanced)] = (
                tuple(members), pack["shards"])
        if self.obs.on:
            nbytes = (self._delta_h2d if delta
                      else sum(x.nbytes for x in jax.tree.leaves(pack)))
            reg = self.obs.registry
            reg.counter("fl/h2d_bytes").inc(nbytes)
            reg.counter("fl/pack_builds").inc()
            if delta:
                reg.counter("fl/pack_delta").inc()
            self.obs.tracer.complete(
                "pack_h2d", t0, time.perf_counter_ns() - t0, cat="fl",
                level=level, bytes=nbytes, delta=delta)
        return pack

    def _cluster_programs(self, level: int, use_kd: bool, capacity: int,
                          want_stack: bool = False):
        """Cached whole-round program for one cluster: broadcast shared params
        over the member axis, run every member's τ local steps under one vmap
        (teacher logits computed in-program for slave clusters), and fuse the
        FedAvg aggregation — a single jitted XLA program per round.
        Keyed on the padded capacity (not the live member count) so cluster
        migrations reuse the program, and on the captured hyperparameters so
        in-place FLConfig mutation (lr sweeps on one engine) invalidates the
        cache.  ``want_stack`` programs additionally return the per-member
        updated params (the buffered-aggregation banking hook)."""
        cfg = self.cfg
        key = (level, use_kd, capacity, want_stack,
               cfg.lr, cfg.kd_T, cfg.kd_alpha)
        if key not in self._programs:
            loss_fn = jax.tree_util.Partial(self.family.loss_and_logits, level)
            kw = dict(kd_T=cfg.kd_T, kd_alpha=cfg.kd_alpha) if use_kd else {}
            update = make_cluster_update(loss_fn, cfg.lr, **kw)
            t_loss_fn = jax.tree_util.Partial(self.family.loss_and_logits, 0)

            def round_fn(params, batches, step_masks, weights, teacher):
                C = step_masks.shape[0]
                p_stack = jax.tree.map(
                    lambda x: jnp.broadcast_to(x[None], (C,) + x.shape),
                    params)
                teachers = None
                if use_kd:
                    teachers = jax.vmap(                       # members axis
                        jax.vmap(lambda b: t_loss_fn(teacher, b)[1])
                    )(batches)                                 # steps axis
                new_stack, losses = update(p_stack, batches, step_masks,
                                           teachers)
                agg = aggregation.aggregate(new_stack, weights)
                if want_stack:
                    return agg, losses, new_stack
                return agg, losses

            prog = jax.jit(round_fn)
            if self.obs.on:
                prog = _TimedProgram(
                    prog, self.obs,
                    f"round_L{level}_cap{capacity}_R1"
                    + ("_kd" if use_kd else "")
                    + ("_stack" if want_stack else ""))
            self._programs[key] = prog
        return self._programs[key]

    def compile_stats(self) -> dict:
        """Program-cache telemetry: {program key -> XLA compile count}.
        With padding on, every key should sit at 1 — a retrace means some
        input shape escaped the capacity bucketing."""
        out = {}
        for key, prog in self._programs.items():
            progs = prog if isinstance(prog, tuple) else (prog,)
            if not all(hasattr(p, "_cache_size") for p in progs):
                raise RuntimeError(
                    "this jax build has no jit _cache_size; compile "
                    "telemetry unavailable (do not silently report 0)")
            out[key] = sum(p._cache_size() for p in progs)
        return out

    def cluster_round(self, level: int, members: list[int], params, r: int, *,
                      teacher=None, step_masks=None, weights=None,
                      buffered=None, return_stack: bool = False):
        """One communication round for a cluster, batched: every member's τ
        local steps run under a single vmapped update, then FedAvg.

        ``step_masks`` (C, steps) zeroes out SGD steps per member — the hook
        for heterogeneous τ_i and for the simulator's straggler/dropout masks
        (a fully-zero row leaves that member at the incoming params).
        ``weights`` are raw non-negative aggregation weights per member
        (default: n_eff); they are renormalized over the members that actually
        contribute.  All-zero weights (every member dropped) leave ``params``
        unchanged — partial aggregation.

        With ``pad_clusters`` the live C is padded up to its capacity bucket
        (zero batches/masks/weights rows); padded rows carry zero aggregation
        weight, so the renormalized FedAvg over the real members is untouched
        and the XLA program is reused across cardinality changes.

        ``buffered`` is a list of (params_pytree, raw_weight) banked async
        contributions (already staleness-discounted); they join this round's
        FedAvg as extra members at their stale params.  ``return_stack=True``
        additionally returns the per-member updated params stack — the
        banking hook for the buffered schedule.

        Returns (new_params, member_losses[, member_params_stack]).
        """
        cfg = self.cfg
        C = len(members)
        if weights is None:
            weights = [self.assignment.n_eff.get(pid, 1) for pid in members]
        w = np.asarray(weights, np.float32)
        buffered = list(buffered) if buffered else []
        u = np.asarray([bw for _, bw in buffered], np.float32)
        total = float(w.sum()) + float(u.sum())
        if total <= 0.0 and not return_stack:
            # everyone dropped: partial agg no-op (with return_stack the
            # program still runs — banked members trained, their stack is
            # needed even though nobody aggregates this round)
            return params, jnp.zeros((C,), jnp.float32)
        cap = self._capacity(C)
        run_program = float(w.sum()) > 0.0 or return_stack
        stack = None
        denom = total if total > 0.0 else 1.0
        if run_program:
            batches = self._stacked_batches(members, r, level, cap)
            steps = jax.tree.leaves(batches)[0].shape[1]
            if step_masks is None:
                step_masks = jnp.ones((C, steps), jnp.float32)
            masks = np.zeros((cap, steps), np.float32)
            masks[:C] = np.asarray(step_masks, np.float32)
            w_pad = np.zeros(cap, np.float32)
            w_pad[:C] = w / denom
            use_kd = teacher is not None and cfg.use_kd
            round_fn = self._cluster_programs(level, use_kd, cap,
                                              want_stack=return_stack)
            out = round_fn(params, batches, jnp.asarray(masks),
                           jnp.asarray(w_pad), teacher)
            partial, losses = out[0], out[1]
            if return_stack:
                stack = out[2]
        else:                           # only banked updates contribute
            partial = jax.tree.map(jnp.zeros_like, params)
            losses = jnp.zeros((cap,), jnp.float32)
        if total <= 0.0:               # stack-only round: aggregate no-op
            return params, losses[:C], stack
        if buffered:
            partial = aggregation.merge_buffered(
                partial, [p for p, _ in buffered], u / total)
        losses = losses[:C]
        return (partial, losses, stack) if return_stack else (partial, losses)

    # ------------------------------------------------------------ dispatch
    def _dispatch_programs(self, level: int, use_kd: bool, capacity: int,
                           R: int, balanced: bool, banked: bool,
                           want_history: bool, t_per_round: bool = False,
                           pack=None, teacher_example=None):
        """Cached scan-fused block program: R communication rounds in ONE
        jitted XLA program.  Per scan step it draws every member's batch
        indices in-program (seeded on the absolute round index and the
        member's global slot), gathers from the device-resident shard pack,
        runs the vmapped member update, and aggregates on the flat parameter
        plane — one contraction, no host round-trip, no tree_flatten.  The
        plane (and bank plane) are donated, so blocks run copy-free.
        ``banked`` variants additionally carry the buffered-aggregation bank
        through the scan: each round merges the previous round's bank
        (pre-discounted weights) into the FedAvg and re-banks this round's
        violators at ``bank_gain``.  ``t_per_round`` programs scan a
        (R, D_master) teacher-plane stack instead of closing over one fixed
        teacher — the hook that keeps KD teachers refreshing at round
        granularity inside a fused block.

        On a mesh the whole block runs under ``shard_map`` with the member
        (capacity) axis split along ``mesh_axis``: every device trains its
        local member rows, the per-round aggregation contracts locally
        (``aggregate_plane`` — the Pallas fedagg kernel on TPU) and ONE psum
        per round completes the §III-B upload all-reduce; donation is
        preserved, and the buffered bank rows ride the carry sharded like
        the members they came from.  On a 1D mesh the plane and the
        per-round teacher stack stay replicated.  On a 2D (data × model)
        mesh they instead split COLUMN-wise along the model axis — each
        device stores only its D/model_size slice of the plane, bank and
        teacher/history stacks.  With ``tp_forward`` (and a family that
        provides ``param_specs``) the 2D block compiles as ONE GSPMD
        global-view program over a TP-layout plane
        (``core.plane.TPPlaneSpec``): the member forward/backward itself is
        Megatron-sharded along the model axis — ``to_params`` is a chain of
        device-local reshapes, XLA inserts only the per-layer activation
        collectives, and the full (D,) plane never materializes on any
        device.  The legacy 2D path (``tp_forward=False``) instead
        all-gathers the plane (and teacher) columns transiently each round
        for a replicated local forward; either way each device contracts
        its (member rows × column slice) subgrid and a single data-axis
        reduction finishes the FedAvg — columns never need reduction."""
        cfg = self.cfg
        tp = self._tp
        key = ("dispatch", level, use_kd, capacity, R, balanced, banked,
               want_history, cfg.lr, cfg.kd_T, cfg.kd_alpha, cfg.seed,
               cfg.steps_per_round, cfg.local_batch, cfg.donate_plane,
               t_per_round, self._mesh_n, self._mesh_m, tp)
        if key in self._programs:
            return self._programs[key]
        loss_fn = jax.tree_util.Partial(self.family.loss_and_logits, level)
        kw = dict(kd_T=cfg.kd_T, kd_alpha=cfg.kd_alpha) if use_kd else {}
        update = make_cluster_update(loss_fn, cfg.lr, **kw)
        t_loss_fn = jax.tree_util.Partial(self.family.loss_and_logits, 0)
        spec = self.plane_spec(level)
        t_spec = (self.plane_spec(0) if (use_kd and (t_per_round or tp))
                  else None)
        steps, batch, seed = cfg.steps_per_round, cfg.local_batch, cfg.seed
        # The TP program is written in the GLOBAL view (no named axes: full
        # capacity, offset 0, one global weight sum) — numerically the
        # unsharded program — and GSPMD partitions it via the in/out
        # shardings + constraints below.  The shard_map path keeps its
        # per-device view with explicit collectives.
        axis = self.mesh_axis if (self.mesh is not None and not tp) else None
        maxis = self.model_axis if (self.mesh is not None and not tp) else None
        use_kernel = False if tp else None    # Pallas agg can't GSPMD-split

        def _constrain(x, pspec):
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, pspec))

        def _gather_cols(plane_loc):
            """Local column slice -> full plane (2D mesh), else identity."""
            if maxis is None:
                return plane_loc
            return jax.lax.all_gather(plane_loc, maxis, tiled=True)

        def _local_cols(plane_full):
            """(C, D_full) member plane -> this device's column slice."""
            if maxis is None:
                return plane_full
            d_loc = plane_full.shape[1] // self._mesh_m
            return jax.lax.dynamic_slice_in_dim(
                plane_full, jax.lax.axis_index(maxis) * d_loc, d_loc, axis=1)

        def one_round(g, bank_p, bank_w, r, shards, n_i, tables,
                      counts, step_masks, weights, teacher, offset):
            C_loc = step_masks.shape[0]       # local member rows (mesh-split)
            key = device_sampler.round_key(seed, r)
            if balanced:
                idx = device_sampler.balanced_indices(key, steps, batch,
                                                      tables, counts,
                                                      offset=offset)
            else:
                idx = device_sampler.uniform_indices(key, steps, batch, n_i,
                                                     offset=offset)
            batches = jax.vmap(lambda sh, ix: self._batch_from_gathered(
                jax.tree.map(lambda a: a[ix], sh)))(shards, idx)
            params = (spec.to_params(g, mesh=self.mesh) if tp
                      else spec.to_params(_gather_cols(g)))
            p_stack = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (C_loc,) + x.shape),
                params)
            if tp:
                # member rows over `data`, each member's leaves TP-sharded —
                # the broadcast stays a broadcast; the forward partitions
                p_stack = jax.tree.map(
                    lambda x, sp: _constrain(
                        x, P(self.mesh_axis, *sp)),
                    p_stack, spec.leaf_specs())
            teachers = None
            if use_kd:
                if tp:
                    t_params = t_spec.to_params(teacher, mesh=self.mesh)
                elif t_per_round:
                    t_params = t_spec.to_params(_gather_cols(teacher))
                else:
                    t_params = teacher
                teachers = jax.vmap(
                    jax.vmap(lambda b: t_loss_fn(t_params, b)[1]))(batches)
            new_stack, losses = update(p_stack, batches, step_masks, teachers)
            # keep only this device's column slice of the updated members:
            # the carry plane, bank rows and aggregate all live column-
            # sharded, so the full-width member plane is transient
            stacked = jax.vmap(spec.to_plane)(new_stack)
            new_plane = (_constrain(stacked, self._pspecs["members"]) if tp
                         else _local_cols(stacked))
            total = jnp.sum(weights) + (jnp.sum(bank_w) if banked else 0.0)
            if axis is not None:
                total = jax.lax.psum(total, axis)
            denom = jnp.where(total > 0.0, total, 1.0)
            local = aggregation.aggregate_plane(new_plane, weights / denom,
                                                use_kernel=use_kernel)
            if banked:
                local = aggregation.merge_buffered_plane(
                    local, bank_p, bank_w / denom, use_kernel=use_kernel)
            agg = jax.lax.psum(local, axis) if axis is not None else local
            g_next = jnp.where(total > 0.0, agg, g)
            if tp:
                g_next = _constrain(g_next, self._pspecs["plane"])
            if maxis is not None:
                # every model column computes identical losses (same batches,
                # same gathered params); the pmean is numerically a no-op
                # that PROVES the model-axis replication the losses
                # out_spec demands
                losses = jax.lax.pmean(losses, maxis)
            return g_next, new_plane, losses

        def _offset(step_masks):
            """Global slot index of this device's first member row."""
            if axis is None:
                return jnp.int32(0)
            return jax.lax.axis_index(axis) * step_masks.shape[0]

        def _xs(r0, teacher):
            rs = r0 + jnp.arange(R, dtype=jnp.int32)
            return (rs, teacher) if t_per_round else rs

        def _trace_ctx():
            """TP activation hints (models/tp.py) are scoped at TRACE time:
            entered inside the jitted function so the member forwards trace
            with the hint context active — exactly and only for TP blocks."""
            return (tp_shard_ctx(self.mesh, self.model_axis) if tp
                    else nullcontext())

        if banked:
            def block_fn(plane, bank_plane, bank_w, shards, n_i,
                         tables, counts, r0, step_masks, weights, bank_gain,
                         teacher):
                off = _offset(step_masks)

                def body(carry, x):
                    g, bp, bw = carry
                    r, t = x if t_per_round else (x, teacher)
                    g2, new_plane, losses = one_round(
                        g, bp, bw, r, shards, n_i, tables, counts,
                        step_masks, weights, t, off)
                    ys = (losses, g2) if want_history else (losses,)
                    return (g2, new_plane, bank_gain), ys
                with _trace_ctx():
                    carry, ys = jax.lax.scan(
                        body, (plane, bank_plane, bank_w), _xs(r0, teacher))
                return carry + tuple(ys)
            donate = (0, 1) if cfg.donate_plane else ()
        else:
            def block_fn(plane, shards, n_i, tables, counts, r0,
                         step_masks, weights, teacher):
                off = _offset(step_masks)

                def body(g, x):
                    r, t = x if t_per_round else (x, teacher)
                    g2, _, losses = one_round(
                        g, None, None, r, shards, n_i, tables, counts,
                        step_masks, weights, t, off)
                    ys = (losses, g2) if want_history else (losses,)
                    return g2, ys
                with _trace_ctx():
                    g, ys = jax.lax.scan(body, plane, _xs(r0, teacher))
                return (g,) + tuple(ys)
            donate = (0,) if cfg.donate_plane else ()

        fn = block_fn
        if tp:
            # GSPMD global view: same argument layout as the shard_map wrap,
            # but expressed as jit in/out shardings — the block body carries
            # the constraints, XLA does the partitioning.
            sp = self._pspecs
            daxis = self.mesh_axis
            def ns(s):
                return NamedSharding(self.mesh, s)

            def named(tree):
                return to_named(self.mesh, tree)
            Pm = ns(P(daxis))
            Pg, Pmm = ns(sp["plane"]), ns(sp["members"])
            t_in = None
            if use_kd:                     # fixed teacher rides as a plane
                t_in = ns(sp["stack"]) if t_per_round else ns(sp["plane"])
            tail = (named(member_specs(pack["shards"], daxis)), Pm,
                    named(member_specs(pack["tables"], daxis)),
                    named(member_specs(pack["counts"], daxis)), None,
                    ns(sp["masks"]), Pm)
            ys_sh = (ns(sp["losses"]),) + ((ns(sp["stack"]),)
                                           if want_history else ())
            if banked:
                in_sh = (Pg, Pmm, Pm) + tail + (Pm, t_in)
                out_sh = (Pg, Pmm, Pm) + ys_sh
            else:
                in_sh = (Pg,) + tail + (t_in,)
                out_sh = (Pg,) + ys_sh
            prog = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=donate)
        elif axis is not None:
            sp = self._pspecs
            Pm, Pr = sp["rows"], P()
            Pg, Pmm = sp["plane"], sp["members"]
            t_in = None
            if use_kd:
                t_in = (sp["stack"] if t_per_round
                        else replicated_specs(teacher_example))
            tail = (member_specs(pack["shards"], axis), Pm,
                    member_specs(pack["tables"], axis),
                    member_specs(pack["counts"], axis), Pr, sp["masks"], Pm)
            ys_specs = (sp["losses"],) + ((sp["stack"],)
                                          if want_history else ())
            if banked:
                in_specs = (Pg, Pmm, Pm) + tail + (Pm, t_in)
                out_specs = (Pg, Pmm, Pm) + ys_specs
            else:
                in_specs = (Pg,) + tail + (t_in,)
                out_specs = (Pg,) + ys_specs
            fn = aggregation._shard_map(block_fn, mesh=self.mesh,
                                        in_specs=in_specs,
                                        out_specs=out_specs)
            prog = jax.jit(fn, donate_argnums=donate)
        else:
            prog = jax.jit(fn, donate_argnums=donate)
        if self.obs.on:
            prog = _TimedProgram(
                prog, self.obs,
                f"dispatch_L{level}_cap{capacity}_R{R}"
                + ("_kd" if use_kd else "") + ("_bank" if banked else ""))
        self._programs[key] = prog
        return self._programs[key]

    def dispatch_rounds(self, level: int, members: list[int], plane, r0: int,
                        n_rounds: int, *, teacher=None, teacher_planes=None,
                        step_masks=None, weights=None, bank=None,
                        want_history: bool = False):
        """Device-resident block dispatch: run ``n_rounds`` rounds fused.

        ``plane`` is the cluster's (D_pad,) parameter plane — it is DONATED
        (with ``donate_plane``): the caller's handle is dead after the call
        and must be replaced by the returned plane.  ``bank`` is the
        buffered-aggregation carry ``(bank_plane (cap, D_pad), bank_w (cap,),
        bank_gain (cap,))``: rows merged into the first round at ``bank_w``,
        each round's member updates re-banked at ``bank_gain`` (zero rows =
        not banked).  The KD teacher is either ``teacher`` (one params
        pytree, fixed for the whole block — the ``FedRAC.train`` path, whose
        master is fully trained first) or ``teacher_planes`` (an
        (n_rounds, D_master) plane stack scanned through the block, one
        teacher per round — the simulator path, where the master co-trains
        and R=1 semantics demand per-round refresh).  Returns a
        ``DispatchOut`` with per-round member losses and, with
        ``want_history``, the per-round planes — the hook that keeps
        telemetry/history exact under fusion.
        """
        cfg = self.cfg
        C = len(members)
        cap = self._capacity(C)
        balanced = cfg.class_balanced and level == 0
        use_kd = cfg.use_kd and (teacher is not None
                                 or teacher_planes is not None)
        t_per_round = use_kd and teacher_planes is not None
        if t_per_round and teacher_planes.shape[0] != n_rounds:
            raise ValueError(
                f"teacher_planes carries {teacher_planes.shape[0]} rounds "
                f"for a {n_rounds}-round block")
        banked = bank is not None
        pack = self._shard_pack(level, members, cap, balanced)
        S = cfg.steps_per_round
        h2d = 0
        if isinstance(weights, jax.Array) and weights.shape == (cap,):
            w = weights                   # pre-padded device array: no copy
        else:
            if weights is None:
                weights = [self.assignment.n_eff.get(pid, 1)
                           for pid in members]
            w = np.zeros(cap, np.float32)
            w[:C] = np.asarray(weights, np.float32)
            h2d += w.nbytes
            w = self.place_member_sharded(jnp.asarray(w))
        if isinstance(step_masks, jax.Array) and step_masks.shape == (cap, S):
            masks = step_masks            # pre-padded device array: no copy
        else:
            masks = np.zeros((cap, S), np.float32)
            masks[:C] = (np.ones((C, S), np.float32) if step_masks is None
                         else np.asarray(step_masks, np.float32))
            h2d += masks.nbytes
            masks = self.place_member_sharded(jnp.asarray(masks))
        prog = self._dispatch_programs(level, use_kd, cap, n_rounds,
                                       balanced, banked, want_history,
                                       t_per_round=t_per_round, pack=pack,
                                       teacher_example=teacher)
        if t_per_round:
            t_arg = teacher_planes
        elif use_kd and self._tp:
            # the TP program consumes the fixed teacher as a TP-layout
            # level-0 plane (its in-program forward is sharded too);
            # convert once per teacher pytree identity
            if (self._t_plane_cache is None
                    or self._t_plane_cache[0] is not teacher):
                self._t_plane_cache = (teacher, self.plane_of(0, teacher))
            t_arg = self._t_plane_cache[1]
        else:
            t_arg = teacher
        tail = (pack["shards"], pack["n"], pack["tables"], pack["counts"],
                jnp.asarray(r0, jnp.int32), masks, w)
        with self.obs.tracer.span("block_exec", cat="fl", level=level,
                                  R=n_rounds, capacity=cap):
            if banked:
                bank_plane, bank_w, bank_gain = bank
                out = prog(plane, bank_plane, bank_w, *tail,
                           jnp.asarray(bank_gain, jnp.float32), t_arg)
                new_plane, bank_out = out[0], (out[1], out[2])
                rest = out[3:]
            else:
                out = prog(plane, *tail, t_arg)
                new_plane, bank_out = out[0], None
                rest = out[1:]
            self.obs.tracer.fence(new_plane)
        losses = rest[0][:, :C]
        history = rest[1] if want_history else None
        if self.obs.on:
            reg = self.obs.registry
            reg.counter("fl/dispatch_blocks").inc()
            reg.counter("fl/dispatch_rounds").inc(n_rounds)
            if h2d:
                reg.counter("fl/h2d_bytes").inc(h2d)
            # per-round member losses are the block's host-bound output
            reg.counter("fl/d2h_bytes").inc(
                losses.size * losses.dtype.itemsize)
            if self.mesh is not None:
                # one psum over the data axis per fused round (see
                # _dispatch_programs) — accounted analytically, since
                # runtime collectives are invisible from inside jit; the
                # HLO cross-check lives in launch/hlo_analysis
                reg.counter("fl/psum_count").inc(n_rounds)
        return DispatchOut(plane=new_plane, losses=losses, bank=bank_out,
                           history=history)

    def _train_cluster(self, level: int, members: list[int], n_rounds: int,
                       test, teacher=None, record_every: int = 1):
        cfg = self.cfg
        key = jax.random.PRNGKey(cfg.seed + level)
        params = self.family.init(key, level)
        if not members:
            return params, []
        if not cfg.vmap_clusters and not (cfg.allow_loop_dispatch
                                          and cfg.rounds_per_dispatch > 1):
            return self._train_cluster_loop(level, members, n_rounds, test,
                                            params, teacher, record_every)
        if cfg.rounds_per_dispatch > 1:
            return self._train_cluster_dispatch(level, members, n_rounds,
                                                test, params, teacher,
                                                record_every)
        history = []
        weights = [self.assignment.n_eff.get(pid, 1) for pid in members]
        for r in range(n_rounds):
            params, _ = self.cluster_round(level, members, params, r,
                                           teacher=teacher, weights=weights)
            if (r + 1) % record_every == 0:
                history.append(self.evaluate(level, params, test))
        return params, history

    def _train_cluster_dispatch(self, level: int, members: list[int],
                                n_rounds: int, test, params, teacher=None,
                                record_every: int = 1):
        """Chunk ``n_rounds`` into blocks of ``rounds_per_dispatch`` fused
        rounds; per-round history stays exact via scan-stacked planes when a
        record boundary falls inside a block."""
        cfg = self.cfg
        R = cfg.rounds_per_dispatch
        spec = self.plane_spec(level)
        plane = self.plane_of(level, params)
        # masks/weights are constant across blocks: pad + transfer once
        cap = self._capacity(len(members))
        weights = np.zeros(cap, np.float32)
        weights[:len(members)] = [self.assignment.n_eff.get(pid, 1)
                                  for pid in members]
        weights = self.place_member_sharded(jnp.asarray(weights))
        masks = self.place_member_sharded(
            jnp.zeros((cap, cfg.steps_per_round), jnp.float32
                      ).at[:len(members)].set(1.0))
        history = []
        r = 0
        while r < n_rounds:
            L = min(R, n_rounds - r)
            rec = [rr for rr in range(r, r + L)
                   if (rr + 1) % record_every == 0]
            want_hist = any(rr != r + L - 1 for rr in rec)
            out = self.dispatch_rounds(level, members, plane, r, L,
                                       teacher=teacher, step_masks=masks,
                                       weights=weights,
                                       want_history=want_hist)
            plane = out.plane
            for rr in rec:
                p = (spec.to_params(out.history[rr - r]) if want_hist
                     else spec.to_params(plane))
                history.append(self.evaluate(level, p, test))
            r += L
        return self.params_of(level, plane), history

    def _train_cluster_loop(self, level: int, members: list[int],
                            n_rounds: int, test, params, teacher=None,
                            record_every: int = 1):
        """Reference per-pid loop (pre-vmap path); kept for the equivalence
        test and benchmarks/bench_sim.py."""
        cfg = self.cfg
        loop_key = ("loop", level, cfg.lr, cfg.kd_T, cfg.kd_alpha)
        if loop_key not in self._programs:
            loss_fn = jax.tree_util.Partial(self.family.loss_and_logits, level)
            t_loss_fn = jax.tree_util.Partial(self.family.loss_and_logits, 0)
            self._programs[loop_key] = (
                jax.jit(lambda tp, batches: jax.vmap(
                    lambda b: t_loss_fn(tp, b)[1])(batches)),
                jax.jit(lambda p, b, tl: local_update(
                    loss_fn, p, b, cfg.lr, teacher_logits=tl,
                    kd_T=cfg.kd_T, kd_alpha=cfg.kd_alpha)),
                jax.jit(lambda p, b: local_update(loss_fn, p, b, cfg.lr)))
        teacher_logits, upd, upd_plain = self._programs[loop_key]

        history = []
        weights = aggregation.normalized_weights(
            [self.assignment.n_eff.get(pid, 1) for pid in members])
        for r in range(n_rounds):
            new_params = []
            for pid in members:
                batches = jax.tree.map(
                    jnp.asarray,
                    self._client_batches(pid, r, cfg.class_balanced and level == 0))
                if teacher is not None and cfg.use_kd:
                    tl = teacher_logits(teacher, batches)
                    p_new, _ = upd(params, batches, tl)
                else:
                    p_new, _ = upd_plain(params, batches)
                new_params.append(p_new)
            stack = jax.tree.map(lambda *xs: jnp.stack(xs), *new_params)
            params = aggregation.aggregate(stack, weights)
            if (r + 1) % record_every == 0:
                history.append(self.evaluate(level, params, test))
        return params, history

    def evaluate(self, level: int, params, test) -> float:
        _, logits = self.family.loss_and_logits(level, params, test)
        return float(jnp.mean((jnp.argmax(logits, -1) == test["y"])))

    def train(self, test, rounds_per_cluster: dict | None = None) -> FedRACResult:
        cfg = self.cfg
        members = self.assignment.members
        n_rounds = {l: (rounds_per_cluster or {}).get(l, cfg.rounds)
                    for l in range(self.m)}
        master_params, hist0 = self._train_cluster(0, members.get(0, []),
                                                   n_rounds[0], test)
        history = {0: hist0}
        final = {0: hist0[-1] if hist0 else 0.0}
        self.master_params = master_params
        self.cluster_params = {0: master_params}
        for level in range(1, self.m):
            mem = members.get(level, [])
            if not mem:
                history[level] = []
                final[level] = float("nan")
                continue
            p, h = self._train_cluster(level, mem, n_rounds[level], test,
                                       teacher=master_params)
            history[level] = h
            final[level] = h[-1] if h else 0.0
            self.cluster_params[level] = p
        accs = [a for a in final.values() if a == a]
        return FedRACResult(
            k_optimal=self.k_optimal, m=self.m, di_values=self.di_values,
            labels=self.labels, assignment=self.assignment, history=history,
            final_acc=final, global_acc=float(np.mean(accs)),
            rounds_used=n_rounds)


def rounds_to_reach(history: list[float], target: float) -> int | None:
    for i, a in enumerate(history):
        if a >= target:
            return i + 1
    return None
