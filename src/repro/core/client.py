"""Client-side local training (vmap-able across the client axis).

``local_update`` runs τ SGD steps over pre-sampled batches via lax.scan; the
per-step mask realizes heterogeneous τ_i inside a uniform program so a whole
cluster of clients trains under one vmap (→ one pjit program on the pod,
clients sharded along the `data` axis).

Supports plain CE, FedProx (proximal term), and master-slave KD (teacher
logits supplied per batch).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.distill import kd_loss


def local_update(loss_fn: Callable, params, batches, lr: float, *,
                 step_mask=None, prox_mu: float = 0.0, global_params=None,
                 teacher_logits=None, kd_T: float = 2.0, kd_alpha: float = 0.3):
    """Run scan over the leading (steps) axis of ``batches``.

    loss_fn(params, batch) -> (loss, logits).  If ``teacher_logits`` (same
    leading steps axis) is given, the KD objective replaces plain CE.
    Returns (new_params, mean_loss).
    """
    g0 = global_params if global_params is not None else params

    def step_loss(p, batch, t_logits):
        if teacher_logits is None:
            loss, _ = loss_fn(p, batch)
        else:
            _, logits = loss_fn(p, batch)
            loss = kd_loss(logits, batch["y"], t_logits, T=kd_T, alpha=kd_alpha)
        if prox_mu > 0.0:
            sq = sum(jnp.sum((a - b.astype(a.dtype)) ** 2)
                     for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(g0)))
            loss = loss + 0.5 * prox_mu * sq
        return loss

    def body(p, xs):
        batch, t_logits, m = xs
        loss, grads = jax.value_and_grad(step_loss)(p, batch, t_logits)
        p = jax.tree.map(
            lambda w, g: (w - (lr * m * g.astype(jnp.float32)).astype(w.dtype)
                          ).astype(w.dtype), p, grads)
        return p, loss * m

    steps = jax.tree.leaves(batches)[0].shape[0]
    mask = jnp.ones((steps,), jnp.float32) if step_mask is None else step_mask
    tl = (teacher_logits if teacher_logits is not None
          else jnp.zeros((steps, 1, 1), jnp.float32))
    params, losses = jax.lax.scan(body, params, (batches, tl, mask))
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return params, jnp.sum(losses) / denom


def make_cluster_update(loss_fn: Callable, lr: float, **kw):
    """vmap local_update over the client axis (params/batches stacked)."""
    fn = partial(local_update, loss_fn, lr=lr, **kw)

    def cluster_update(params_stack, batches_stack, step_masks, teachers=None):
        if teachers is None:
            return jax.vmap(lambda p, b, m: fn(p, b, step_mask=m))(
                params_stack, batches_stack, step_masks)
        return jax.vmap(lambda p, b, m, t: fn(p, b, step_mask=m,
                                              teacher_logits=t))(
            params_stack, batches_stack, step_masks, teachers)

    return jax.jit(cluster_update)
