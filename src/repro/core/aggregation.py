"""FedAvg aggregation of client-stacked WPMs (§III-B).

Two interchangeable implementations (tested equal):
  * ``aggregate``           — tree-mapped weighted sum over the client axis.
  * ``shard_map psum``      — clients sharded along the mesh `data` axis;
    each device reduces its local clients, then one psum finishes the job.
    This is the paper's "upload WPM to server" step realized as an
    all-reduce, and the Pallas ``kernels/fedagg`` kernel is its per-device
    inner loop.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:                                    # jax >= 0.5 top-level export
    _shard_map = jax.shard_map
except AttributeError:                  # jax 0.4.x experimental location
    from jax.experimental.shard_map import shard_map as _shard_map


def aggregate(params_stack, weights):
    """params_stack: pytree with leading client dim C; weights: (C,) summing to 1."""
    w = jnp.asarray(weights)
    return jax.tree.map(
        lambda x: jnp.tensordot(w.astype(x.dtype), x, axes=(0, 0)), params_stack)


def normalized_weights(n_list) -> jnp.ndarray:
    n = jnp.asarray(n_list, dtype=jnp.float32)
    return n / jnp.sum(n)


def aggregate_sharded(mesh, params_stack, weights, axis: str = "data"):
    """Clients sharded along `axis`; returns replicated aggregated params."""
    C = weights.shape[0]

    def local_agg(stack, w):
        local = jax.tree.map(
            lambda x: jnp.tensordot(w.astype(x.dtype), x, axes=(0, 0)), stack)
        return jax.tree.map(lambda x: jax.lax.psum(x, axis), local)

    specs_in = jax.tree.map(lambda _: P(axis), params_stack)
    fn = _shard_map(
        local_agg, mesh=mesh,
        in_specs=(specs_in, P(axis)),
        out_specs=jax.tree.map(lambda _: P(), params_stack))
    return fn(params_stack, weights)


def fedavg_delta(global_params, params_stack, weights):
    """Server update as an aggregated delta (useful with server optimizers)."""
    agg = aggregate(params_stack, weights)
    return jax.tree.map(lambda a, g: a - g, agg, global_params)


# ------------------------------------------------------------ flat plane
# Plane counterparts of the pytree ops above: the dispatch path carries
# cluster parameters as one contiguous (C, D_pad) fp32 buffer (core/plane.py)
# so aggregation is a single contraction with no per-call tree_flatten /
# concatenate / pad.  On TPU the contraction routes through the Pallas
# ``kernels/fedagg`` kernel (the plane length is already block-aligned);
# elsewhere it lowers to one dot.


def _use_fedagg_kernel() -> bool:
    return jax.default_backend() == "tpu"


def aggregate_plane(plane, weights, *, use_kernel: bool | None = None):
    """plane: (C, D) fp32; weights: (C,) raw or normalized → (D,) Σ w_i p_i."""
    w = jnp.asarray(weights, jnp.float32)
    if use_kernel is None:
        use_kernel = _use_fedagg_kernel()
    if use_kernel:
        from repro.kernels.fedagg.ops import aggregate_plane as _kernel_plane
        return _kernel_plane(plane, w, interpret=False)
    return jnp.tensordot(w, plane, axes=(0, 0))


def fedavg_delta_plane(global_plane, plane, weights):
    """Server update as an aggregated delta, on the plane."""
    return aggregate_plane(plane, weights) - global_plane


def merge_buffered_plane(partial_plane, bank_plane, bank_weights):
    """Plane form of ``merge_buffered``: fold banked rows (already normalized
    by the live+buffered total) into a partial plane sum — one contraction,
    no per-contribution tree_map."""
    return partial_plane + aggregate_plane(bank_plane, bank_weights)


# ------------------------------------------------------------ buffered async
def staleness_weights(n_list, age_list, discount: float) -> list[float]:
    """Raw weights for banked (late) contributions: the member's data weight
    n_b geometrically discounted by how many rounds its update sat in the
    buffer — ``discount**age`` with age ≥ 1 (an update banked in round r
    joins round r+1's aggregate at the first discount step)."""
    return [float(n) * discount ** max(1, int(age))
            for n, age in zip(n_list, age_list)]


def merge_buffered(partial, contribs, norm_weights):
    """Fold banked contributions into a partial FedAvg sum.

    ``partial`` is Σ ŵ_i p_i over this round's live members where the ŵ_i
    were normalized by the TOTAL weight (live + buffered), so Σŵ_i < 1;
    adding Σ û_b p_b over the banked params (û_b = norm_weights, also
    normalized by the total) completes a convex combination — one FedAvg
    over live and stale contributors alike."""
    out = partial
    for p, nw in zip(contribs, norm_weights):
        w = float(nw)
        out = jax.tree.map(lambda a, b: a + w * b.astype(a.dtype), out, p)
    return out
