"""FedAvg aggregation of client-stacked WPMs (§III-B).

Two interchangeable implementations (tested equal):
  * ``aggregate``           — tree-mapped weighted sum over the client axis.
  * ``shard_map psum``      — clients sharded along the mesh `data` axis;
    each device reduces its local clients, then one psum finishes the job.
    This is the paper's "upload WPM to server" step realized as an
    all-reduce, and the Pallas ``kernels/fedagg`` kernel is its per-device
    inner loop.

Both exist in flat-plane form too (``aggregate_plane[_sharded]`` etc.): the
dispatch path's (C, D) parameter plane shards along the same ``data`` axis,
and non-divisible member counts ride any mesh via zero-weight padding rows
(``core.plane.pad_member_rows``) instead of a divisibility assert.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:                                    # jax >= 0.5 top-level export
    _shard_map = jax.shard_map
except AttributeError:                  # jax 0.4.x experimental location
    from jax.experimental.shard_map import shard_map as _shard_map


def aggregate(params_stack, weights):
    """params_stack: pytree with leading client dim C; weights: (C,) summing to 1."""
    w = jnp.asarray(weights)
    return jax.tree.map(
        lambda x: jnp.tensordot(w.astype(x.dtype), x, axes=(0, 0)), params_stack)


def normalized_weights(n_list) -> jnp.ndarray:
    """Normalize raw non-negative weights to sum 1 — with a zero-total guard:
    an all-violator round (every live member banked/dropped) has Σn = 0, and
    an unguarded n/Σn would NaN-poison every downstream aggregate/plane.
    The all-zero case returns zeros, which every aggregation in this module
    treats as the partial-aggregation no-op."""
    n = jnp.asarray(n_list, dtype=jnp.float32)
    total = jnp.sum(n)
    return n / jnp.where(total > 0.0, total, 1.0)


def aggregate_sharded(mesh, params_stack, weights, axis: str = "data"):
    """Clients sharded along `axis`; returns replicated aggregated params.

    The client count does not have to divide the mesh axis: the stack is
    padded with zero-weight rows (``core.plane.pad_member_rows`` invariant)
    up to the next multiple, so arbitrary live member counts ride any mesh.
    """
    C = weights.shape[0]
    rows = _plane_rows_for_mesh(mesh, C, axis)
    w = jnp.asarray(weights, jnp.float32)
    if rows != C:
        params_stack = jax.tree.map(
            lambda x: jnp.concatenate(
                [x, jnp.zeros((rows - C,) + x.shape[1:], x.dtype)]),
            params_stack)
        w = jnp.concatenate([w, jnp.zeros((rows - C,), jnp.float32)])

    def local_agg(stack, wl):
        local = jax.tree.map(
            lambda x: jnp.tensordot(wl.astype(x.dtype), x, axes=(0, 0)), stack)
        return jax.tree.map(lambda x: jax.lax.psum(x, axis), local)

    specs_in = jax.tree.map(lambda _: P(axis), params_stack)
    fn = _shard_map(
        local_agg, mesh=mesh,
        in_specs=(specs_in, P(axis)),
        out_specs=jax.tree.map(lambda _: P(), params_stack))
    return fn(params_stack, w)


def fedavg_delta(global_params, params_stack, weights):
    """Server update as an aggregated delta (useful with server optimizers).
    A zero total weight (nobody contributed) yields a ZERO delta — the
    server-step no-op — rather than the poisoned ``-global_params``."""
    w = jnp.asarray(weights, jnp.float32)
    total = jnp.sum(w)
    agg = aggregate(params_stack, weights)
    return jax.tree.map(
        lambda a, g: jnp.where(total > 0.0, a - g, jnp.zeros_like(g)),
        agg, global_params)


# ------------------------------------------------------------ flat plane
# Plane counterparts of the pytree ops above: the dispatch path carries
# cluster parameters as one contiguous (C, D_pad) fp32 buffer (core/plane.py)
# so aggregation is a single contraction with no per-call tree_flatten /
# concatenate / pad.  On TPU the contraction routes through the Pallas
# ``kernels/fedagg`` kernel (the plane length is already block-aligned);
# elsewhere it lowers to one dot.


def _use_fedagg_kernel() -> bool:
    return jax.default_backend() == "tpu"


def aggregate_plane(plane, weights, *, use_kernel: bool | None = None):
    """plane: (C, D) fp32; weights: (C,) raw or normalized → (D,) Σ w_i p_i."""
    w = jnp.asarray(weights, jnp.float32)
    if use_kernel is None:
        use_kernel = _use_fedagg_kernel()
    if use_kernel:
        from repro.kernels.fedagg.ops import aggregate_plane as _kernel_plane
        return _kernel_plane(plane, w, interpret=False)
    return jnp.tensordot(w, plane, axes=(0, 0))


def fedavg_delta_plane(global_plane, plane, weights):
    """Server update as an aggregated delta, on the plane.  Zero total
    weight → zero delta (the server-step no-op), never ``-global_plane``."""
    w = jnp.asarray(weights, jnp.float32)
    return jnp.where(jnp.sum(w) > 0.0,
                     aggregate_plane(plane, w) - global_plane,
                     jnp.zeros_like(global_plane))


def merge_buffered_plane(partial_plane, bank_plane, bank_weights, *,
                         use_kernel: bool | None = None):
    """Plane form of ``merge_buffered``: fold banked rows (already normalized
    by the live+buffered total) into a partial plane sum — one contraction,
    no per-contribution tree_map.  ``use_kernel=False`` forces the plain
    tensordot (required inside GSPMD global-view programs, where the Pallas
    fedagg custom call cannot be partitioned)."""
    return partial_plane + aggregate_plane(bank_plane, bank_weights,
                                           use_kernel=use_kernel)


# ------------------------------------------------------- sharded flat plane
# Multi-device counterparts of the plane ops: the (C, D) member plane is
# sharded along the mesh ``data`` axis, each device contracts its LOCAL
# member rows (the Pallas ``kernels/fedagg`` plane kernel on TPU, one
# tensordot elsewhere — exactly ``aggregate_plane``), and a single psum
# finishes the §III-B "upload WPM to server" all-reduce.  The member count
# never has to divide the mesh axis: rows are padded with zero weights
# (``core.plane.pad_member_rows``), which every weighted contraction
# ignores by construction.


def _plane_rows_for_mesh(mesh, C: int, axis: str) -> int:
    """Smallest row count ≥ C divisible by the mesh ``axis`` size."""
    n = mesh.shape[axis]
    return -(-C // n) * n


def aggregate_plane_sharded(mesh, plane, weights, *, axis: str = "data",
                            model_axis: str | None = None,
                            use_kernel: bool | None = None):
    """plane: (C, D) fp32 sharded along ``axis`` (and, with ``model_axis``,
    column-sharded along it); weights: (C,) raw or normalized → (D,)
    Σ w_i p_i, data-replicated (column-sharded along ``model_axis`` when
    given).  Each device contracts its LOCAL (data, model) subgrid and ONE
    psum over ``axis`` finishes the job — columns never need reduction, so
    the model axis contributes no collective at all."""
    from repro.core.plane import pad_member_rows

    plane, w = pad_member_rows(
        plane, jnp.asarray(weights, jnp.float32),
        _plane_rows_for_mesh(mesh, plane.shape[0], axis))
    D = plane.shape[1]
    m = mesh.shape[model_axis] if model_axis else 1
    pad_d = (-D) % m
    if pad_d:
        # zero columns contract to zero columns — sliced back off below
        plane = jnp.concatenate(
            [plane, jnp.zeros((plane.shape[0], pad_d), plane.dtype)], axis=1)

    def local_agg(p, wl):
        return jax.lax.psum(
            aggregate_plane(p, wl, use_kernel=use_kernel), axis)

    fn = _shard_map(local_agg, mesh=mesh,
                    in_specs=(P(axis, model_axis), P(axis)),
                    out_specs=P(model_axis))
    out = fn(plane, w)
    return out[:D] if pad_d else out


def fedavg_delta_plane_sharded(mesh, global_plane, plane, weights, *,
                               axis: str = "data",
                               model_axis: str | None = None):
    """Sharded server update as an aggregated delta on the plane.  A zero
    total weight yields a zero delta (same guard as ``fedavg_delta``)."""
    w = jnp.asarray(weights, jnp.float32)
    agg = aggregate_plane_sharded(mesh, plane, w, axis=axis,
                                  model_axis=model_axis)
    return jnp.where(jnp.sum(w) > 0.0, agg - global_plane,
                     jnp.zeros_like(global_plane))


def merge_buffered_plane_sharded(mesh, partial_plane, bank_plane,
                                 bank_weights, *, axis: str = "data",
                                 model_axis: str | None = None):
    """Sharded ``merge_buffered_plane``: the banked rows live on the same
    mesh axes as the member plane; their discounted contraction joins the
    partial sum through the same local-reduce + psum-over-``axis`` path."""
    return partial_plane + aggregate_plane_sharded(
        mesh, bank_plane, bank_weights, axis=axis, model_axis=model_axis)


# ------------------------------------------------------------ buffered async
def compress_bank_rows(rows: list, us: list, cap: int, *, obs=None):
    """Fit a banked backlog into ``cap`` carry slots: when membership shrank
    below the backlog (event between dispatch blocks), ALL rows compress
    into ONE weighted-average row.  Σu and Σu·p are preserved exactly, so
    the round-0 bank merge — which only ever sees the products u·p and the
    total — is unchanged.  Returns (rows, us) untouched when they fit.

    ``obs``: optional Observability bundle; counted host-side only (this
    helper is never traced), so the counters see one increment per real
    compression, not per retrace."""
    if len(rows) <= cap:
        return rows, us
    if obs is not None and obs.on:
        obs.registry.counter("agg/bank_compressions").inc()
        obs.registry.counter("agg/bank_rows_compressed").inc(len(rows))
    u = jnp.asarray(us, jnp.float32)
    total = float(u.sum())
    return ([aggregate_plane(jnp.stack(rows), u / total)], [total])


def staleness_weights(n_list, age_list, discount: float) -> list[float]:
    """Raw weights for banked (late) contributions: the member's data weight
    n_b geometrically discounted by how many rounds its update sat in the
    buffer — ``discount**age`` with age ≥ 1 (an update banked in round r
    joins round r+1's aggregate at the first discount step)."""
    return [float(n) * discount ** max(1, int(age))
            for n, age in zip(n_list, age_list)]


def version_staleness_weights(n_list, version_list, current_version: int,
                              discount: float) -> list[float]:
    """Async-server form of :func:`staleness_weights`: staleness is measured
    in *server versions* — the plane version a contribution was computed
    against vs. the version it merges at — instead of banked round-age.  A
    ledger entry tagged ``v`` merging at version ``V`` weighs
    ``n · discount**max(1, V - v)``; with versions advancing one per
    committed round this is numerically identical to the round-age form,
    which is what makes the synchronized-arrival anchor bit-exact."""
    return staleness_weights(
        n_list, [int(current_version) - int(v) for v in version_list],
        discount)


def anchored_merge_weights(anchor_weight: float, us) -> tuple[float, list[float]]:
    """Normalize an anchored stale merge — ``anchor_weight`` is the current
    plane's weight (Σ n_eff of the cluster), ``us`` the raw discounted
    ledger weights — under the ``normalized_weights`` zero-total contract:
    when everything underflows (``discount**lag → 0`` on deeply stale
    entries AND the cluster emptied, so the anchor is 0 too), the anchor
    keeps weight 1 and the ledger gets zeros — a zero delta, never a NaN
    plane."""
    total = float(anchor_weight) + float(sum(us))
    if total <= 0.0:
        return 1.0, [0.0 for _ in us]
    return float(anchor_weight) / total, [float(u) / total for u in us]


def merge_buffered(partial, contribs, norm_weights, *, obs=None):
    """Fold banked contributions into a partial FedAvg sum.

    ``partial`` is Σ ŵ_i p_i over this round's live members where the ŵ_i
    were normalized by the TOTAL weight (live + buffered), so Σŵ_i < 1;
    adding Σ û_b p_b over the banked params (û_b = norm_weights, also
    normalized by the total) completes a convex combination — one FedAvg
    over live and stale contributors alike.  ``obs`` (optional
    Observability bundle) counts merges/rows host-side."""
    if obs is not None and obs.on and contribs:
        obs.registry.counter("agg/bank_merges").inc()
        obs.registry.counter("agg/bank_rows_merged").inc(len(contribs))
    out = partial
    for p, nw in zip(contribs, norm_weights):
        w = float(nw)
        out = jax.tree.map(lambda a, b: a + w * b.astype(a.dtype), out, p)
    return out
