"""Training/communication-time cost model (§III-B1, Eq. 2; §IV-C Eq. 9/10).

T_i = T_i^a · E + T_i^c with
  T_i^a  = flops_per_sample · n_i / (s_i · GFLOPS_PER_GHZ · 1e9)
  T_i^c  = model_bytes · 8 / (r_i · 1e6)          [r_i in Mbps]

On a homogeneous pod the heterogeneity is *simulated* through these terms;
the clustering/assignment math consumes only T_i, so it is unchanged from
the paper (DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.resources import Participant

GFLOPS_PER_GHZ = 8.0      # effective flops per cycle (SIMD MAC units)
EFFICIENCY = 0.3          # achieved fraction of peak on an edge device


def train_time(p: Participant, flops_per_sample: float, E: int,
               n_i: int | None = None) -> float:
    n = p.n_data if n_i is None else n_i
    return flops_per_sample * n * E / (p.s * GFLOPS_PER_GHZ * 1e9 * EFFICIENCY)


def comm_time(p: Participant, model_bytes: float) -> float:
    return model_bytes * 8.0 / (p.r * 1e6)


def round_time(p: Participant, flops_per_sample: float, model_bytes: float,
               E: int, n_i: int | None = None,
               compute_slowdown: float = 1.0) -> float:
    """T_i = T_i^a E + T_i^c.  ``compute_slowdown`` multiplies T_i^a for
    transient device conditions (repro.sim straggler spikes)."""
    return (train_time(p, flops_per_sample, E, n_i) * compute_slowdown
            + comm_time(p, model_bytes))


def train_time_vec(s: np.ndarray, flops_per_sample, E, n,
                   compute_slowdown=1.0) -> np.ndarray:
    """Vectorized T_i^a · E over participant arrays (fleet engine); every
    argument broadcasts, constants identical to ``train_time``."""
    return (flops_per_sample * n * E * compute_slowdown
            / (s * GFLOPS_PER_GHZ * 1e9 * EFFICIENCY))


def comm_time_vec(r: np.ndarray, model_bytes) -> np.ndarray:
    return model_bytes * 8.0 / (r * 1e6)


def round_bytes(model_bytes: float, *, download: bool = True,
                upload: bool = True) -> float:
    """Per-participant traffic in one round: WPM down + WPM up (§III-B).
    A deadline-dropped participant still burned its download."""
    return model_bytes * (float(download) + float(upload))


def total_time_sync(times: np.ndarray, rounds: int) -> float:
    """Eq. 2: per-round time is the straggler's; total = R · max_i T_i."""
    return float(rounds * np.max(times))


def mar_parallel(T_m: float, kappa: float, m: int) -> float:
    """Eq. 9: master then slaves in parallel: (κ^{m-1} + 1) · T_m.
    (m=1: no slave phase — just the master's time.)"""
    if m <= 1:
        return T_m
    return (kappa ** (m - 1) + 1.0) * T_m


def mar_sequential(T_m: float, kappa: float, m: int) -> float:
    """Eq. 10: fully sequential cluster training: Σ_{i=0}^{m-1} κ^i · T_m."""
    return T_m * (1.0 - kappa ** m) / (1.0 - kappa)


def can_accommodate(p: Participant, model_bytes: float,
                    mem_overhead: float = 3.0) -> bool:
    """Memory check: params + grads + optimizer state must fit a_i (GB)."""
    return p.a * 1e9 >= model_bytes * mem_overhead
