"""Participant resource vectors, unit normalization, λ-weighted similarity (§IV-A).

Includes the paper's exact data: Table I (10-participant example) and
Table III (the 40 real participants used in §V-F1) — these anchor the
reproduction tests.

Fleet-scale state is struct-of-arrays: ``Fleet`` holds the whole
population as columnar numpy arrays (pids, an (n, 3) resource matrix,
online/spike/n_data vectors), and ``Participant`` doubles as a thin row
view (``Fleet.participant``) so every object-per-participant call site —
Procedure-2 placement, the cost model, the sim engine — keeps working
while mutations write through to the arrays.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np


@dataclass
class Participant:
    pid: int
    s: float        # processing speed (GHz-equivalents)
    r: float        # transmission rate (Mbps)
    a: float        # available memory (GB)
    n_data: int = 0

    @property
    def vector(self):
        return np.array([self.s, self.r, self.a], dtype=np.float64)


class _FleetRow(Participant):
    """Row view over one ``Fleet`` slot: attribute reads/writes go straight
    to the fleet's arrays, so a view and its fleet can never disagree."""
    __slots__ = ("_fleet", "_i")

    def __init__(self, fleet: "Fleet", i: int):
        object.__setattr__(self, "_fleet", fleet)
        object.__setattr__(self, "_i", int(i))

    @property
    def pid(self) -> int:
        return int(self._fleet.pids[self._i])

    @property
    def s(self) -> float:
        return float(self._fleet.V[self._i, 0])

    @s.setter
    def s(self, v):
        self._fleet.V[self._i, 0] = v

    @property
    def r(self) -> float:
        return float(self._fleet.V[self._i, 1])

    @r.setter
    def r(self, v):
        self._fleet.V[self._i, 1] = v

    @property
    def a(self) -> float:
        return float(self._fleet.V[self._i, 2])

    @a.setter
    def a(self, v):
        self._fleet.V[self._i, 2] = v

    @property
    def n_data(self) -> int:
        return int(self._fleet.n_data[self._i])

    @n_data.setter
    def n_data(self, v):
        self._fleet.n_data[self._i] = v

    def detach(self) -> Participant:
        """A standalone (plain dataclass) copy of this row."""
        return Participant(self.pid, self.s, self.r, self.a, self.n_data)

    def __repr__(self):
        return (f"_FleetRow(pid={self.pid}, s={self.s}, r={self.r}, "
                f"a={self.a}, n_data={self.n_data})")


@dataclass
class Fleet:
    """Struct-of-arrays participant state — the canonical representation at
    fleet scale (10⁴–10⁶ devices).  All arrays share length n; ``V`` columns
    are (s, r, a) in the Table-III units.  ``online``/``spike`` are the
    simulator-facing dynamic state (vectorized engines mutate them with
    whole-array ops; ``HeterogeneitySim`` mutates rows through views)."""
    pids: np.ndarray                 # (n,)  int64
    V: np.ndarray                    # (n,3) float64 — s, r, a columns
    n_data: np.ndarray               # (n,)  int64
    online: np.ndarray = None        # (n,)  bool
    spike: np.ndarray = None         # (n,)  float64 compute-slowdown factor
    _rows: dict = field(default_factory=dict, repr=False)

    def __post_init__(self):
        n = len(self.pids)
        self.pids = np.ascontiguousarray(self.pids, np.int64)
        self.V = np.ascontiguousarray(self.V, np.float64)
        self.n_data = np.ascontiguousarray(self.n_data, np.int64)
        if self.online is None:
            self.online = np.ones(n, bool)
        if self.spike is None:
            self.spike = np.ones(n, np.float64)
        assert self.V.shape == (n, 3)

    @classmethod
    def from_matrix(cls, V: np.ndarray, n_data=None) -> "Fleet":
        n = len(V)
        nd = (np.full(n, 100, np.int64) if n_data is None
              else np.asarray(n_data, np.int64))
        return cls(pids=np.arange(n, dtype=np.int64),
                   V=np.asarray(V, np.float64), n_data=nd)

    @classmethod
    def from_participants(cls, parts: Sequence[Participant]) -> "Fleet":
        return cls(pids=np.array([p.pid for p in parts], np.int64),
                   V=np.stack([p.vector for p in parts]),
                   n_data=np.array([p.n_data for p in parts], np.int64))

    def __len__(self) -> int:
        return len(self.pids)

    def participant(self, i: int) -> Participant:
        """Row view for slot ``i`` (cached: one view object per slot)."""
        if i not in self._rows:
            self._rows[i] = _FleetRow(self, i)
        return self._rows[i]

    def participants(self) -> list:
        """All row views, slot order — a drop-in ``parts`` list."""
        return [self.participant(i) for i in range(len(self))]


def resource_matrix(parts) -> np.ndarray:
    if isinstance(parts, Fleet):
        return parts.V
    return np.stack([p.vector for p in parts])


def unit_normalize(V: np.ndarray) -> np.ndarray:
    """Per-column min-max to [0,1]; constant columns map to 0 (paper §IV-A)."""
    lo, hi = V.min(axis=0), V.max(axis=0)
    span = np.where(hi > lo, hi - lo, 1.0)
    return (V - lo) / span


def similarity_matrix(Vbar: np.ndarray, lam=(1 / 3, 1 / 3, 1 / 3)) -> np.ndarray:
    """S_ij = sqrt(Σ_d λ_d (v_id - v_jd)^2) — λ-weighted Euclidean distance.

    Accumulates per dimension (squared-norm expansion over columns) instead
    of broadcasting an (n, n, 3) diff temp: peak extra memory is two (n, n)
    scratch arrays (~3× lower than the einsum form this replaces).  For the
    3-axis resource vectors the partial sums follow einsum's 2-way-unrolled
    pairwise order — (λ₀d₀² + λ₂d₂²) + λ₁d₁² — so the result is
    bit-identical to the previous implementation on the paper tables."""
    lam = np.asarray(lam, dtype=np.float64)
    assert abs(lam.sum() - 1.0) < 1e-9, "λ must sum to 1 (paper constraint)"

    def sq(d):
        diff = Vbar[:, d, None] - Vbar[None, :, d]
        np.multiply(diff, diff, out=diff)
        diff *= lam[d]
        return diff
    if Vbar.shape[1] == 3:
        acc = sq(0)
        acc += sq(2)
        acc += sq(1)
    else:
        acc = sq(0)
        for d in range(1, Vbar.shape[1]):
            acc += sq(d)
    return np.sqrt(acc, out=acc)


# ----------------------------------------------------------------- paper data
# Table I — 10-participant illustration (Example 2; optimal k = 3).
TABLE_I = np.array([
    [100, 10, 20], [50, 15, 30], [75, 8, 25], [125, 10, 15], [150, 7, 10],
    [110, 10, 25], [125, 15, 20], [80, 10, 10], [75, 15, 20], [50, 10, 30],
], dtype=np.float64)

# Table III — 40 participants [processing GHz, transmission Mbps, memory GB].
TABLE_III = np.array([
    [1.6, 10.88, 8], [2.8, 4.1, 3], [1.1, 1.13, 6], [1.6, 11.45, 3],
    [3.2, 8.9, 3], [2.2, 2, 4], [3.1, 8.7, 1], [1.8, 60, 3],
    [2.7, 8.89, 3], [1.4, 34.5, 8], [1.6, 12.54, 6], [0.8, 1.2, 6],
    [1.3, 28.41, 6], [1.3, 21.9, 3], [3.1, 25.99, 6], [3.2, 19.43, 4],
    [1.0, 20.98, 3], [1.6, 30, 3], [1.0, 12, 2], [2.7, 10, 6],
    [1.6, 40, 1], [1.1, 11.4, 6], [2.5, 25, 6], [2.2, 30, 4],
    [1.6, 9.62, 6], [2.2, 23.27, 6], [1.5, 49.79, 6], [1.7, 37.65, 6],
    [3.1, 15.71, 6], [2.6, 3, 6], [3.1, 18.04, 6], [2.5, 44.13, 6],
    [2.3, 6.5, 6], [2.1, 60.21, 6], [2.1, 61.3, 8], [3.2, 19, 6],
    [2.7, 32.05, 6], [2.9, 6.52, 6], [0.8, 38.8, 6], [2.1, 32, 6],
], dtype=np.float64)

LAMBDA_EQUAL = (1 / 3, 1 / 3, 1 / 3)
LAMBDA_PAPER = (0.4, 0.4, 0.2)      # FastDeepIoT-derived weighting [33]


def participants_from_matrix(V: np.ndarray, n_data=None) -> list[Participant]:
    n_data = n_data if n_data is not None else [100] * len(V)
    return [Participant(i, *V[i], n_data=int(n_data[i])) for i in range(len(V))]
