"""Participant resource vectors, unit normalization, λ-weighted similarity (§IV-A).

Includes the paper's exact data: Table I (10-participant example) and
Table III (the 40 real participants used in §V-F1) — these anchor the
reproduction tests.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np


@dataclass
class Participant:
    pid: int
    s: float        # processing speed (GHz-equivalents)
    r: float        # transmission rate (Mbps)
    a: float        # available memory (GB)
    n_data: int = 0

    @property
    def vector(self):
        return np.array([self.s, self.r, self.a], dtype=np.float64)


def resource_matrix(parts: Sequence[Participant]) -> np.ndarray:
    return np.stack([p.vector for p in parts])


def unit_normalize(V: np.ndarray) -> np.ndarray:
    """Per-column min-max to [0,1]; constant columns map to 0 (paper §IV-A)."""
    lo, hi = V.min(axis=0), V.max(axis=0)
    span = np.where(hi > lo, hi - lo, 1.0)
    return (V - lo) / span


def similarity_matrix(Vbar: np.ndarray, lam=(1 / 3, 1 / 3, 1 / 3)) -> np.ndarray:
    """S_ij = sqrt(Σ_d λ_d (v_id - v_jd)^2) — λ-weighted Euclidean distance."""
    lam = np.asarray(lam, dtype=np.float64)
    assert abs(lam.sum() - 1.0) < 1e-9, "λ must sum to 1 (paper constraint)"
    diff = Vbar[:, None, :] - Vbar[None, :, :]
    return np.sqrt(np.einsum("ijd,d->ij", diff ** 2, lam))


# ----------------------------------------------------------------- paper data
# Table I — 10-participant illustration (Example 2; optimal k = 3).
TABLE_I = np.array([
    [100, 10, 20], [50, 15, 30], [75, 8, 25], [125, 10, 15], [150, 7, 10],
    [110, 10, 25], [125, 15, 20], [80, 10, 10], [75, 15, 20], [50, 10, 30],
], dtype=np.float64)

# Table III — 40 participants [processing GHz, transmission Mbps, memory GB].
TABLE_III = np.array([
    [1.6, 10.88, 8], [2.8, 4.1, 3], [1.1, 1.13, 6], [1.6, 11.45, 3],
    [3.2, 8.9, 3], [2.2, 2, 4], [3.1, 8.7, 1], [1.8, 60, 3],
    [2.7, 8.89, 3], [1.4, 34.5, 8], [1.6, 12.54, 6], [0.8, 1.2, 6],
    [1.3, 28.41, 6], [1.3, 21.9, 3], [3.1, 25.99, 6], [3.2, 19.43, 4],
    [1.0, 20.98, 3], [1.6, 30, 3], [1.0, 12, 2], [2.7, 10, 6],
    [1.6, 40, 1], [1.1, 11.4, 6], [2.5, 25, 6], [2.2, 30, 4],
    [1.6, 9.62, 6], [2.2, 23.27, 6], [1.5, 49.79, 6], [1.7, 37.65, 6],
    [3.1, 15.71, 6], [2.6, 3, 6], [3.1, 18.04, 6], [2.5, 44.13, 6],
    [2.3, 6.5, 6], [2.1, 60.21, 6], [2.1, 61.3, 8], [3.2, 19, 6],
    [2.7, 32.05, 6], [2.9, 6.52, 6], [0.8, 38.8, 6], [2.1, 32, 6],
], dtype=np.float64)

LAMBDA_EQUAL = (1 / 3, 1 / 3, 1 / 3)
LAMBDA_PAPER = (0.4, 0.4, 0.2)      # FastDeepIoT-derived weighting [33]


def participants_from_matrix(V: np.ndarray, n_data=None) -> list[Participant]:
    n_data = n_data if n_data is not None else [100] * len(V)
    return [Participant(i, *V[i], n_data=int(n_data[i])) for i in range(len(V))]
