"""Flat parameter plane: the device-resident currency of the dispatch path.

A cluster's parameters are raveled ONCE at setup into a contiguous fp32
vector padded to a lane-friendly multiple (``PLANE_ALIGN``), so that the
multi-round ``lax.scan`` dispatch, the Pallas ``kernels/fedagg`` weighted
aggregate, ``fedavg_delta`` and the buffered-async merges all operate on a
single ``(capacity, D_pad)`` buffer with no per-call ``tree_flatten`` /
``concatenate`` / ``pad``.  Pytrees reappear only at evaluation/reporting
boundaries (``PlaneSpec.to_params``) and inside the per-member model forward
(where XLA fuses the unravel slices away).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree
from jax.sharding import PartitionSpec as P

# Multiple every plane length is padded to: keeps the Pallas fedagg block
# grid divisible without per-call padding, and matches the 128-lane TPU
# register tile.
PLANE_ALIGN = 128


@dataclass(frozen=True)
class PlaneSpec:
    """Ravel/unravel recipe for one cluster level's parameter pytree."""
    d: int                      # true parameter count
    d_pad: int                  # padded plane length (multiple of PLANE_ALIGN)
    unravel: Callable           # (d,) -> params pytree (jax-traceable)

    def to_plane(self, params) -> jnp.ndarray:
        """params pytree -> (d_pad,) fp32 plane (jax-traceable)."""
        flat, _ = ravel_pytree(params)
        flat = flat.astype(jnp.float32)
        if self.d_pad > self.d:
            flat = jnp.concatenate(
                [flat, jnp.zeros((self.d_pad - self.d,), jnp.float32)])
        return flat

    def to_params(self, plane: jnp.ndarray):
        """(d_pad,) plane -> params pytree (jax-traceable)."""
        return self.unravel(plane[:self.d])


def make_plane_spec(params_template, *, model_size: int = 1) -> PlaneSpec:
    """``model_size`` > 1 column-shards the plane over a mesh ``model``
    axis: D is padded to a multiple of ``model_size × PLANE_ALIGN`` so every
    device's column slice is itself PLANE_ALIGN-aligned and the Pallas
    ``fedagg`` tile grid stays divisible per device."""
    flat, unravel = ravel_pytree(params_template)
    d = flat.shape[0]
    align = PLANE_ALIGN * max(1, int(model_size))
    d_pad = -(-d // align) * align
    return PlaneSpec(d=d, d_pad=d_pad, unravel=unravel)


def plane_specs(data_axis: str = "data", model_axis: str | None = None):
    """PartitionSpecs for every plane-shaped buffer of the dispatch path.

    Mirrors ``launch/sharding.param_specs``' role for the FL plane world:
    one place decides how each buffer splits over the (data, model) mesh.
    Member rows (shard packs, step masks, weights, bank rows) shard along
    ``data_axis``; plane COLUMNS shard along ``model_axis`` when given (the
    2D mesh for member models too large to replicate per device) — the
    global (D,) plane, the (capacity, D) member/bank planes, and (R, D)
    teacher/history stacks all split column-wise, and aggregation contracts
    per-device on the (data, model) subgrid with a psum over ``data`` only
    (columns never need reduction).  ``model_axis=None`` degenerates to the
    1D member-sharded layout (plane replicated)."""
    m = model_axis
    return {
        "plane": P(m) if m else P(),      # (D,) global parameter plane
        "members": P(data_axis, m),       # (capacity, D) member/bank planes
        "stack": P(None, m),              # (R, D) teacher/history stacks
        "rows": P(data_axis),             # (capacity,) weights/gains
        "masks": P(data_axis, None),      # (capacity, S) step masks
        "losses": P(None, data_axis),     # (R, capacity) per-round losses
    }


def pad_member_rows(plane: jnp.ndarray, weights: jnp.ndarray, rows: int):
    """Pad a (C, D) member plane and its (C,) weight vector with zero rows up
    to ``rows`` (jax-traceable).  This is the PR-2 padding invariant applied
    to the member axis: a zero-weight row contributes nothing to any weighted
    contraction, so callers may round C up to whatever divisibility a mesh
    axis (or capacity bucket) demands instead of asserting it."""
    C = plane.shape[0]
    if rows < C:
        raise ValueError(f"cannot pad {C} member rows down to {rows}")
    if rows == C:
        return plane, jnp.asarray(weights, jnp.float32)
    pad = rows - C
    plane = jnp.concatenate(
        [plane, jnp.zeros((pad, plane.shape[1]), plane.dtype)])
    weights = jnp.concatenate(
        [jnp.asarray(weights, jnp.float32), jnp.zeros((pad,), jnp.float32)])
    return plane, weights
