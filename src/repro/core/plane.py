"""Flat parameter plane: the device-resident currency of the dispatch path.

A cluster's parameters are raveled ONCE at setup into a contiguous fp32
vector padded to a lane-friendly multiple (``PLANE_ALIGN``), so that the
multi-round ``lax.scan`` dispatch, the Pallas ``kernels/fedagg`` weighted
aggregate, ``fedavg_delta`` and the buffered-async merges all operate on a
single ``(capacity, D_pad)`` buffer with no per-call ``tree_flatten`` /
``concatenate`` / ``pad``.  Pytrees reappear only at evaluation/reporting
boundaries (``PlaneSpec.to_params``) and inside the per-member model forward
(where XLA fuses the unravel slices away).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree
from jax.sharding import NamedSharding, PartitionSpec as P

# Multiple every plane length is padded to: keeps the Pallas fedagg block
# grid divisible without per-call padding, and matches the 128-lane TPU
# register tile.
PLANE_ALIGN = 128


@dataclass(frozen=True)
class PlaneSpec:
    """Ravel/unravel recipe for one cluster level's parameter pytree."""
    d: int                      # true parameter count
    d_pad: int                  # padded plane length (multiple of PLANE_ALIGN)
    unravel: Callable           # (d,) -> params pytree (jax-traceable)

    def to_plane(self, params) -> jnp.ndarray:
        """params pytree -> (d_pad,) fp32 plane (jax-traceable)."""
        flat, _ = ravel_pytree(params)
        flat = flat.astype(jnp.float32)
        if self.d_pad > self.d:
            flat = jnp.concatenate(
                [flat, jnp.zeros((self.d_pad - self.d,), jnp.float32)])
        return flat

    def to_params(self, plane: jnp.ndarray):
        """(d_pad,) plane -> params pytree (jax-traceable)."""
        return self.unravel(plane[:self.d])


def make_plane_spec(params_template, *, model_size: int = 1) -> PlaneSpec:
    """``model_size`` > 1 column-shards the plane over a mesh ``model``
    axis: D is padded to a multiple of ``model_size × PLANE_ALIGN`` so every
    device's column slice is itself PLANE_ALIGN-aligned and the Pallas
    ``fedagg`` tile grid stays divisible per device."""
    flat, unravel = ravel_pytree(params_template)
    d = flat.shape[0]
    align = PLANE_ALIGN * max(1, int(model_size))
    d_pad = -(-d // align) * align
    return PlaneSpec(d=d, d_pad=d_pad, unravel=unravel)


@dataclass(frozen=True)
class TPPlaneSpec:
    """Tensor-parallel plane recipe: a (d_pad,) plane whose LAYOUT matches
    the mesh ``model``-axis split of every leaf.

    The plane is ``msize`` contiguous chunks of ``d_loc`` entries; chunk
    ``i`` holds shard ``i`` of every TP-sharded leaf (its shard dim split
    ``msize``-ways, shard index moved in front of the leaf's own axes
    before raveling) and a full copy of every replicated leaf.  Sharding
    the flat plane ``P(model)`` therefore places each leaf's shard on
    exactly the device that consumes it: ``to_params`` under GSPMD is a
    chain of *local* reshapes/slices (no collective), unlike the legacy
    row-major ravel whose unravel needs the full plane per device.  The
    cost is that replicated leaves are stored ``msize``× (biases, norms —
    noise next to the sharded matmul weights), and that TP planes are NOT
    byte-compatible with ``PlaneSpec`` planes of the same params: convert
    through pytrees (``to_params``/``to_plane``), never by copying planes
    across layouts.

    All plane algebra stays valid: aggregation/delta/bank merges are linear
    and act identically on every duplicated copy, and ``d_loc`` is padded to
    a PLANE_ALIGN multiple so ``d_pad = msize·d_loc`` keeps the fedagg tile
    grid divisible per device.
    """
    d: int                  # true (unduplicated) parameter count
    d_pad: int              # plane length = msize · d_loc
    msize: int              # model-axis size the layout is built for
    d_loc: int              # per-chunk length (PLANE_ALIGN multiple)
    treedef: object         # params pytree structure
    recs: tuple             # per leaf: (shape, dtype, shard_dim|None,
    #                         chunk offset, per-chunk size)
    axis: str = "model"     # mesh axis name the layout shards along

    def leaf_specs(self):
        """Pytree of per-leaf PartitionSpecs (the family TP rules actually
        honored by the layout — non-divisible leaves already demoted)."""
        leaves = []
        for shape, _, k, _, _ in self.recs:
            sp = [None] * len(shape)
            if k is not None:
                sp[k] = self.axis
            leaves.append(P(*sp))
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def to_plane(self, params) -> jnp.ndarray:
        """params pytree -> (d_pad,) fp32 TP-layout plane (jax-traceable,
        vmap-safe over a leading member axis)."""
        leaves = self.treedef.flatten_up_to(params)
        m = self.msize
        pieces = []
        for leaf, (shape, _, k, _, s_loc) in zip(leaves, self.recs):
            x = jnp.asarray(leaf, jnp.float32)
            if k is None:
                pieces.append(jnp.broadcast_to(x.reshape(1, -1), (m, s_loc)))
            else:
                ck = shape[k] // m
                split = shape[:k] + (m, ck) + shape[k + 1:]
                x = jnp.moveaxis(x.reshape(split), k, 0)
                pieces.append(x.reshape(m, s_loc))
        pad = self.d_loc - sum(r[4] for r in self.recs)
        if pad:
            pieces.append(jnp.zeros((m, pad), jnp.float32))
        return jnp.concatenate(pieces, axis=1).reshape(m * self.d_loc)

    def to_params(self, plane: jnp.ndarray, mesh=None):
        """(d_pad,) plane -> params pytree.  With ``mesh`` (inside a GSPMD
        program) every intermediate carries its sharding constraint so XLA
        keeps the whole chain device-local — each device reads only its own
        chunk; the sliced leaves come out TP-sharded, never replicated."""
        m = self.msize
        x2 = plane.reshape(m, self.d_loc)
        if mesh is not None:
            x2 = jax.lax.with_sharding_constraint(
                x2, NamedSharding(mesh, P(self.axis, None)))
        leaves = []
        for shape, dt, k, off, s_loc in self.recs:
            piece = jax.lax.slice(x2, (0, off), (m, off + s_loc))
            if k is None:
                leaf = piece[0].reshape(shape)
            else:
                ck = shape[k] // m
                split = (m,) + shape[:k] + (ck,) + shape[k + 1:]
                leaf = jnp.moveaxis(piece.reshape(split), 0, k)
                leaf = leaf.reshape(shape)
            leaf = leaf.astype(dt)
            if mesh is not None:
                sp = [None] * len(shape)
                if k is not None:
                    sp[k] = self.axis
                leaf = jax.lax.with_sharding_constraint(
                    leaf, NamedSharding(mesh, P(*sp)))
            leaves.append(leaf)
        return self.treedef.unflatten(leaves)


def _tp_leaf_axis(spec, axis: str):
    """Index of the ``axis``-sharded dim in a PartitionSpec, or None."""
    for i, s in enumerate(spec):
        names = s if isinstance(s, tuple) else (s,)
        if axis in names:
            return i
    return None


def make_tp_plane_spec(params_template, specs, *, msize: int,
                       axis: str = "model") -> TPPlaneSpec:
    """Build the TP plane layout for one level from its params template and
    the family's PartitionSpec pytree (``FLModelFamily.param_specs`` rules —
    typically bridged from ``launch/sharding.tp_specs``).  Leaves whose
    sharded dim is not divisible by ``msize`` are demoted to replicated,
    matching the ``param_specs`` fallback."""
    leaves, treedef = jax.tree_util.tree_flatten(params_template)
    spec_leaves = treedef.flatten_up_to(specs)
    recs = []
    off = 0
    d = 0
    for leaf, spec in zip(leaves, spec_leaves):
        shape = tuple(leaf.shape)
        k = _tp_leaf_axis(spec, axis)
        if k is not None and (k >= len(shape) or shape[k] % msize != 0):
            k = None
        size = int(np.prod(shape)) if shape else 1
        s_loc = size // msize if k is not None else size
        recs.append((shape, jnp.asarray(leaf).dtype, k, off, s_loc))
        off += s_loc
        d += size
    d_loc = -(-off // PLANE_ALIGN) * PLANE_ALIGN
    return TPPlaneSpec(d=d, d_pad=msize * d_loc, msize=msize, d_loc=d_loc,
                       treedef=treedef, recs=tuple(recs), axis=axis)


def plane_specs(data_axis: str = "data", model_axis: str | None = None):
    """PartitionSpecs for every plane-shaped buffer of the dispatch path.

    Mirrors ``launch/sharding.param_specs``' role for the FL plane world:
    one place decides how each buffer splits over the (data, model) mesh.
    Member rows (shard packs, step masks, weights, bank rows) shard along
    ``data_axis``; plane COLUMNS shard along ``model_axis`` when given (the
    2D mesh for member models too large to replicate per device) — the
    global (D,) plane, the (capacity, D) member/bank planes, and (R, D)
    teacher/history stacks all split column-wise, and aggregation contracts
    per-device on the (data, model) subgrid with a psum over ``data`` only
    (columns never need reduction).  ``model_axis=None`` degenerates to the
    1D member-sharded layout (plane replicated)."""
    m = model_axis
    return {
        "plane": P(m) if m else P(),      # (D,) global parameter plane
        "members": P(data_axis, m),       # (capacity, D) member/bank planes
        "stack": P(None, m),              # (R, D) teacher/history stacks
        "rows": P(data_axis),             # (capacity,) weights/gains
        "masks": P(data_axis, None),      # (capacity, S) step masks
        "losses": P(None, data_axis),     # (R, capacity) per-round losses
    }


def pad_member_rows(plane: jnp.ndarray, weights: jnp.ndarray, rows: int):
    """Pad a (C, D) member plane and its (C,) weight vector with zero rows up
    to ``rows`` (jax-traceable).  This is the PR-2 padding invariant applied
    to the member axis: a zero-weight row contributes nothing to any weighted
    contraction, so callers may round C up to whatever divisibility a mesh
    axis (or capacity bucket) demands instead of asserting it."""
    C = plane.shape[0]
    if rows < C:
        raise ValueError(f"cannot pad {C} member rows down to {rows}")
    if rows == C:
        return plane, jnp.asarray(weights, jnp.float32)
    pad = rows - C
    plane = jnp.concatenate(
        [plane, jnp.zeros((pad, plane.shape[1]), plane.dtype)])
    weights = jnp.concatenate(
        [jnp.asarray(weights, jnp.float32), jnp.zeros((pad,), jnp.float32)])
    return plane, weights
