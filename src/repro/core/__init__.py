from repro.core import (aggregation, assignment, baselines, client, clustering,
                        compaction, cost_model, distill, resources, rounds,
                        scaling, server)
