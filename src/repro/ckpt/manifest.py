"""Manifest-backed checkpoint directory: CRC32 validation, atomic
write-then-rename, keep-K rotation, and restore that degrades to the newest
*valid* checkpoint instead of crashing.

Layout::

    <ckpt_dir>/MANIFEST.json            # {"format": 1, "checkpoints": [...]}
    <ckpt_dir>/step_00000004/arrays.ckpt   # msgpack leaves (repro.ckpt.checkpoint)
    <ckpt_dir>/step_00000004/meta.json     # JSON-safe run metadata

Each manifest entry records the byte size and CRC32 of every file in its
step directory, so a SIGKILL mid-write (torn arrays.ckpt), bit rot
(garbage), or a deleted leaf file are all detected *before* deserialization.
Writes land in a dot-prefixed temp directory first and become visible via a
single ``os.replace``; the manifest itself is rewritten the same way — a
reader never observes a half-written checkpoint.

``load_latest`` walks entries newest-first, logs a warning for each invalid
one, and returns the first that passes CRC + decode — the graceful-
degradation contract the fault-injection suite pins down.  A corrupt or
missing manifest falls back to scanning ``step_*`` directories (decode-only
validation).
"""
from __future__ import annotations

import json
import logging
import os
import re
import shutil
import zlib

from repro.ckpt import checkpoint
from repro.ckpt.checkpoint import CheckpointError

log = logging.getLogger("repro.ckpt")

MANIFEST = "MANIFEST.json"
ARRAYS_FILE = "arrays.ckpt"
META_FILE = "meta.json"
MANIFEST_FORMAT = 1


def crc32_file(path: str, chunk: int = 1 << 20) -> int:
    crc = 0
    with open(path, "rb") as f:
        while block := f.read(chunk):
            crc = zlib.crc32(block, crc)
    return crc & 0xFFFFFFFF


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return   # platform without directory fds
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _write_json_atomic(path: str, obj) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=1, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


class CheckpointManager:
    """Versioned run-state checkpoints under one directory (see module doc)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        if keep < 1:
            raise ValueError("keep must be >= 1")
        self.dir = ckpt_dir
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)

    # ------------------------------------------------------------ write path
    def save(self, step: int, meta: dict, arrays: dict) -> str:
        """Atomically write checkpoint ``step`` (JSON-safe ``meta`` + a flat
        ``{name: ndarray}`` payload), update the manifest, rotate old steps.
        Returns the final step-directory path."""
        name = f"step_{step:08d}"
        final = os.path.join(self.dir, name)
        tmp = os.path.join(self.dir, "." + name + ".tmp")
        for stale in (tmp, final):
            if os.path.exists(stale):
                shutil.rmtree(stale)
        os.makedirs(tmp)
        checkpoint.save(os.path.join(tmp, ARRAYS_FILE), arrays)
        _write_json_atomic(os.path.join(tmp, META_FILE), meta)
        files = {fn: {"bytes": os.path.getsize(os.path.join(tmp, fn)),
                      "crc32": crc32_file(os.path.join(tmp, fn))}
                 for fn in (ARRAYS_FILE, META_FILE)}
        os.replace(tmp, final)
        _fsync_dir(self.dir)

        entries = [e for e in self._manifest_entries() if e["step"] != step]
        entries.append({"step": step, "dir": name, "files": files})
        entries.sort(key=lambda e: e["step"])
        entries = entries[-self.keep:]
        _write_json_atomic(os.path.join(self.dir, MANIFEST),
                           {"format": MANIFEST_FORMAT, "checkpoints": entries})
        keep_dirs = {e["dir"] for e in entries}
        for fn in os.listdir(self.dir):
            if (re.match(r"^\.?step_\d+(\.tmp)?$", fn)
                    and fn not in keep_dirs):
                shutil.rmtree(os.path.join(self.dir, fn), ignore_errors=True)
        return final

    # ------------------------------------------------------------ read path
    def _manifest_entries(self) -> list[dict]:
        """Entries from MANIFEST.json (oldest first); scans ``step_*`` dirs
        (entries without CRCs) when the manifest is absent or unreadable."""
        path = os.path.join(self.dir, MANIFEST)
        try:
            with open(path) as f:
                doc = json.load(f)
            entries = list(doc["checkpoints"])
            entries.sort(key=lambda e: int(e["step"]))
            return entries
        except FileNotFoundError:
            pass
        except (OSError, ValueError, KeyError, TypeError) as e:
            log.warning("checkpoint manifest %s unreadable (%s); "
                        "falling back to directory scan", path, e)
        entries = []
        for fn in sorted(os.listdir(self.dir)) if os.path.isdir(self.dir) else []:
            m = re.match(r"^step_(\d+)$", fn)
            if m:
                entries.append({"step": int(m.group(1)), "dir": fn,
                                "files": None})
        return entries

    def steps(self) -> list[int]:
        return [int(e["step"]) for e in self._manifest_entries()]

    def _load_entry(self, entry: dict):
        d = os.path.join(self.dir, entry["dir"])
        files = entry.get("files") or {}
        for fn in (ARRAYS_FILE, META_FILE):
            p = os.path.join(d, fn)
            if not os.path.isfile(p):
                raise CheckpointError(f"{p} missing")
            want = files.get(fn)
            if want is not None:
                size = os.path.getsize(p)
                if size != int(want["bytes"]):
                    raise CheckpointError(
                        f"{p} truncated: {size} bytes (manifest says "
                        f"{want['bytes']})")
                crc = crc32_file(p)
                if crc != int(want["crc32"]):
                    raise CheckpointError(
                        f"{p} corrupt: crc32 {crc:#x} != manifest "
                        f"{int(want['crc32']):#x}")
        try:
            with open(os.path.join(d, META_FILE)) as f:
                meta = json.load(f)
        except (OSError, ValueError) as e:
            raise CheckpointError(f"{d}/{META_FILE} undecodable: {e}") from e
        arrays = checkpoint.restore(os.path.join(d, ARRAYS_FILE))
        return meta, arrays

    def load_step(self, step: int):
        """(meta, arrays) for one exact step; raises ``CheckpointError``."""
        for e in self._manifest_entries():
            if int(e["step"]) == step:
                return self._load_entry(e)
        raise CheckpointError(f"no checkpoint for step {step} in {self.dir}")

    def load_latest(self):
        """(step, meta, arrays) of the newest checkpoint that passes CRC +
        decode validation, or ``None`` when no valid checkpoint exists.
        Invalid newer checkpoints are skipped with a logged warning — never
        an exception."""
        for e in reversed(self._manifest_entries()):
            try:
                meta, arrays = self._load_entry(e)
                return int(e["step"]), meta, arrays
            except CheckpointError as err:
                log.warning("skipping invalid checkpoint step %s: %s",
                            e.get("step"), err)
        return None
