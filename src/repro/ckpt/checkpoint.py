"""msgpack-based pytree checkpointing (offline container: no orbax).

Layout: <dir>/step_<n>.ckpt — a msgpack map {path: {dtype, shape, data}}
using tree paths as stable keys, so restore does not need the live pytree
(but can verify against one).
"""
from __future__ import annotations

import os
import re

import jax
import msgpack
import numpy as np


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def save(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    payload = {}
    for p, leaf in flat:
        arr = np.asarray(leaf)
        payload[_path_str(p)] = {
            "dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes()}
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def restore(path: str, like=None):
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    arrays = {k: np.frombuffer(v["data"], dtype=v["dtype"]).reshape(v["shape"])
              for k, v in payload.items()}
    if like is None:
        return arrays
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = _path_str(p)
        assert key in arrays, f"checkpoint missing {key}"
        a = arrays[key]
        assert list(a.shape) == list(np.shape(leaf)), (key, a.shape, np.shape(leaf))
        leaves.append(a.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_step(ckpt_dir: str, step: int, tree, keep: int = 3) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}.ckpt")
    save(path, tree)
    ckpts = sorted(f for f in os.listdir(ckpt_dir) if re.match(r"step_\d+\.ckpt$", f))
    for old in ckpts[:-keep]:
        os.remove(os.path.join(ckpt_dir, old))
    return path


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.ckpt$", f))]
    return max(steps) if steps else None
