"""msgpack-based pytree checkpointing (offline container: no orbax).

Layout: <dir>/step_<n>.ckpt — a msgpack map {path: {dtype, shape, data}}
using tree paths as stable keys, so restore does not need the live pytree
(but can verify against one).

Failure handling is deliberately strict: every malformed input — truncated
file, undecodable msgpack, missing leaf, byte-count/shape mismatch — raises
``CheckpointError`` (never a bare ``assert``, which vanishes under
``python -O``).  Restored arrays are WRITABLE copies, never read-only
``np.frombuffer`` views: callers feed them straight into donated jax
buffers and in-place numpy state.
"""
from __future__ import annotations

import math
import os
import re

import jax
import msgpack
import numpy as np


class CheckpointError(RuntimeError):
    """A checkpoint file is missing, truncated, corrupt, or does not match
    the requested template.  The manifest layer (``repro.ckpt.manifest``)
    catches this to fall back to an older valid checkpoint."""


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def save(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    payload = {}
    for p, leaf in flat:
        arr = np.asarray(leaf)
        payload[_path_str(p)] = {
            "dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes()}
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _decode_leaf(key: str, rec) -> np.ndarray:
    """One {dtype, shape, data} record -> a WRITABLE numpy array, with the
    byte count checked against the declared dtype/shape (a short read — the
    classic SIGKILL-mid-write artifact — must fail loudly, not reshape)."""
    if (not isinstance(rec, dict)
            or not {"dtype", "shape", "data"} <= set(rec)):
        raise CheckpointError(f"leaf {key!r} is not a {{dtype,shape,data}} "
                              "record")
    try:
        dtype = np.dtype(rec["dtype"])
    except TypeError as e:
        raise CheckpointError(f"leaf {key!r} has bad dtype "
                              f"{rec['dtype']!r}") from e
    shape = tuple(int(s) for s in rec["shape"])
    want = int(math.prod(shape)) * dtype.itemsize
    data = rec["data"]
    if not isinstance(data, (bytes, bytearray)) or len(data) != want:
        raise CheckpointError(
            f"leaf {key!r} truncated/corrupt: {len(data) if data is not None else 0} "
            f"bytes for dtype={dtype} shape={shape} (want {want})")
    # .copy() → writable, independently-owned memory (frombuffer alone
    # returns a read-only view of the msgpack payload)
    return np.frombuffer(data, dtype=dtype).reshape(shape).copy()


def restore(path: str, like=None):
    try:
        with open(path, "rb") as f:
            payload = msgpack.unpackb(f.read(), raw=False)
    except OSError as e:
        raise CheckpointError(f"cannot read checkpoint {path!r}: {e}") from e
    except Exception as e:   # msgpack's unpack errors are library-specific
        raise CheckpointError(f"undecodable checkpoint {path!r}: {e}") from e
    if not isinstance(payload, dict):
        raise CheckpointError(f"checkpoint {path!r} is not a map")
    arrays = {k: _decode_leaf(k, v) for k, v in payload.items()}
    if like is None:
        return arrays
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = _path_str(p)
        if key not in arrays:
            raise CheckpointError(f"checkpoint {path!r} missing leaf {key!r}")
        a = arrays[key]
        if list(a.shape) != list(np.shape(leaf)):
            raise CheckpointError(
                f"leaf {key!r} shape {tuple(a.shape)} != template "
                f"{tuple(np.shape(leaf))}")
        leaves.append(a.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_step(ckpt_dir: str, step: int, tree, keep: int = 3) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}.ckpt")
    save(path, tree)
    ckpts = sorted(f for f in os.listdir(ckpt_dir) if re.match(r"step_\d+\.ckpt$", f))
    for old in ckpts[:-keep]:
        os.remove(os.path.join(ckpt_dir, old))
    return path


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.ckpt$", f))]
    return max(steps) if steps else None
