"""Versioned run-state envelope over ``repro.ckpt.manifest``.

A run-state checkpoint is one ``CheckpointManager`` step whose meta carries
a ``{"run_state": {"version", "kind"}}`` header.  The engines
(``HeterogeneitySim``, ``FleetSim``) own *what* goes in the snapshot —
planes, bank, sampler position, event queue, fleet arrays, metrics tables —
this module owns the envelope: version/kind validation, the save cadence,
and the newest-valid-or-nothing resume read.

``RunCheckpointer`` is the object a launcher hands to an engine::

    ckpt = make_checkpointer("runs/ckpt", every=2, keep=3, resume=True)
    HeterogeneitySim(eng, trace, cfg, checkpoint=ckpt).run(test)

The engine captures a snapshot at every round boundary — every *merge
event* in ``mode="async"``, where per-cluster clocks replace the global
round barrier and the snapshot additionally carries the per-cluster clock
states, server version counters and the in-flight delta ledger under
``meta["async"]`` (same envelope version: the section is additive) —
(cheap host copies; also the graceful-shutdown payload), writes it when
``due()``, and on
``resume`` loads the newest checkpoint that passes CRC + decode + header
validation — a corrupt or truncated newest checkpoint degrades to the
previous valid one with a logged warning, and no valid checkpoint at all
degrades to a from-scratch run, never a crash.
"""
from __future__ import annotations

import logging
from dataclasses import dataclass

from repro.ckpt.checkpoint import CheckpointError
from repro.ckpt.manifest import CheckpointManager

log = logging.getLogger("repro.ckpt")

RUN_STATE_VERSION = 1


def header(kind: str) -> dict:
    return {"version": RUN_STATE_VERSION, "kind": kind}


def check_header(meta: dict, kind: str) -> None:
    """Raise ``CheckpointError`` unless ``meta`` carries a compatible
    run-state header for ``kind``."""
    hdr = meta.get("run_state")
    if not isinstance(hdr, dict):
        raise CheckpointError("checkpoint has no run_state header")
    if hdr.get("version") != RUN_STATE_VERSION:
        raise CheckpointError(
            f"run-state version {hdr.get('version')!r} != "
            f"{RUN_STATE_VERSION} (incompatible checkpoint)")
    if hdr.get("kind") != kind:
        raise CheckpointError(
            f"run-state kind {hdr.get('kind')!r} != {kind!r} "
            "(checkpoint from a different engine)")


@dataclass
class RunCheckpointer:
    """Save cadence + resume switch around a ``CheckpointManager``."""
    manager: CheckpointManager
    every: int = 1
    resume: bool = False

    def due(self, r: int) -> bool:
        """Write a checkpoint at boundary ``r``?  (r counts completed
        rounds — merge events in async mode — so the first eligible
        boundary is r == every.)"""
        return r > 0 and self.every > 0 and r % self.every == 0

    def save(self, r: int, kind: str, meta: dict, arrays: dict) -> str:
        meta = dict(meta)
        meta["run_state"] = header(kind)
        return self.manager.save(r, meta, arrays)

    def load_latest(self, kind: str):
        """Newest (step, meta, arrays) whose header matches ``kind``, or
        ``None`` (degrade-to-fresh-run) when no checkpoint validates.
        Corrupt/foreign checkpoints are skipped with a warning."""
        for step in reversed(self.manager.steps()):
            try:
                meta, arrays = self.manager.load_step(step)
                check_header(meta, kind)
                return step, meta, arrays
            except CheckpointError as e:
                log.warning("skipping checkpoint step %d: %s", step, e)
        return None


def make_checkpointer(ckpt_dir: str, *, every: int = 1, keep: int = 3,
                      resume: bool = False) -> RunCheckpointer:
    return RunCheckpointer(CheckpointManager(ckpt_dir, keep=keep),
                           every=every, resume=resume)
