"""Mixture-of-Experts FFN: top-k router + two dispatch strategies.

* ``dense``    — every expert runs on every token, outputs masked by the
  combine matrix.  Exact top-k semantics (no token dropping); compute scales
  with E, so it is used for reduced smoke configs and as the correctness
  oracle for the capacity path.
* ``capacity`` — GShard/Switch-style grouped dispatch with per-expert capacity
  C = ceil(gs*K/E * capacity_factor).  Compute scales with K (active experts),
  which is what the 235B-A22B roofline must reflect.  Token order within a
  group decides dropping, as in GShard.

Both are einsum-only (no ragged ops) so GSPMD can shard the expert axis
(``cfg.moe_shard == "ep"``) or the expert hidden dim (``"tp"``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init


def init_moe(key, cfg: ModelConfig, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    scale = d ** -0.5
    return {
        "router": dense_init(k1, d, E, dtype, scale=0.02),
        "w_gate": (jax.random.normal(k2, (E, d, f)) * scale).astype(dtype),
        "w_up": (jax.random.normal(k3, (E, d, f)) * scale).astype(dtype),
        "w_down": (jax.random.normal(k4, (E, f, d)) * (f ** -0.5)).astype(dtype),
    }


def _route(p, cfg: ModelConfig, x):
    logits = (x @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, cfg.experts_per_tok)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    return probs, top_w, top_i


def _aux_loss(cfg: ModelConfig, probs, top_i):
    E = cfg.n_experts
    routed = jnp.sum(jax.nn.one_hot(top_i, E, dtype=jnp.float32), axis=-2)
    frac = jnp.mean(routed, axis=tuple(range(routed.ndim - 1)))      # (E,)
    prob_mean = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    return E * jnp.sum(frac / cfg.experts_per_tok * prob_mean)


def _apply_dense(p, cfg: ModelConfig, x):
    E = cfg.n_experts
    probs, top_w, top_i = _route(p, cfg, x)
    combine = jnp.sum(
        top_w[..., None] * jax.nn.one_hot(top_i, E, dtype=jnp.float32), axis=-2
    ).astype(x.dtype)                                                # (B,S,E)
    g = jnp.einsum("bsd,edf->besf", x, p["w_gate"])
    u = jnp.einsum("bsd,edf->besf", x, p["w_up"])
    h = jax.nn.silu(g) * u
    y = jnp.einsum("besf,efd->besd", h, p["w_down"])
    y = jnp.einsum("besd,bse->bsd", y, combine)
    return y, _aux_loss(cfg, probs, top_i)


def _apply_capacity(p, cfg: ModelConfig, x):
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.experts_per_tok
    gs = min(cfg.moe_group, B * S)
    N = B * S
    assert N % gs == 0, (N, gs)
    G = N // gs
    xt = x.reshape(G, gs, d)
    cap = max(4, int(-(-gs * K * cfg.moe_capacity // E)))
    cg = cfg.moe_chunk_groups
    if cg and G > cg and G % cg == 0:
        # scan over group-chunks: only one chunk's dispatch one-hots live
        def chunk_body(aux, xc):
            y, a = _capacity_groups(p, cfg, xc, cap)
            return aux + a, y
        aux, ys = jax.lax.scan(chunk_body, jnp.zeros((), jnp.float32),
                               xt.reshape(G // cg, cg, gs, d))
        return ys.reshape(B, S, d), aux / (G // cg)
    y, aux = _capacity_groups(p, cfg, xt, cap)
    return y.reshape(B, S, d), aux


def _capacity_groups(p, cfg: ModelConfig, xt, cap):
    """xt: (G, gs, d) → (y (G,gs,d), aux)."""
    G, gs, d = xt.shape
    E, K = cfg.n_experts, cfg.experts_per_tok
    probs, top_w, top_i = _route(p, cfg, xt)                         # (G,gs,E/K)
    # token-major queue position per expert
    oh = jax.nn.one_hot(top_i, E, dtype=jnp.int32)                   # (G,gs,K,E)
    flat = oh.reshape(G, gs * K, E)
    pos_in_e = jnp.cumsum(flat, axis=1) - flat                       # (G,gsK,E)
    pos = jnp.sum(pos_in_e * flat, axis=-1)                          # (G,gsK)
    keep = (pos < cap).astype(jnp.float32)
    slot = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[..., None]
    # dispatch: (G, gsK, E, cap) -> fold k back into gs
    disp = flat.astype(jnp.float32)[..., None] * slot[..., None, :]  # (G,gsK,E,cap)
    disp = disp.reshape(G, gs, K, E, cap)
    combine = disp * top_w[..., None, None]                          # weighted
    disp_t = jnp.sum(disp, axis=2).astype(xt.dtype)                  # (G,gs,E,cap)
    comb_t = jnp.sum(combine, axis=2).astype(xt.dtype)
    ein = jnp.einsum("gsec,gsd->gecd", disp_t, xt)                   # (G,E,cap,d)
    g = jnp.einsum("gecd,edf->gecf", ein, p["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", ein, p["w_up"])
    h = jax.nn.silu(g) * u
    y_slots = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    y = jnp.einsum("gsec,gecd->gsd", comb_t, y_slots)
    return y, _aux_loss(cfg, probs, top_i)


def apply_moe(p, cfg: ModelConfig, x):
    """x: (B,S,d) -> (y, load_balance_aux_loss)."""
    if cfg.moe_impl == "capacity":
        return _apply_capacity(p, cfg, x)
    return _apply_dense(p, cfg, x)
