"""Uniform model API over the families: init / loss / forward / cache / decode.

``batch`` layout by family:
  * decoder-only (dense/moe/hybrid/ssm):  {"tokens": (B,S) int32}
  * vlm:     {"tokens": (B,S_txt)}, {"embeds": (B,S_front,d)}  (frontend stub)
  * encdec:  {"tokens": (B,S_tgt)}, {"embeds": (B,S_src,d)}    (frontend stub)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer


def is_encdec(cfg: ModelConfig) -> bool:
    return cfg.family == "encdec"


def init_params(cfg: ModelConfig, key):
    return (encdec if is_encdec(cfg) else transformer).init_params(cfg, key)


def forward(cfg: ModelConfig, params, batch):
    if is_encdec(cfg):
        return encdec.forward(cfg, params, batch["tokens"], embeds=batch["embeds"])
    return transformer.forward(cfg, params, batch.get("tokens"),
                               embeds=batch.get("embeds"))


def loss_fn(cfg: ModelConfig, params, batch):
    """Returns (total_loss, ce) — next-token CE (+ MoE aux)."""
    if is_encdec(cfg):
        logits, _ = encdec.forward(cfg, params, batch["tokens"], embeds=batch["embeds"])
        lg = logits[:, :-1].astype(jnp.float32)
        lbl = batch["tokens"][:, 1:]
        lg = jnp.where(transformer.vocab_mask(cfg)[None, None], lg,
                       -2.0 ** 30)
        lse = jax.nn.logsumexp(lg, axis=-1)
        picked = jnp.take_along_axis(lg, lbl[..., None], axis=-1)[..., 0]
        ce = jnp.mean(lse - picked)
        return ce, ce
    return transformer.next_token_loss(cfg, params, batch["tokens"],
                                       embeds=batch.get("embeds"))


def init_cache(cfg: ModelConfig, batch: int, max_len: int, src_len: int = 0):
    if is_encdec(cfg):
        return encdec.init_cache(cfg, batch, max_len, src_len or max_len // 8)
    return transformer.init_cache(cfg, batch, max_len)


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    return (encdec if is_encdec(cfg) else transformer).decode_step(
        cfg, params, cache, token, pos)


def param_count(params) -> int:
    return int(sum(p.size for p in jax.tree.leaves(params)))
