"""Selective SSM (Mamba-1) block: chunked associative-scan prefill, O(1) decode.

TPU adaptation (vs the CUDA selective-scan kernel): the recurrence
``h_t = exp(dt_t A) h_{t-1} + (dt_t B_t) x_t`` is a first-order linear
recurrence, so prefill/train uses ``jax.lax.associative_scan`` inside
fixed-size chunks (VMEM-friendly working set, MXU-shaped einsums) with the
inter-chunk carry threaded through ``jax.lax.scan``.  Decode keeps the
``(B, d_inner, state)`` hidden plus a (conv_k-1)-deep conv buffer in the cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

CHUNK = 128


def init_mamba(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 6)
    d, di, st, dtr, ck = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    # S4D-real initialization for A
    a_init = jnp.broadcast_to(jnp.arange(1, st + 1, dtype=jnp.float32), (di, st))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (ck, di)) * (ck ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dtr + 2 * st, dtype),
        "dt_proj": dense_init(ks[3], dtr, di, dtype),
        "dt_bias": jnp.full((di,), -2.0, dtype),   # softplus^-1(~0.12)
        "A_log": jnp.log(a_init).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[4], di, d, dtype),
    }


def _ssm_inputs(p, cfg: ModelConfig, xc):
    """xc: post-conv activations (B,S,di) -> dt (B,S,di), Bm/Cm (B,S,st)."""
    st, dtr = cfg.ssm_state, cfg.dt_rank
    proj = xc @ p["x_proj"]
    dt, Bm, Cm = jnp.split(proj, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])
    return dt, Bm, Cm


def _causal_conv(x, w, b):
    K, S = w.shape[0], x.shape[1]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(pad[:, i:i + S] * w[i] for i in range(K))
    return jax.nn.silu(y + b)


def mamba_forward(p, cfg: ModelConfig, x):
    """x: (B,S,d) -> (B,S,d). Full-sequence (train/prefill)."""
    B, S, _ = x.shape
    di, st = cfg.d_inner, cfg.ssm_state
    xz = x @ p["in_proj"]
    xm, z = jnp.split(xz, 2, axis=-1)
    xc = _causal_conv(xm, p["conv_w"], p["conv_b"])
    dt, Bm, Cm = _ssm_inputs(p, cfg, xc)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (di,st)

    chunk = min(CHUNK, S)
    assert S % chunk == 0
    nc = S // chunk

    def chunk_body(h, inputs):
        xc_c, dt_c, B_c, C_c = inputs                             # (B,L,...)
        dtf = dt_c.astype(jnp.float32)
        a = jnp.exp(dtf[..., None] * A)                           # (B,L,di,st)
        b = (dtf * xc_c.astype(jnp.float32))[..., None] * B_c.astype(jnp.float32)[:, :, None, :]
        def comb(l, r):
            al, bl = l
            ar, br = r
            return ar * al, ar * bl + br
        aa, bb = jax.lax.associative_scan(comb, (a, b), axis=1)
        h_all = aa * h[:, None] + bb                              # (B,L,di,st)
        y = jnp.einsum("blds,bls->bld", h_all, C_c.astype(jnp.float32))
        return h_all[:, -1], y

    h0 = jnp.zeros((B, di, st), jnp.float32)
    resh = lambda t: t.reshape(B, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
    _, ys = jax.lax.scan(chunk_body, h0, (resh(xc), resh(dt), resh(Bm), resh(Cm)))
    y = ys.swapaxes(0, 1).reshape(B, S, di)
    y = y.astype(x.dtype) + xc * p["D"]
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"]


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
    }


def mamba_decode(p, cfg: ModelConfig, cache, x, pos):
    """x: (B,1,d). Returns (y, cache)."""
    del pos
    B = x.shape[0]
    xz = x[:, 0] @ p["in_proj"]
    xm, z = jnp.split(xz, 2, axis=-1)                             # (B,di)
    w = p["conv_w"]
    K = w.shape[0]
    buf = cache["conv"]                                           # (B,K-1,di)
    conv = sum(buf[:, i] * w[i] for i in range(K - 1)) + xm * w[K - 1]
    xc = jax.nn.silu(conv + p["conv_b"])
    new_buf = jnp.concatenate([buf[:, 1:], xm[:, None].astype(buf.dtype)], axis=1)
    dt, Bm, Cm = _ssm_inputs(p, cfg, xc[:, None])
    dt, Bm, Cm = dt[:, 0], Bm[:, 0], Cm[:, 0]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dtf = dt.astype(jnp.float32)
    a = jnp.exp(dtf[..., None] * A)                               # (B,di,st)
    b = (dtf * xc.astype(jnp.float32))[..., None] * Bm.astype(jnp.float32)[:, None, :]
    h = a * cache["h"] + b
    y = jnp.einsum("bds,bs->bd", h, Cm.astype(jnp.float32)).astype(x.dtype)
    y = y + xc * p["D"]
    y = y * jax.nn.silu(z)
    y = (y @ p["out_proj"])[:, None]
    return y, {"h": h, "conv": new_buf}
