"""Shared neural-net layers: norms, RoPE / M-RoPE, MLPs, init helpers.

All layers are pure functions over explicit parameter pytrees (dicts of jnp
arrays) so they compose with jax.lax.scan over stacked superblock parameters,
pjit parameter sharding, and the Fed-RAC client-stacked vmap.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.tp import shard_hint


# --------------------------------------------------------------------------- init
def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d_model: int, dtype):
    return (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)


# --------------------------------------------------------------------------- norms
def init_norm(cfg: ModelConfig, d: int, dtype):
    if cfg.norm_type == "nonparam_ln":            # olmo: no learnable affine
        return {}
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype)}       # rmsnorm


def apply_norm(cfg: ModelConfig, p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm_type in ("layernorm", "nonparam_ln"):
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        if cfg.norm_type == "layernorm":
            y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
        return y.astype(x.dtype)
    # rmsnorm
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale, x, eps: float = 1e-6):
    """Per-head RMSNorm over the last (head_dim) axis — qwen3 qk_norm."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                      # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    ang = ang[..., None, :]                                     # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype)], axis=-1)


def apply_mrope(x, positions3, theta: float, sections):
    """Qwen2-VL multimodal RoPE.

    x: (..., S, H, hd); positions3: (3, ..., S) — temporal/height/width position
    streams.  ``sections`` partitions the half-dim; section ``i`` rotates with
    position stream ``i`` (text tokens carry identical streams, reducing to 1-D
    RoPE, which is the fidelity anchor we test).
    """
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                      # (half,)
    # Build a (..., S, half) angle tensor by selecting the stream per section.
    idx = []
    for i, s in enumerate(sections):
        idx.extend([i] * s)
    sel = jnp.asarray(idx)                                      # (half,)
    pos = jnp.take(positions3, sel, axis=0)                     # (half, ..., S)
    pos = jnp.moveaxis(pos, 0, -1)                              # (..., S, half)
    ang = pos.astype(jnp.float32) * freqs
    ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype)], axis=-1)


# --------------------------------------------------------------------------- mlp
def init_mlp(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def apply_mlp(p, x):
    # TP hint: column-parallel w_gate/w_up leave the FFN hidden sharded;
    # the row-parallel w_down contraction is the layer's one all-reduce
    g = jax.nn.silu(shard_hint(x @ p["w_gate"], -1))
    return (g * shard_hint(x @ p["w_up"], -1)) @ p["w_down"]


def softcap(x, cap: float):
    return jnp.tanh(x / cap) * cap if cap > 0 else x
