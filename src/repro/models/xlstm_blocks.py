"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory) [arXiv:2405.04517].

Both cells are exponential-gated with the max-stabilizer ``m_t``.  The mLSTM
matrix memory ``C_t = f_t C_{t-1} + i_t v_t k_t^T`` and the sLSTM recurrence
run as ``jax.lax.scan`` over time (single HLO while-loop — depth-independent
program size).  A chunkwise-parallel mLSTM is a §Perf candidate recorded in
EXPERIMENTS.md (recurrent-scan → chunk-parallel is the canonical TPU
adaptation of this family).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init


# ------------------------------------------------------------------ mLSTM
def init_mlstm(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    di = cfg.mlstm_expand * d
    H = cfg.n_heads
    return {
        "up": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (4, di)) * 0.5).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": dense_init(ks[2], di, di, dtype),
        "wk": dense_init(ks[3], di, di, dtype),
        "wv": dense_init(ks[4], di, di, dtype),
        "w_if": dense_init(ks[5], di, 2 * H, dtype, scale=0.02),
        "b_if": jnp.concatenate([jnp.zeros((H,)), jnp.full((H,), 3.0)]).astype(dtype),
        "skip": jnp.ones((di,), dtype),
        "down": dense_init(ks[6], di, d, dtype),
    }


def _mlstm_cell(carry, qkvif):
    """One timestep.  carry: (C,n,m); q,k,v: (B,H,hd); i,f: (B,H)."""
    C, n, m = carry
    q, k, v, it, ft = qkvif
    logf = jax.nn.log_sigmoid(ft)                       # (B,H)
    m_new = jnp.maximum(logf + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(logf + m - m_new)
    C = f_p[..., None, None] * C + i_p[..., None, None] * (v[..., :, None] * k[..., None, :])
    n = f_p[..., None] * n + i_p[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.sum(n * q, axis=-1)), jnp.exp(-m_new)) + 1e-6
    h = jnp.einsum("bhvk,bhk->bhv", C, q) / denom[..., None]
    return (C, n, m_new), h


def _mlstm_qkvif(p, cfg: ModelConfig, xm):
    """xm: (B,S,di) pre-conv input half. Returns per-step tensors."""
    B, S, di = xm.shape
    H = cfg.n_heads
    hd = di // H
    K = p["conv_w"].shape[0]
    pad = jnp.pad(xm, ((0, 0), (K - 1, 0), (0, 0)))
    xc = sum(pad[:, i:i + S] * p["conv_w"][i] for i in range(K))
    xc = jax.nn.silu(xc + p["conv_b"])
    q = (xc @ p["wq"]).reshape(B, S, H, hd)
    k = (xc @ p["wk"]).reshape(B, S, H, hd) * (hd ** -0.5)
    v = (xm @ p["wv"]).reshape(B, S, H, hd)
    gate = (xm @ p["w_if"]).astype(jnp.float32) + p["b_if"].astype(jnp.float32)
    it, ft = gate[..., :H], gate[..., H:]
    return q, k, v, it, ft, xc


def _mlstm_seq(cfg: ModelConfig, q, k, v, it, ft, B, S, H, hd):
    f32 = jnp.float32
    carry = (jnp.zeros((B, H, hd, hd), f32), jnp.zeros((B, H, hd), f32),
             jnp.full((B, H), -1e30, f32))
    sw = lambda t: jnp.moveaxis(t, 1, 0)
    _, hs = jax.lax.scan(
        _mlstm_cell, carry,
        (sw(q.astype(f32)), sw(k.astype(f32)), sw(v.astype(f32)), sw(it), sw(ft)))
    return jnp.moveaxis(hs, 0, 1)                            # (B,S,H,hd)


def _mlstm_chunked(cfg: ModelConfig, q, k, v, it, ft, B, S, H, hd,
                   chunk: int = 64):
    """Chunkwise-parallel mLSTM — exact same math as the sequential cell,
    but the recurrence only crosses CHUNK boundaries; within a chunk the
    contributions are an (L,L) masked matrix product (MXU-shaped).  This is
    the TPU-native adaptation of the paper-family's CUDA recurrence
    (DESIGN.md §2; §Perf beyond-paper entry).

    Per chunk with F_j = Σ_{r≤j} logσ(f_r):
      intra:  D_{jk} = F_j - F_k + i_k          (k ≤ j)
      inter:  g_j    = F_j + m_prev             (decayed carry)
      m_j    = max(max_k D_{jk}, g_j)
      num_j  = e^{g_j-m_j}(q_j C_prev) + Σ_k e^{D_{jk}-m_j}(q_j·k_k) v_k
      den_j  = e^{g_j-m_j}(q_j·n_prev) + Σ_k e^{D_{jk}-m_j}(q_j·k_k)
      h_j    = num_j / max(|den_j|, e^{-m_j})
    Carry update uses the same statistics at j = L.
    """
    f32 = jnp.float32
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L
    resh = lambda t: jnp.moveaxis(
        t.reshape(B, nc, L, *t.shape[2:]), 1, 0).astype(f32)
    qs, ks, vs = resh(q), resh(k), resh(v)                   # (nc,B,L,H,hd)
    its, fts = resh(it), resh(ft)                            # (nc,B,L,H)

    def chunk_body(carry, xs):
        C, n, m = carry                                      # (B,H,hd,hd) ...
        qc, kc, vc, ic, fc = xs
        lf = jax.nn.log_sigmoid(fc)                          # (B,L,H)
        F = jnp.cumsum(lf, axis=1)                           # F_j
        D = (F[:, :, None] - F[:, None, :]                   # (B,L,L,H)
             + ic[:, None, :, :])
        mask = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
        D = jnp.where(mask, D, -jnp.inf)
        g = F + m[:, None]                                   # (B,L,H)
        m_j = jnp.maximum(jnp.max(D, axis=2), g)             # (B,L,H)
        w = jnp.exp(D - m_j[:, :, None])                     # (B,L,L,H)
        qk = jnp.einsum("blhd,bkhd->blkh", qc, kc)           # (B,L,L,H)
        num_intra = jnp.einsum("blkh,blkh,bkhd->blhd", w, qk, vc)
        den_intra = jnp.einsum("blkh,blkh->blh", w, qk)
        dec = jnp.exp(g - m_j)                               # (B,L,H)
        num_inter = jnp.einsum("blh,bhvk,blhk->blhv", dec, C, qc)
        den_inter = dec * jnp.einsum("bhk,blhk->blh", n, qc)
        num = num_intra + num_inter
        den = den_intra + den_inter
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_j))[..., None]
        # carry update at j = L
        FL = F[:, -1]                                        # (B,H)
        m_new = jnp.maximum(FL + m, jnp.max(FL[:, None] - F + ic, axis=1))
        wL = jnp.exp(FL[:, None] - F + ic - m_new[:, None])  # (B,L,H)
        C_new = (jnp.exp(FL + m - m_new)[..., None, None] * C
                 + jnp.einsum("blh,blhv,blhk->bhvk", wL, vc, kc))
        n_new = (jnp.exp(FL + m - m_new)[..., None] * n
                 + jnp.einsum("blh,blhk->bhk", wL, kc))
        return (C_new, n_new, m_new), h

    carry = (jnp.zeros((B, H, hd, hd), f32), jnp.zeros((B, H, hd), f32),
             jnp.full((B, H), -1e30, f32))
    _, hs = jax.lax.scan(chunk_body, carry, (qs, ks, vs, its, fts))
    return jnp.moveaxis(hs, 0, 1).reshape(B, S, H, hd)


def mlstm_forward(p, cfg: ModelConfig, x):
    B, S, d = x.shape
    di = cfg.mlstm_expand * d
    H = cfg.n_heads
    hd = di // H
    xz = x @ p["up"]
    xm, z = jnp.split(xz, 2, axis=-1)
    q, k, v, it, ft, xc = _mlstm_qkvif(p, cfg, xm)
    if cfg.mlstm_impl == "chunk" and S > 1:
        hs = _mlstm_chunked(cfg, q, k, v, it, ft, B, S, H, hd)
    else:
        hs = _mlstm_seq(cfg, q, k, v, it, ft, B, S, H, hd)
    h = hs.reshape(B, S, di).astype(x.dtype)
    h = h + p["skip"] * xc
    h = h * jax.nn.silu(z)
    return h @ p["down"]


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype):
    di = cfg.mlstm_expand * cfg.d_model
    H = cfg.n_heads
    hd = di // H
    f32 = jnp.float32
    return {
        "C": jnp.zeros((batch, H, hd, hd), f32),
        "n": jnp.zeros((batch, H, hd), f32),
        "m": jnp.full((batch, H), -1e30, f32),
        "conv": jnp.zeros((batch, 3, di), dtype),
    }


def mlstm_decode(p, cfg: ModelConfig, cache, x, pos):
    del pos
    B, _, d = x.shape
    di = cfg.mlstm_expand * d
    H = cfg.n_heads
    hd = di // H
    xz = x[:, 0] @ p["up"]
    xm, z = jnp.split(xz, 2, axis=-1)
    w = p["conv_w"]
    K = w.shape[0]
    buf = cache["conv"]
    conv = sum(buf[:, i] * w[i] for i in range(K - 1)) + xm * w[K - 1]
    xc = jax.nn.silu(conv + p["conv_b"])
    new_buf = jnp.concatenate([buf[:, 1:], xm[:, None].astype(buf.dtype)], axis=1)
    f32 = jnp.float32
    q = (xc @ p["wq"]).reshape(B, H, hd).astype(f32)
    k = ((xc @ p["wk"]) * (hd ** -0.5)).reshape(B, H, hd).astype(f32)
    v = (xm @ p["wv"]).reshape(B, H, hd).astype(f32)
    gate = (xm @ p["w_if"]).astype(f32) + p["b_if"].astype(f32)
    it, ft = gate[..., :H], gate[..., H:]
    (C, n, m), h = _mlstm_cell((cache["C"], cache["n"], cache["m"]), (q, k, v, it, ft))
    h = h.reshape(B, di).astype(x.dtype)
    h = h + p["skip"] * xc
    h = h * jax.nn.silu(z)
    return (h @ p["down"])[:, None], {"C": C, "n": n, "m": m, "conv": new_buf}


# ------------------------------------------------------------------ sLSTM
def init_slstm(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    pf = -(-int(cfg.slstm_proj * d) // 128) * 128    # MXU/mesh aligned
    return {
        "wx": dense_init(ks[0], d, 4 * d, dtype),
        # recurrent weights, block-diagonal per head: (H, hd, 4*hd)
        "r": (jax.random.normal(ks[1], (H, hd, 4 * hd)) * (hd ** -0.5)).astype(dtype),
        "b": jnp.zeros((4 * d,), dtype),
        "up_g": dense_init(ks[2], d, pf, dtype),
        "up_v": dense_init(ks[3], d, pf, dtype),
        "down": dense_init(ks[4], pf, d, dtype),
    }


def _slstm_cell(p, cfg: ModelConfig, carry, xg):
    """carry: (c,n,h,m) each (B,H,hd) / m:(B,H,hd). xg: (B,4d) pre-activations."""
    c, n, h, m = carry
    B = xg.shape[0]
    H = cfg.n_heads
    hd = cfg.d_model // H
    rec = jnp.einsum("bhd,hdk->bhk", h, p["r"].astype(jnp.float32))  # (B,H,4hd)
    g = xg.reshape(B, H, 4 * hd).astype(jnp.float32) + rec
    zt, it, ft, ot = jnp.split(g, 4, axis=-1)                        # (B,H,hd)
    z = jnp.tanh(zt)
    o = jax.nn.sigmoid(ot)
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(logf + m - m_new)
    c = f_p * c + i_p * z
    n = f_p * n + i_p
    h_new = o * c / jnp.maximum(n, 1e-6)
    return (c, n, h_new, m_new)


def slstm_forward(p, cfg: ModelConfig, x):
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    xg = x @ p["wx"] + p["b"]
    f32 = jnp.float32
    carry = (jnp.zeros((B, H, hd), f32), jnp.zeros((B, H, hd), f32),
             jnp.zeros((B, H, hd), f32), jnp.full((B, H, hd), -1e30, f32))

    def step(carry, xg_t):
        new = _slstm_cell(p, cfg, carry, xg_t)
        return new, new[2]

    _, hs = jax.lax.scan(step, carry, jnp.moveaxis(xg, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    # post-up/down projection (GeGLU, factor slstm_proj)
    return (jax.nn.gelu(h @ p["up_g"]) * (h @ p["up_v"])) @ p["down"]


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype):
    H = cfg.n_heads
    hd = cfg.d_model // H
    f32 = jnp.float32
    z = lambda: jnp.zeros((batch, H, hd), f32)
    return {"c": z(), "n": z(), "h": z(), "m": jnp.full((batch, H, hd), -1e30, f32)}


def slstm_decode(p, cfg: ModelConfig, cache, x, pos):
    del pos
    B, _, d = x.shape
    xg = x[:, 0] @ p["wx"] + p["b"]
    carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, n, h, m = _slstm_cell(p, cfg, carry, xg)
    hh = h.reshape(B, d).astype(x.dtype)
    y = (jax.nn.gelu(hh @ p["up_g"]) * (hh @ p["up_v"])) @ p["down"]
    return y[:, None], {"c": c, "n": n, "h": h, "m": m}
