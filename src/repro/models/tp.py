"""Tensor-parallel activation hints for the model zoo.

The FL dispatch path compiles member forwards inside ONE GSPMD global-view
program (``core/server._dispatch_programs`` with ``tp_forward``), where the
parameters are already TP-sharded by ``core.plane.TPPlaneSpec``.  GSPMD
propagates shardings from the weights on its own, but the model code can do
better than propagation at the classic Megatron cut points — the head axis
of q/k/v, the FFN hidden, the vocab-parallel logits — and only the model
code knows where those are.  This module carries that knowledge without
threading a mesh through every forward signature: the server enters
``tp_shard_ctx`` around the block trace, and ``shard_hint`` becomes a
``with_sharding_constraint`` exactly there (a no-op everywhere else:
single-device tests, the legacy shard_map path, the launch dry-run which
has its own pjit specs).

Hints are advisory and shape-guarded: a dim that does not divide the mesh
axis is silently left unconstrained, mirroring the replication fallback of
``launch/sharding.tp_specs``.
"""
from __future__ import annotations

from contextlib import contextmanager

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX: "tuple | None" = None        # (mesh, model-axis name) or None


@contextmanager
def tp_shard_ctx(mesh, axis: str):
    """Activate TP hints for code traced within this block (trace-time
    scoping: enter it inside the function being jitted)."""
    global _CTX
    prev = _CTX
    _CTX = (mesh, axis)
    try:
        yield
    finally:
        _CTX = prev


def tp_ctx():
    """The active (mesh, axis) TP context, or None."""
    return _CTX


def shard_hint(x, dim: int):
    """Constrain ``x``'s dimension ``dim`` to the TP model axis when a
    context is active and the dim divides the axis size; identity
    otherwise.  Safe under vmap (the batched dim stays unconstrained)."""
    c = _CTX
    if c is None:
        return x
    mesh, axis = c
    d = dim if dim >= 0 else x.ndim + dim
    if x.shape[d] % mesh.shape[axis] != 0:
        return x
    sp = [None] * x.ndim
    sp[d] = axis
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*sp)))
