"""Encoder-decoder backbone (seamless-m4t-medium's text/unit transformer).

The audio frontend (mel + conformer feature extractor) is STUBBED per the
assignment brief: ``input_specs`` feeds precomputed frame embeddings
``(B, S_src, d)``.  The encoder is bidirectional; the decoder is causal with
cross-attention.  Decode carries a self-attention KV cache plus the static
cross-attention K/V built once from the encoder output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (apply_mlp, apply_norm, embed_init, init_mlp,
                                 init_norm, softcap)


def _init_enc_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {"norm1": init_norm(cfg, cfg.d_model, dtype),
            "attn": attn.init_attn(k1, cfg, dtype),
            "norm2": init_norm(cfg, cfg.d_model, dtype),
            "ffn": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)}


def _init_dec_block(key, cfg: ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"norm1": init_norm(cfg, cfg.d_model, dtype),
            "self_attn": attn.init_attn(k1, cfg, dtype),
            "norm_x": init_norm(cfg, cfg.d_model, dtype),
            "cross": attn.init_cross_attn(k2, cfg, dtype),
            "norm2": init_norm(cfg, cfg.d_model, dtype),
            "ffn": init_mlp(k3, cfg.d_model, cfg.d_ff, dtype)}


def _stack(blocks):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.dtype)
    ke, kd, kv = jax.random.split(key, 3)
    enc = [_init_enc_block(jax.random.fold_in(ke, i), cfg, dtype)
           for i in range(cfg.n_enc_layers)]
    dec = [_init_dec_block(jax.random.fold_in(kd, i), cfg, dtype)
           for i in range(cfg.n_layers)]
    return {
        "embed": embed_init(kv, cfg.padded_vocab, cfg.d_model, dtype),
        "enc_blocks": _stack(enc),
        "dec_blocks": _stack(dec),
        "enc_norm": init_norm(cfg, cfg.d_model, dtype),
        "dec_norm": init_norm(cfg, cfg.d_model, dtype),
    }


def encode(cfg: ModelConfig, params, embeds):
    B, S, _ = embeds.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(h, p):
        x = apply_norm(cfg, p["norm1"], h)
        h = h + attn.attn_forward(p["attn"], cfg, x, positions, causal=False)
        x = apply_norm(cfg, p["norm2"], h)
        return h + apply_mlp(p["ffn"], x), None

    h = embeds.astype(jnp.dtype(cfg.dtype))
    h, _ = jax.lax.scan(body, h, params["enc_blocks"],
                        unroll=cfg.n_enc_layers if cfg.scan_unroll else 1)
    return apply_norm(cfg, params["enc_norm"], h)


def _dec_body(cfg: ModelConfig, h, p, positions, kv):
    k, v = kv
    x = apply_norm(cfg, p["norm1"], h)
    h = h + attn.attn_forward(p["self_attn"], cfg, x, positions)
    x = apply_norm(cfg, p["norm_x"], h)
    h = h + attn.cross_attn_forward(p["cross"], cfg, x, k, v)
    x = apply_norm(cfg, p["norm2"], h)
    return h + apply_mlp(p["ffn"], x)


def forward(cfg: ModelConfig, params, tokens, *, embeds, positions=None):
    """tokens: (B,S_tgt) decoder input; embeds: (B,S_src,d) frontend stub."""
    enc_out = encode(cfg, params, embeds)
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    h = params["embed"][tokens] * cfg.embed_scale

    def body(h, p):
        kv = attn.cross_kv(p["cross"], cfg, enc_out)
        return _dec_body(cfg, h, p, positions, kv), None

    h, _ = jax.lax.scan(body, h, params["dec_blocks"],
                        unroll=cfg.n_layers if cfg.scan_unroll else 1)
    h = apply_norm(cfg, params["dec_norm"], h)
    logits = (h @ params["embed"].T.astype(h.dtype)) * cfg.logit_scale
    return softcap(logits, cfg.final_softcap), jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, src_len: int):
    dtype = jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    kvshape = (L, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    xshape = (L, batch, src_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(kvshape, dtype), "v": jnp.zeros(kvshape, dtype),
            "xk": jnp.zeros(xshape, dtype), "xv": jnp.zeros(xshape, dtype)}


def build_cross_cache(cfg: ModelConfig, params, cache, embeds):
    """Run the encoder once and fill the static cross K/V (prefill side)."""
    enc_out = encode(cfg, params, embeds)

    def body(_, p):
        return None, attn.cross_kv(p["cross"], cfg, enc_out)

    _, (xk, xv) = jax.lax.scan(body, None, params["dec_blocks"])
    return dict(cache, xk=xk.astype(cache["xk"].dtype), xv=xv.astype(cache["xv"].dtype))


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    h = params["embed"][token] * cfg.embed_scale

    def body(h, xs):
        p, k_l, v_l, xk_l, xv_l = xs
        x = apply_norm(cfg, p["norm1"], h)
        r, newc = attn.attn_decode(p["self_attn"], cfg, {"k": k_l, "v": v_l}, x, pos)
        h = h + r
        x = apply_norm(cfg, p["norm_x"], h)
        h = h + attn.cross_attn_forward(p["cross"], cfg, x, xk_l, xv_l)
        x = apply_norm(cfg, p["norm2"], h)
        h = h + apply_mlp(p["ffn"], x)
        return h, (newc["k"], newc["v"])

    h, (nk, nv) = jax.lax.scan(
        body, h, (params["dec_blocks"], cache["k"], cache["v"], cache["xk"], cache["xv"]),
        unroll=cfg.n_layers if cfg.scan_unroll else 1)
    h = apply_norm(cfg, params["dec_norm"], h)
    logits = (h @ params["embed"].T.astype(h.dtype)) * cfg.logit_scale
    return softcap(logits, cfg.final_softcap), dict(cache, k=nk, v=nv)
