"""Grouped-query attention with sliding-window, softcap, qk-norm, (M-)RoPE.

Two entry points per block:
  * ``attn_forward``  — full-sequence (train / prefill), causal.
  * ``attn_decode``   — one new token against a KV cache.

The jnp path is the canonical implementation that pjit/GSPMD partitions for the
dry-run; ``kernels/flash`` provides the Pallas TPU kernel validated against the
same math (``attn_impl="pallas"`` routes through it, interpret=True on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (apply_mrope, apply_rope, dense_init,
                                 rms_head_norm, softcap)
from repro.models.tp import shard_hint, tp_ctx

from jax.sharding import PartitionSpec as P

try:                                    # jax >= 0.5 top-level export
    _shard_map = jax.shard_map
except AttributeError:                  # jax 0.4.x experimental location
    from jax.experimental.shard_map import shard_map as _shard_map

NEG_INF = -2.0 ** 30  # large-but-finite: keeps fully-masked rows NaN-free


def init_attn(key, cfg: ModelConfig, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, cfg.d_model, cfg.q_dim, dtype),
        "wk": dense_init(k2, cfg.d_model, cfg.kv_dim, dtype),
        "wv": dense_init(k3, cfg.d_model, cfg.kv_dim, dtype),
        "wo": dense_init(k4, cfg.q_dim, cfg.d_model, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dtype)
        p["k_norm"] = jnp.ones((cfg.head_dim,), dtype)
    return p


def _project_qkv(p, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    # TP hint: column-parallel wq/wk/wv leave the HEAD axis sharded —
    # attention then runs head-local per device (Megatron cut #1)
    q = shard_hint((x @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim), 2)
    k = shard_hint((x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim), 2)
    v = shard_hint((x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim), 2)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)
    if cfg.mrope_sections:
        if positions.ndim == x.ndim - 1:          # (B,S) -> identical streams
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(cfg: ModelConfig, q, k, v, mask):
    """q:(B,S,H,hd) k,v:(B,T,KV,hd) mask:(B,1,S,T) or (1,1,S,T) bool."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    scores = softcap(scores, cfg.attn_softcap)
    scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, H, hd)


def _sdpa_blocked(cfg: ModelConfig, q, k, v, *, causal: bool, window: int,
                  block: int = 1024):
    """Flash-style blocked attention in pure jnp: lax.scan over key blocks
    with online-softmax running (m, l, acc).  Never materializes the (S,T)
    score matrix — the §Perf fix for long-prefill memory (e.g. minicpm's
    36-head full-MHA at 32k).  Same math as _sdpa to fp32 accuracy."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    bk = min(block, T)
    assert T % bk == 0, (T, bk)
    nb = T // bk
    scale = hd ** -0.5
    qr = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    kb = jnp.moveaxis(k.reshape(B, nb, bk, KV, hd), 1, 0).astype(jnp.float32)
    vb = jnp.moveaxis(v.reshape(B, nb, bk, KV, hd), 1, 0).astype(jnp.float32)
    q_idx = jnp.arange(S)

    def body(carry, xs):
        m, l, acc = carry
        j, kblk, vblk = xs
        s = jnp.einsum("bskgd,btkd->bkgst", qr, kblk) * scale
        s = softcap(s, cfg.attn_softcap)
        k_idx = j * bk + jnp.arange(bk)
        mask = jnp.ones((S, bk), bool)
        if causal:
            mask &= k_idx[None, :] <= q_idx[:, None]
        if window > 0:
            mask &= (q_idx[:, None] - k_idx[None, :]) < window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgst,btkd->bkgsd", p, vblk)
        return (m_new, l, acc), None

    m0 = jnp.full((B, KV, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, S), jnp.float32)
    a0 = jnp.zeros((B, KV, G, S, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (jnp.arange(nb), kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1)                 # (B,S,KV,G,hd)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def _causal_mask(S: int, window: int):
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    m = j <= i
    if window > 0:
        m &= (i - j) < window
    return m[None]  # (1,S,T)


def attn_forward(p, cfg: ModelConfig, x, positions, *, local: bool = False,
                 causal: bool = True):
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    window = cfg.sliding_window if local else 0
    if cfg.attn_impl == "pallas" and not cfg.mrope_sections and causal:
        from repro.kernels.flash import ops as flash_ops

        def _flash(q, k, v):
            return flash_ops.flash_attention(
                q, k, v, causal=True, window=window,
                softcap=cfg.attn_softcap)

        c = tp_ctx()
        if (c is not None
                and cfg.n_heads % c[0].shape[c[1]] == 0
                and cfg.n_kv_heads % c[0].shape[c[1]] == 0):
            # head-sharded TP: run the Pallas kernel per device on its
            # LOCAL head shard — shard_map keeps the kernel call out of
            # GSPMD's hands (a custom call has no partitioning rule), so
            # the sharded attention path is served by the same kernel
            mesh, axis = c
            hs = P(None, None, axis, None)
            out = _shard_map(_flash, mesh=mesh,
                             in_specs=(hs, hs, hs), out_specs=hs,
                             check_rep=False)(q, k, v)
        else:
            out = _flash(q, k, v)
    elif cfg.attn_impl == "blocked":
        out = _sdpa_blocked(cfg, q, k, v, causal=causal, window=window)
    else:
        if causal:
            mask = _causal_mask(S, window)[:, None]      # (1,1,S,T)
        else:
            mask = jnp.ones((1, 1, S, S), bool)
        out = _sdpa(cfg, q, k, v, mask)
    # TP hint: head-sharded context feeds the row-parallel wo — the
    # contraction's all-reduce is the layer's single output collective
    out = shard_hint(out, 2)
    return out.reshape(B, S, cfg.q_dim) @ p["wo"]


def init_cross_attn(key, cfg: ModelConfig, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, cfg.d_model, cfg.q_dim, dtype),
        "wk": dense_init(k2, cfg.d_model, cfg.kv_dim, dtype),
        "wv": dense_init(k3, cfg.d_model, cfg.kv_dim, dtype),
        "wo": dense_init(k4, cfg.q_dim, cfg.d_model, dtype),
    }


def cross_kv(p, cfg: ModelConfig, enc_out):
    B, T, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out @ p["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def cross_attn_forward(p, cfg: ModelConfig, x, k, v):
    """x: (B,S,d); k,v: (B,T,KV,hd) from the encoder. No positional encoding."""
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    mask = jnp.ones((1, 1, S, k.shape[1]), bool)
    out = _sdpa(cfg, q, k, v, mask)
    return out.reshape(B, S, cfg.q_dim) @ p["wo"]


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_decode(p, cfg: ModelConfig, cache, x, pos, *, local: bool = False):
    """x: (B,1,d); pos: scalar int32 current position. Returns (out, cache)."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1)
    T = k.shape[1]
    j = jnp.arange(T)[None, :]
    m = j <= pos
    if local and cfg.sliding_window > 0:
        m &= (pos - j) < cfg.sliding_window
    mask = m[None, None]                              # (1,1,1,T)
    out = _sdpa(cfg, q, k, v, mask)
    out = out.reshape(B, 1, cfg.q_dim) @ p["wo"]
    return out, {"k": k, "v": v}
