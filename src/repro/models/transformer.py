"""Decoder-only LM over heterogeneous superblocks (dense/MoE/Mamba/xLSTM/VLM).

Parameters for each position-in-superblock are stacked across superblocks so
the whole depth runs under a single ``jax.lax.scan`` — program size is O(1) in
depth, which keeps the 94-layer dry-runs compilable, and the stacked leading
axis is what the Fed-RAC client-vmap and GSPMD sharding rules see.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba, xlstm_blocks as xb
from repro.models.layers import (apply_mlp, apply_norm, embed_init, init_mlp,
                                 init_norm, softcap)
from repro.models.moe import apply_moe, init_moe
from repro.models.tp import shard_hint


def _init_mixer(key, cfg: ModelConfig, kind: str, dtype):
    if kind in ("attn", "attn_local"):
        return attn.init_attn(key, cfg, dtype)
    if kind == "mamba":
        return mamba.init_mamba(key, cfg, dtype)
    if kind == "mlstm":
        return xb.init_mlstm(key, cfg, dtype)
    if kind == "slstm":
        return xb.init_slstm(key, cfg, dtype)
    raise ValueError(kind)


def _init_block(key, cfg: ModelConfig, pos: int, dtype):
    kind = cfg.block_pattern[pos]
    ffn = cfg.ffn_kind(pos)
    k1, k2 = jax.random.split(key)
    p = {"norm1": init_norm(cfg, cfg.d_model, dtype),
         "mixer": _init_mixer(k1, cfg, kind, dtype)}
    if ffn == "dense":
        p["norm2"] = init_norm(cfg, cfg.d_model, dtype)
        p["ffn"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
    elif ffn == "moe":
        p["norm2"] = init_norm(cfg, cfg.d_model, dtype)
        p["ffn"] = init_moe(k2, cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key):
    cfg.validate()
    dtype = jnp.dtype(cfg.dtype)
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    params = {"embed": embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dtype)}
    blocks = {}
    for j in range(cfg.period):
        keys = jax.random.split(jax.random.fold_in(k_blocks, j), cfg.n_superblocks)
        per_sb = [_init_block(keys[s], cfg, j, dtype) for s in range(cfg.n_superblocks)]
        blocks[f"p{j}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_sb)
    params["blocks"] = blocks
    params["final_norm"] = init_norm(cfg, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(k_head, cfg.padded_vocab, cfg.d_model, dtype)
    return params


def _apply_block(cfg: ModelConfig, pos: int, p, h, positions):
    kind = cfg.block_pattern[pos]
    x = apply_norm(cfg, p["norm1"], h)
    if kind == "attn":
        r = attn.attn_forward(p["mixer"], cfg, x, positions)
    elif kind == "attn_local":
        r = attn.attn_forward(p["mixer"], cfg, x, positions, local=True)
    elif kind == "mamba":
        r = mamba.mamba_forward(p["mixer"], cfg, x)
    elif kind == "mlstm":
        r = xb.mlstm_forward(p["mixer"], cfg, x)
    elif kind == "slstm":
        r = xb.slstm_forward(p["mixer"], cfg, x)
    else:
        raise ValueError(kind)
    h = h + r * cfg.residual_scale
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in p:
        x = apply_norm(cfg, p["norm2"], h)
        if cfg.ffn_kind(pos) == "moe":
            r, aux = apply_moe(p["ffn"], cfg, x)
        else:
            r = apply_mlp(p["ffn"], x)
        h = h + r * cfg.residual_scale
    return h, aux


def embed_tokens(cfg: ModelConfig, params, tokens):
    return params["embed"][tokens] * cfg.embed_scale


def forward(cfg: ModelConfig, params, tokens=None, *, embeds=None,
            positions=None, return_hidden: bool = False):
    """Full-sequence forward (train / prefill).

    tokens: (B, S_txt) int32 or None; embeds: (B, S_front, d) modality-frontend
    embeddings prepended to the token embeddings (VLM/audio stub).
    Returns (logits (B,S,V_pad), moe_aux).
    """
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(jnp.dtype(cfg.dtype)))
    if tokens is not None:
        parts.append(embed_tokens(cfg, params, tokens))
    h = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    B, S, _ = h.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def sb_body(h, sbp):
        aux = jnp.zeros((), jnp.float32)
        for j in range(cfg.period):
            h, a = _apply_block(cfg, j, sbp[f"p{j}"], h, positions)
            aux = aux + a
        return h, aux

    if cfg.remat:
        sb_body = jax.checkpoint(sb_body)
    h, auxs = jax.lax.scan(sb_body, h, params["blocks"],
                            unroll=cfg.n_superblocks if cfg.scan_unroll else 1)
    h = apply_norm(cfg, params["final_norm"], h)
    if return_hidden:
        return h, jnp.sum(auxs)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    # TP hint: the vocab-parallel head keeps logits VOCAB-sharded — the
    # downstream logsumexp/gather loss reduces partials per device instead
    # of materializing the full (B,S,V) per device (Megatron vocab loss)
    logits = shard_hint((h @ head.T.astype(h.dtype)) * cfg.logit_scale, -1)
    logits = softcap(logits, cfg.final_softcap)
    return logits, jnp.sum(auxs)


# ------------------------------------------------------------------ decode
def _init_block_cache(cfg: ModelConfig, pos: int, batch: int, max_len: int, dtype):
    kind = cfg.block_pattern[pos]
    if kind in ("attn", "attn_local"):
        return attn.init_attn_cache(cfg, batch, max_len, dtype)
    if kind == "mamba":
        return mamba.init_mamba_cache(cfg, batch, dtype)
    if kind == "mlstm":
        return xb.init_mlstm_cache(cfg, batch, dtype)
    if kind == "slstm":
        return xb.init_slstm_cache(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    cache = {}
    for j in range(cfg.period):
        one = _init_block_cache(cfg, j, batch, max_len, dtype)
        cache[f"p{j}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_superblocks,) + x.shape).copy(), one)
    return cache


def _decode_block(cfg: ModelConfig, pos_j: int, p, cache_j, h, pos):
    kind = cfg.block_pattern[pos_j]
    x = apply_norm(cfg, p["norm1"], h)
    if kind == "attn":
        r, newc = attn.attn_decode(p["mixer"], cfg, cache_j, x, pos)
    elif kind == "attn_local":
        r, newc = attn.attn_decode(p["mixer"], cfg, cache_j, x, pos, local=True)
    elif kind == "mamba":
        r, newc = mamba.mamba_decode(p["mixer"], cfg, cache_j, x, pos)
    elif kind == "mlstm":
        r, newc = xb.mlstm_decode(p["mixer"], cfg, cache_j, x, pos)
    elif kind == "slstm":
        r, newc = xb.slstm_decode(p["mixer"], cfg, cache_j, x, pos)
    else:
        raise ValueError(kind)
    h = h + r * cfg.residual_scale
    if "ffn" in p:
        x = apply_norm(cfg, p["norm2"], h)
        if cfg.ffn_kind(pos_j) == "moe":
            r, _ = apply_moe(p["ffn"], cfg, x)
        else:
            r = apply_mlp(p["ffn"], x)
        h = h + r * cfg.residual_scale
    return h, newc


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    """token: (B,1) int32; pos: scalar int32.  Returns (logits (B,1,V), cache)."""
    h = embed_tokens(cfg, params, token)

    def sb_body(h, xs):
        sbp, sbc = xs
        newc = {}
        for j in range(cfg.period):
            h, newc[f"p{j}"] = _decode_block(cfg, j, sbp[f"p{j}"], sbc[f"p{j}"], h, pos)
        return h, newc

    h, new_cache = jax.lax.scan(sb_body, h, (params["blocks"], cache),
                                unroll=cfg.n_superblocks if cfg.scan_unroll else 1)
    h = apply_norm(cfg, params["final_norm"], h)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = (h @ head.T.astype(h.dtype)) * cfg.logit_scale
    logits = softcap(logits, cfg.final_softcap)
    return logits, new_cache


# ------------------------------------------------------------------ loss
def vocab_mask(cfg: ModelConfig):
    return jnp.arange(cfg.padded_vocab) < cfg.vocab_size


def next_token_loss(cfg: ModelConfig, params, tokens, *, embeds=None):
    """Causal LM loss over the token portion (frontend positions excluded)."""
    logits, aux = forward(cfg, params, tokens, embeds=embeds)
    n_front = 0 if embeds is None else embeds.shape[1]
    logits = logits[:, n_front:, :]
    lg = logits[:, :-1].astype(jnp.float32)
    lbl = tokens[:, 1:]
    lg = jnp.where(vocab_mask(cfg)[None, None], lg, attn.NEG_INF)
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, lbl[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - picked)
    return ce + cfg.router_aux_coef * aux, ce
