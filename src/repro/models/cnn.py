"""The paper's experimental model: C(128)-C(64)-C(128)-C(256)-C(512)-D(classes).

§V-A of Fed-RAC.  Width-scalable by the cluster compression factor α — the
paper compresses only the conv layers ("dropout of 0.5, i.e. M2 = 0.5(M1)"),
so ``filters(level)`` scales every conv width by α^level and leaves the dense
head at ``classes``.  Used by the FL experiments/benchmarks (Tables IV-VII).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

BASE_FILTERS = (128, 64, 128, 256, 512)
DN = ("NHWC", "HWIO", "NHWC")


def filters(alpha: float = 1.0, level: int = 0, base_width: float = 1.0):
    """base_width scales the whole family (CPU-budget experiments use 0.25);
    alpha**level is the paper's per-cluster compression."""
    s = base_width * alpha ** level
    return tuple(max(4, int(round(f * s))) for f in BASE_FILTERS)


def init_params(key, *, in_channels: int = 1, classes: int = 10,
                alpha: float = 1.0, level: int = 0, base_width: float = 1.0,
                dtype=jnp.float32):
    fs = filters(alpha, level, base_width)
    params = {"convs": []}
    cin = in_channels
    for i, f in enumerate(fs):
        k = jax.random.fold_in(key, i)
        w = jax.random.normal(k, (3, 3, cin, f)) * math.sqrt(2.0 / (9 * cin))
        params["convs"].append({"w": w.astype(dtype), "b": jnp.zeros((f,), dtype)})
        cin = f
    kd = jax.random.fold_in(key, 99)
    params["dense"] = {
        "w": (jax.random.normal(kd, (cin, classes)) * cin ** -0.5).astype(dtype),
        "b": jnp.zeros((classes,), dtype)}
    return params


def forward(params, x):
    """x: (B,H,W,C) -> logits (B,classes)."""
    for i, p in enumerate(params["convs"]):
        x = jax.lax.conv_general_dilated(x, p["w"], (1, 1), "SAME",
                                         dimension_numbers=DN) + p["b"]
        x = jax.nn.relu(x)
        if i % 2 == 1 and min(x.shape[1], x.shape[2]) >= 2:   # pool every other
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = jnp.mean(x, axis=(1, 2))                              # global avg pool
    return x @ params["dense"]["w"] + params["dense"]["b"]


def loss_fn(params, batch):
    logits = forward(params, batch["x"])
    labels = batch["y"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(lse - picked)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, acc


def param_count(params) -> int:
    return sum(p.size for p in jax.tree.leaves(params))
