"""Selectable config for --arch (see archs.py for the cited source)."""
from repro.configs.archs import MINICPM_2B as CONFIG, smoke_variant

SMOKE = smoke_variant(CONFIG)
