"""The 10 assigned architectures (exact numbers from the assignment brief,
source papers/model cards cited per entry) + reduced smoke variants.

Full configs are exercised ONLY via the dry-run (ShapeDtypeStruct, no
allocation); smoke variants (≤2 layers, d_model ≤ 512, ≤4 experts) run one
real forward/train step on CPU in tests/test_arch_smoke.py.
"""
from __future__ import annotations

import dataclasses
import math

from repro.configs.base import ModelConfig

_D = dict  # brevity


def _cfg(**kw) -> ModelConfig:
    c = ModelConfig(**kw)
    c.validate()
    return c


# --------------------------------------------------------------------- full
# [arXiv:2409.12191] Qwen2-VL: M-RoPE (sections 16/24/24 of half-dim), dynamic
# resolution handled by the stubbed ViT frontend (patch embeddings provided).
QWEN2_VL_2B = _cfg(
    name="qwen2-vl-2b", family="vlm", n_layers=28, d_model=1536, n_heads=12,
    n_kv_heads=2, head_dim=128, d_ff=8960, vocab_size=151936,
    mrope_sections=(16, 24, 24), rope_theta=1e6, tie_embeddings=True,
    frontend="vision", frontend_tokens=1024, dtype="bfloat16")

# [hf:Qwen/Qwen3-30B-A3B family, scaled per brief] 94L, 128 experts top-8.
QWEN3_MOE_235B = _cfg(
    name="qwen3-moe-235b-a22b", family="moe", n_layers=94, d_model=4096,
    n_heads=64, n_kv_heads=4, head_dim=128, d_ff=1536, vocab_size=151936,
    ffn_pattern=("moe",), n_experts=128, experts_per_tok=8,
    moe_impl="capacity", qk_norm=True, rope_theta=1e6, tie_embeddings=False,
    dtype="bfloat16")

# [arXiv:2404.06395] MiniCPM: WSD schedule + μP-style depth/width scaling.
MINICPM_2B = _cfg(
    name="minicpm-2b", family="dense", n_layers=40, d_model=2304, n_heads=36,
    n_kv_heads=36, head_dim=64, d_ff=5760, vocab_size=122753,
    rope_theta=1e4, residual_scale=1.4 / math.sqrt(40), embed_scale=12.0,
    logit_scale=256.0 / 2304.0, tie_embeddings=True, dtype="bfloat16")

# [arXiv:2403.19887] Jamba: Mamba+attention 1:7 interleave, MoE every other
# layer (16e top-2); no positional encoding.
JAMBA_52B = _cfg(
    name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab_size=65536,
    block_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    ffn_pattern=("dense", "moe"), n_experts=16, experts_per_tok=2,
    moe_impl="capacity", use_rope=False, tie_embeddings=False,
    ssm_state=16, ssm_conv=4, ssm_expand=2, dtype="bfloat16")

# [arXiv:2402.00838] OLMo: non-parametric LayerNorm, tied embeddings.
OLMO_1B = _cfg(
    name="olmo-1b", family="dense", n_layers=16, d_model=2048, n_heads=16,
    n_kv_heads=16, head_dim=128, d_ff=8192, vocab_size=50304,
    norm_type="nonparam_ln", rope_theta=1e4, tie_embeddings=True,
    dtype="bfloat16")

# [hf:ibm-granite/granite-3.0-1b-a400m-base] 32 experts top-8, tiny experts.
GRANITE_MOE_1B = _cfg(
    name="granite-moe-1b-a400m", family="moe", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=8, head_dim=64, d_ff=512, vocab_size=49155,
    ffn_pattern=("moe",), n_experts=32, experts_per_tok=8,
    moe_impl="capacity", rope_theta=1e4, tie_embeddings=True, dtype="bfloat16")

# [hf:Qwen/Qwen3-8B] qk_norm, GQA kv=8.
QWEN3_8B = _cfg(
    name="qwen3-8b", family="dense", n_layers=36, d_model=4096, n_heads=32,
    n_kv_heads=8, head_dim=128, d_ff=12288, vocab_size=151936,
    qk_norm=True, rope_theta=1e6, tie_embeddings=False, dtype="bfloat16")

# [arXiv:2308.11596] SeamlessM4T medium: enc-dec; audio frontend stubbed
# (frame embeddings).  12 encoder + 12 decoder layers.
SEAMLESS_M4T_MED = _cfg(
    name="seamless-m4t-medium", family="encdec", n_layers=12, n_enc_layers=12,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64, d_ff=4096,
    vocab_size=256206, norm_type="layernorm", rope_theta=1e4,
    frontend="audio", frontend_tokens=1024, tie_embeddings=True,
    dtype="bfloat16")

# [arXiv:2405.04517] xLSTM: mLSTM blocks with an sLSTM every 6th; no FFN
# (d_ff=0) — projections live inside the blocks.
XLSTM_350M = _cfg(
    name="xlstm-350m", family="ssm", n_layers=24, d_model=1024, n_heads=4,
    n_kv_heads=4, head_dim=256, d_ff=0, vocab_size=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm"),
    ffn_pattern=("none",), tie_embeddings=True, dtype="bfloat16")

# [arXiv:2408.00118] Gemma2: local(4096)/global alternation, softcaps,
# embedding scaled by sqrt(d_model).
GEMMA2_9B = _cfg(
    name="gemma2-9b", family="dense", n_layers=42, d_model=3584, n_heads=16,
    n_kv_heads=8, head_dim=256, d_ff=14336, vocab_size=256000,
    block_pattern=("attn_local", "attn"), sliding_window=4096,
    attn_softcap=50.0, final_softcap=30.0, embed_scale=math.sqrt(3584.0),
    rope_theta=1e4, tie_embeddings=True, dtype="bfloat16")


ARCHS = {c.name: c for c in [
    QWEN2_VL_2B, QWEN3_MOE_235B, MINICPM_2B, JAMBA_52B, OLMO_1B,
    GRANITE_MOE_1B, QWEN3_8B, SEAMLESS_M4T_MED, XLSTM_350M, GEMMA2_9B]}


# --------------------------------------------------------------------- smoke
def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: ≤2 layers of the same block pattern,
    d_model ≤ 512, ≤4 experts — real forward/train step on CPU."""
    kw: dict = _D(
        name=cfg.name + "-smoke", d_model=256, n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=64, d_ff=512 if cfg.d_ff else 0, vocab_size=512,
        dtype="float32", frontend_tokens=8 if cfg.frontend else 0,
        embed_scale=1.0 if cfg.embed_scale == 1.0 else 4.0,
        sliding_window=8 if cfg.sliding_window else 0,
    )
    if cfg.mrope_sections:
        kw["mrope_sections"] = (8, 12, 12)
    if cfg.n_experts:
        kw.update(n_experts=4, experts_per_tok=2, d_ff=128,
                  moe_impl="dense")
    if cfg.family == "hybrid":
        kw.update(block_pattern=("mamba", "attn"), ffn_pattern=("dense", "moe"),
                  n_layers=2)
    elif cfg.family == "ssm":
        kw.update(block_pattern=("mlstm", "slstm"), n_layers=2)
    elif cfg.family == "encdec":
        kw.update(n_layers=2, n_enc_layers=2)
    else:
        kw.update(n_layers=2, block_pattern=cfg.block_pattern[:2] or ("attn",))
        if len(cfg.block_pattern) >= 2:
            kw["block_pattern"] = cfg.block_pattern[:2]
        else:
            kw["block_pattern"] = cfg.block_pattern
        if len(cfg.ffn_pattern) > 1:
            kw["ffn_pattern"] = cfg.ffn_pattern[:2]
    if cfg.residual_scale != 1.0:
        kw["residual_scale"] = 1.4 / math.sqrt(2)
    c = dataclasses.replace(cfg, **kw)
    c.validate()
    return c


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    cfg = ARCHS[name]
    return smoke_variant(cfg) if smoke else cfg


def list_archs():
    return sorted(ARCHS)
