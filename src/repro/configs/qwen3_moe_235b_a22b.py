"""Selectable config for --arch (see archs.py for the cited source)."""
from repro.configs.archs import QWEN3_MOE_235B as CONFIG, smoke_variant

SMOKE = smoke_variant(CONFIG)
