"""Selectable config for --arch (see archs.py for the cited source)."""
from repro.configs.archs import SEAMLESS_M4T_MED as CONFIG, smoke_variant

SMOKE = smoke_variant(CONFIG)
