"""Selectable config for --arch (see archs.py for the cited source)."""
from repro.configs.archs import GEMMA2_9B as CONFIG, smoke_variant

SMOKE = smoke_variant(CONFIG)
