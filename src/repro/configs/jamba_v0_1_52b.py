"""Selectable config for --arch (see archs.py for the cited source)."""
from repro.configs.archs import JAMBA_52B as CONFIG, smoke_variant

SMOKE = smoke_variant(CONFIG)
