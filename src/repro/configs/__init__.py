from repro.configs.base import (INPUT_SHAPES, InputShape, ModelConfig,
                                TrainConfig, pad_vocab)
from repro.configs.archs import ARCHS, get_config, list_archs, smoke_variant

__all__ = ["INPUT_SHAPES", "InputShape", "ModelConfig", "TrainConfig",
           "pad_vocab", "ARCHS", "get_config", "list_archs", "smoke_variant"]
