"""Model / run configuration dataclasses shared across the framework.

Every assigned architecture gets a ``ModelConfig`` in ``src/repro/configs/<id>.py``
with the exact numbers from the assignment brief (source cited there).  The config
is the single source of truth consumed by the model zoo, the sharding rules, the
Fed-RAC α-compression (``core/scaling.py``), and the dry-run launcher.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple


def pad_vocab(v: int, multiple: int = 256) -> int:
    """Pad vocab to a mesh-divisible multiple (Megatron-style)."""
    return ((v + multiple - 1) // multiple) * multiple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | vlm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int             # raw vocab (loss masks the padding)
    # --- mixer pattern -----------------------------------------------------
    # kinds per position within a superblock; n_layers % len(pattern) == 0.
    # entries: "attn" | "attn_local" | "mamba" | "mlstm" | "slstm"
    block_pattern: Tuple[str, ...] = ("attn",)
    # ffn kind per position: "dense" | "moe" | "none"
    ffn_pattern: Tuple[str, ...] = ("dense",)
    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    experts_per_tok: int = 0
    router_aux_coef: float = 0.01
    moe_impl: str = "dense"       # dense | capacity (GShard grouped dispatch)
    moe_group: int = 512          # tokens per dispatch group (capacity impl)
    moe_capacity: float = 1.25    # capacity factor
    # >0: lax.scan over group-chunks of this many groups so only one chunk's
    # dispatch one-hots are live (§Perf memory lever for the 235B MoE)
    moe_chunk_groups: int = 0
    # --- attention flavour ---------------------------------------------------
    rope_theta: float = 1_000_000.0
    use_rope: bool = True               # jamba: no positional encoding
    qk_norm: bool = False
    mrope_sections: Tuple[int, ...] = ()     # qwen2-vl M-RoPE (sums to head_dim//2)
    sliding_window: int = 0                  # for "attn_local" layers
    attn_softcap: float = 0.0                # gemma2 logit softcap (attn)
    final_softcap: float = 0.0               # gemma2 final-logit softcap
    # --- norms / residual scaling -------------------------------------------
    norm_type: str = "rmsnorm"               # rmsnorm | layernorm | nonparam_ln (olmo)
    residual_scale: float = 1.0              # minicpm depth scaling
    embed_scale: float = 1.0                 # minicpm scale_emb
    logit_scale: float = 1.0                 # minicpm 1/(d_model/dim_base)
    tie_embeddings: bool = True
    # --- ssm (mamba) ----------------------------------------------------------
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0                     # 0 -> ceil(d_model/16)
    # --- xlstm ----------------------------------------------------------------
    mlstm_expand: int = 2
    slstm_proj: float = 4 / 3
    # mLSTM prefill/train: "scan" (sequential cell) or "chunk" (chunkwise-
    # parallel, MXU-shaped — the TPU-native form; exact same math)
    mlstm_impl: str = "scan"
    # --- enc-dec --------------------------------------------------------------
    n_enc_layers: int = 0
    # --- modality frontend stub ------------------------------------------------
    frontend: str = ""                       # "" | "vision" | "audio"
    frontend_tokens: int = 0                 # frontend positions per sample (train/prefill)
    # --- numerics ---------------------------------------------------------------
    dtype: str = "float32"
    # MoE sharding mode: "tp" shards expert d_ff, "ep" shards the expert axis.
    moe_shard: str = "tp"
    # Parameter sharding scheme: "tp" (tensor-parallel along `model`) or
    # "fsdp" (params sharded over the combined data axes, batch over ALL
    # axes — the beyond-paper scheme for small-d_model archs, §Perf).
    shard_mode: str = "tp"
    # Decode-cache sharding: "seq" (sequence over model — flash-decode style,
    # the production default: §Perf H2 shows 8-65x lower collectives than
    # "hd" on every decode shape), "hd" (head_dim over model — the original
    # baseline), "batch" (replicate over model).
    cache_shard: str = "seq"
    # attention implementation: "jnp" | "pallas" (pallas = flash kernel via ops)
    attn_impl: str = "jnp"
    remat: bool = False                      # rematerialize each superblock
    scan_unroll: bool = False                # unroll layer scans (dry-run cost measurement)

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def period(self) -> int:
        return len(self.block_pattern)

    @property
    def n_superblocks(self) -> int:
        assert self.n_layers % self.period == 0, (self.name, self.n_layers, self.period)
        return self.n_layers // self.period

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def ffn_kind(self, pos: int) -> str:
        return self.ffn_pattern[pos % len(self.ffn_pattern)]

    def validate(self) -> None:
        assert self.n_layers % self.period == 0
        assert len(self.ffn_pattern) in (1, self.period) or self.period % len(self.ffn_pattern) == 0
        if "attn" in self.block_pattern or "attn_local" in self.block_pattern:
            assert self.n_heads % self.n_kv_heads == 0
        if "moe" in self.ffn_pattern:
            assert self.n_experts > 0 and self.experts_per_tok > 0
        if self.mrope_sections:
            assert sum(self.mrope_sections) == self.head_dim // 2


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    learning_rate: float = 1e-3
    weight_decay: float = 0.01
    optimizer: str = "adamw"      # sgd | momentum | adamw
    schedule: str = "constant"    # constant | cosine | wsd
    warmup_steps: int = 0
    total_steps: int = 1000
    grad_clip: float = 1.0
    seed: int = 0
