"""ShapeDtypeStruct stand-ins for every model input — shardable, weak-type
correct, ZERO device allocation.  The dry-run lowers against these.

Per family:
  * decoder-only train/prefill:  tokens (B, S) int32
  * vlm:    embeds (B, front, d) bf16 + tokens (B, S-front)   [frontend stub]
  * encdec: embeds (B, S, d) + tokens (B, max(S//8,128))      [frontend stub]
  * decode: token (B,1) + pos scalar + cache (via eval_shape on init_cache)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.models import registry

Sds = jax.ShapeDtypeStruct


def train_inputs(cfg: ModelConfig, shape: InputShape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "vlm":
        front = min(cfg.frontend_tokens, S // 4)
        return {"embeds": Sds((B, front, cfg.d_model), dt),
                "tokens": Sds((B, S - front), jnp.int32)}
    if cfg.family == "encdec":
        return {"embeds": Sds((B, S, cfg.d_model), dt),
                "tokens": Sds((B, max(S // 8, 128)), jnp.int32)}
    return {"tokens": Sds((B, S), jnp.int32)}


def decode_inputs(cfg: ModelConfig, shape: InputShape):
    """Returns (token, pos, cache_shape) — ONE new token against a seq_len cache."""
    B, S = shape.global_batch, shape.seq_len
    token = Sds((B, 1), jnp.int32)
    pos = Sds((), jnp.int32)
    cache = jax.eval_shape(lambda: registry.init_cache(cfg, B, S))
    return token, pos, cache


def params_shape(cfg: ModelConfig):
    return jax.eval_shape(
        lambda k: registry.init_params(cfg, k), jax.random.PRNGKey(0))


def input_specs(cfg: ModelConfig, shape_name: str):
    shape = INPUT_SHAPES[shape_name]
    if shape.kind in ("train", "prefill"):
        return train_inputs(cfg, shape)
    return decode_inputs(cfg, shape)


def applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """long_500k only for sub-quadratic archs (DESIGN.md §4)."""
    if shape_name != "long_500k":
        return True, ""
    sub_quadratic = (cfg.family in ("hybrid", "ssm")
                     or (cfg.sliding_window > 0))
    if not sub_quadratic:
        return False, ("pure full-attention arch: 500k-token decode requires "
                       "sub-quadratic attention (skip per assignment brief)")
    return True, ""
