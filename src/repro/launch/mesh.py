"""Production meshes.  A FUNCTION (not a module constant) so importing this
module never touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
tests and benchmarks must keep seeing 1 device)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """The batch-sharding axes: ('pod','data') on the multi-pod mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over host devices (tests use 8 forced host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def parse_sim_mesh_shape(shape) -> tuple:
    """Normalize a sim-mesh shape — int, ``"8"``/``"8x1"``/``"4x2"`` string,
    or tuple — to a validated ``(data, model)`` pair."""
    if isinstance(shape, str):
        shape = tuple(int(s) for s in shape.lower().replace("×", "x")
                      .split("x"))
    elif isinstance(shape, int):
        shape = (shape,)
    if len(shape) > 2:
        raise ValueError(
            f"sim meshes have at most (data, model) axes, got {shape}")
    n_data = int(shape[0])
    n_model = int(shape[1]) if len(shape) > 1 else 1
    if n_data < 1 or n_model < 1:
        raise ValueError(f"mesh axes must be ≥ 1, got {shape}")
    return n_data, n_model


def make_sim_mesh(shape):
    """Mesh for mesh-sharded FL simulation (``sim_run --mesh-shape``): the
    ``data`` axis shards the cluster member axis of the dispatch-path plane
    programs, and a non-trivial ``model`` axis column-shards the parameter
    plane / bank / teacher stacks (2D dispatch for member models too large
    to replicate per device).  ``shape`` is an int (data-axis size), an
    ``"8"`` / ``"8x1"`` / ``"4x2"`` string, or a tuple ``(data[, model])``."""
    return make_host_mesh(*parse_sim_mesh_shape(shape))
