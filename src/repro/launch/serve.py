"""Batched serving driver: prefill prompts, then decode with a KV cache.

Fed-RAC flavour: the server holds the α-compressed model FAMILY and routes
each request batch to the model level matching the requester's resource
cluster — the serving-side analogue of §IV-A2 (used by examples/serve_demo).

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.core.scaling import compress_config
from repro.models import registry, transformer


def prefill_into_cache(cfg, params, tokens, max_len):
    """Run the full prompt through decode steps to fill the cache.

    (Production prefill computes the cache in one forward; the step-by-step
    fill here shares the decode program — fine at example scale and exercises
    exactly the serve_step the dry-run lowers.)"""
    B, S = tokens.shape
    cache = registry.init_cache(cfg, B, max_len)
    step = jax.jit(lambda p, c, t, i: registry.decode_step(cfg, p, c, t, i))
    logits = None
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t:t + 1], jnp.asarray(t))
    return logits, cache


def generate(cfg, params, prompts, gen_len):
    B, S = prompts.shape
    max_len = S + gen_len
    logits, cache = prefill_into_cache(cfg, params, prompts, max_len)
    step = jax.jit(lambda p, c, t, i: registry.decode_step(cfg, p, c, t, i))
    out = []
    vmask = transformer.vocab_mask(cfg)
    tok = jnp.argmax(jnp.where(vmask, logits[:, -1], -jnp.inf), -1)[:, None]
    for i in range(gen_len):
        out.append(np.asarray(tok))
        logits, cache = step(params, cache, tok.astype(jnp.int32),
                             jnp.asarray(S + i))
        tok = jnp.argmax(jnp.where(vmask, logits[:, -1], -jnp.inf), -1)[:, None]
    return np.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--cluster-level", type=int, default=0,
                    help="Fed-RAC cluster level (α-compressed model)")
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    cfg = compress_config(cfg, args.alpha, args.cluster_level)
    key = jax.random.PRNGKey(args.seed)
    params = registry.init_params(cfg, key)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    toks = generate(cfg, params, prompts, args.gen)
    dt = time.time() - t0
    print(f"arch={cfg.name} level={args.cluster_level} "
          f"generated {toks.shape} in {dt:.1f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", toks[0, :16])
    return toks


if __name__ == "__main__":
    main()
