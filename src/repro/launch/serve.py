"""Batched serving driver: prefill prompts, then decode with a KV cache.

Fed-RAC flavour: the server holds the α-compressed model FAMILY and routes
each request batch to the model level matching the requester's resource
cluster — the serving-side analogue of §IV-A2 (used by examples/serve_demo).

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
      --batch 4 --prompt-len 32 --gen 32

``--watch-ckpt DIR`` points at a training run's crash-safe checkpoint
directory (``sim_run --ckpt-dir``): between request batches a
``PlaneWatcher`` polls the manifest and hot-reloads the newest *valid*
aggregated ``plane/<level>`` into the serving params — corrupt, partial, or
shape-incompatible checkpoints are skipped with a warning and the previous
plane keeps serving, never a crash.
"""
from __future__ import annotations

import argparse
import json
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointError
from repro.ckpt.manifest import CheckpointManager
from repro.configs import get_config, list_archs
from repro.core.plane import make_plane_spec
from repro.core.scaling import compress_config
from repro.models import registry, transformer
from repro.obs import NULL_OBS, make_observability

log = logging.getLogger("repro.serve")


class PlaneWatcher:
    """Mid-training hot-reload of the aggregated model plane.

    Polls a run-state checkpoint directory (written by ``sim_run
    --ckpt-dir``) for steps newer than the one currently serving, walks
    them newest-first, and returns the first ``plane/<level>`` that passes
    manifest CRC + decode + shape validation, adapted into the serving
    params pytree via its ``PlaneSpec``.  Every failure mode — unreadable
    manifest, corrupt or truncated step, missing plane key, plane from a
    different model — logs a warning and keeps the previous params serving.
    """

    def __init__(self, ckpt_dir: str, params_template, level: int = 0,
                 obs=NULL_OBS):
        self.manager = CheckpointManager(ckpt_dir)
        self.spec = make_plane_spec(params_template)
        self.level = int(level)
        self.obs = obs
        self.step = -1     # newest checkpoint step already adapted

    def poll(self, params):
        """(params', reloaded): the newest valid plane newer than
        ``self.step`` adapted into params, or ``params`` unchanged."""
        key = f"plane/{self.level}"
        try:
            fresh = [s for s in self.manager.steps() if s > self.step]
        except Exception as e:
            log.warning("plane watch: manifest unreadable (%s)", e)
            return params, False
        for step in sorted(fresh, reverse=True):
            try:
                _meta, arrays = self.manager.load_step(step)
            except CheckpointError as e:
                log.warning("plane watch: skipping step %d: %s", step, e)
                continue
            plane = arrays.get(key)
            if plane is None:
                log.warning("plane watch: step %d has no %r", step, key)
                continue
            if plane.shape != (self.spec.d_pad,):
                log.warning(
                    "plane watch: step %d %s shape %s != (%d,) — plane is "
                    "from a different model; keeping previous params",
                    step, key, plane.shape, self.spec.d_pad)
                continue
            self.step = step
            if self.obs.on:
                self.obs.registry.counter("serve/plane_reloads").inc()
                self.obs.registry.gauge("serve/plane_step").set(step)
            return self.spec.to_params(jnp.asarray(plane)), True
        return params, False


def prefill_into_cache(cfg, params, tokens, max_len, obs=NULL_OBS):
    """Run the full prompt through decode steps to fill the cache.

    (Production prefill computes the cache in one forward; the step-by-step
    fill here shares the decode program — fine at example scale and exercises
    exactly the serve_step the dry-run lowers.)"""
    B, S = tokens.shape
    cache = registry.init_cache(cfg, B, max_len)
    step = jax.jit(lambda p, c, t, i: registry.decode_step(cfg, p, c, t, i))
    logits = None
    with obs.tracer.span("serve.prefill", cat="serve", batch=B,
                         prompt_len=S):
        for t in range(S):
            logits, cache = step(params, cache, tokens[:, t:t + 1],
                                 jnp.asarray(t))
        obs.tracer.fence(logits)
    if obs.on:
        obs.registry.counter("serve/prefill_tokens").inc(B * S)
    return logits, cache


def generate(cfg, params, prompts, gen_len, obs=NULL_OBS):
    B, S = prompts.shape
    max_len = S + gen_len
    logits, cache = prefill_into_cache(cfg, params, prompts, max_len, obs)
    step = jax.jit(lambda p, c, t, i: registry.decode_step(cfg, p, c, t, i))
    out = []
    vmask = transformer.vocab_mask(cfg)
    tok = jnp.argmax(jnp.where(vmask, logits[:, -1], -jnp.inf), -1)[:, None]
    t0 = time.perf_counter()
    with obs.tracer.span("serve.decode", cat="serve", batch=B,
                         gen_len=gen_len):
        for i in range(gen_len):
            out.append(np.asarray(tok))
            logits, cache = step(params, cache, tok.astype(jnp.int32),
                                 jnp.asarray(S + i))
            tok = jnp.argmax(jnp.where(vmask, logits[:, -1], -jnp.inf),
                             -1)[:, None]
    if obs.on:
        dt = time.perf_counter() - t0
        obs.registry.counter("serve/decode_steps").inc(gen_len)
        obs.registry.counter("serve/generated_tokens").inc(B * gen_len)
        if dt > 0:
            obs.registry.gauge("serve/decode_tok_per_s").set(B * gen_len / dt)
        obs.registry.histogram("serve/decode_step_s").observe(
            dt / max(gen_len, 1))
    return np.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--cluster-level", type=int, default=0,
                    help="Fed-RAC cluster level (α-compressed model)")
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-text", action="store_true",
                    help="print a Prometheus-style /metrics text snapshot "
                         "after the run")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the registry snapshot as JSON ('-' for "
                         "stdout)")
    ap.add_argument("--watch-ckpt", default=None, metavar="DIR",
                    help="hot-reload the newest valid aggregated plane from "
                         "this run-state checkpoint dir between request "
                         "batches (sim_run --ckpt-dir)")
    ap.add_argument("--watch-level", type=int, default=0,
                    help="cluster level whose plane/<level> to watch")
    ap.add_argument("--watch-batches", type=int, default=3, metavar="N",
                    help="with --watch-ckpt: serve N request batches, "
                         "polling for a newer plane between each")
    ap.add_argument("--watch-poll-s", type=float, default=0.0, metavar="S",
                    help="sleep between watched batches (poll interval)")
    args = ap.parse_args(argv)

    obs = (make_observability(trace=False)
           if args.metrics_text or args.metrics_json else NULL_OBS)
    cfg = get_config(args.arch, smoke=args.smoke)
    cfg = compress_config(cfg, args.alpha, args.cluster_level)
    key = jax.random.PRNGKey(args.seed)
    params = registry.init_params(cfg, key)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    watcher = None
    if args.watch_ckpt:
        watcher = PlaneWatcher(args.watch_ckpt, params,
                               level=args.watch_level, obs=obs)
        params, fresh = watcher.poll(params)
        if fresh:
            print(f"# serving plane from checkpoint step {watcher.step}")
    t0 = time.time()
    batches = max(args.watch_batches, 1) if watcher is not None else 1
    for b in range(batches):
        toks = generate(cfg, params, prompts, args.gen, obs=obs)
        if watcher is not None and b + 1 < batches:
            if args.watch_poll_s:
                time.sleep(args.watch_poll_s)
            params, fresh = watcher.poll(params)
            if fresh:
                print(f"# hot-reloaded plane at checkpoint step "
                      f"{watcher.step}")
    dt = time.time() - t0
    if obs.on:
        obs.registry.gauge("serve/wall_clock_s").set(dt)
        obs.registry.counter("serve/requests").inc(args.batch * batches)
    print(f"arch={cfg.name} level={args.cluster_level} "
          f"generated {toks.shape}x{batches} in {dt:.1f}s "
          f"({batches * args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", toks[0, :16])
    if args.metrics_text:
        print(obs.registry.render_text(), end="")
    if args.metrics_json:
        snap = json.dumps(obs.registry.snapshot(), indent=2)
        if args.metrics_json == "-":
            print(snap)
        else:
            with open(args.metrics_json, "w") as f:
                f.write(snap + "\n")
    return toks


if __name__ == "__main__":
    main()
