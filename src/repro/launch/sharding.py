"""GSPMD PartitionSpec rules for every model family.

Parameters are TP-sharded along `model` by leaf name (stacked superblock
leading axes are handled by negative-dim rules); any dim not divisible by the
mesh axis size falls back to replication (small tensors: routers, per-head
norms, sLSTM recurrent blocks).  Batch shards along ('pod','data'); the
long_500k (batch=1) decode shards the KV-cache SEQUENCE axis along `data`
instead (flash-decode style — GSPMD inserts the partial-softmax collectives).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# leaf-name -> dim to shard along `model` (negative = from the end).
# `embed`/`lm_head` shard the vocab (dim 0, no superblock prefix).
PARAM_DIM = {
    "embed": 0, "lm_head": 0,
    "wq": -1, "wk": -1, "wv": -1, "w_up": -1, "up": -1,
    "up_g": -1, "up_v": -1, "in_proj": -1, "x_proj": -1, "wx": -1,
    "conv_w": -1, "conv_b": -1, "D": -1, "dt_bias": -1, "skip": -1,
    "dt_proj": -1, "w_gate": -1,
    "wo": -2, "w_down": -2, "down": -2, "out_proj": -2, "A_log": -2,
}
# MoE expert tensors (ndim>=4 under stacked blocks / >=3 in encdec) can
# alternatively shard the EXPERT axis (expert parallelism).
MOE_LEAVES = {"w_gate", "w_up", "w_down"}

CACHE_DIM = {"k": None, "v": None}   # handled specially (batch/seq axes)


def _leaf_name(path) -> str:
    for p in reversed(path):
        if hasattr(p, "key"):
            return str(p.key)
    return ""


def tp_specs(cfg: ModelConfig, params_shape, msize: int,
             axis: str = "model"):
    """Name-rule TP PartitionSpecs for a bare model-axis SIZE (no mesh).

    The mesh-independent core of ``param_specs``: the FL dispatch path
    (``core/families.lm_family``) bridges its plane world to the same
    Megatron column/row/vocab rules through this entry point, with ``axis``
    naming whatever mesh axis the caller's world shards models along.
    params_shape: pytree of ShapeDtypeStruct (or arrays)."""

    def spec(path, leaf):
        name = _leaf_name(path)
        nd = len(leaf.shape)
        out = [None] * nd
        is_moe = (name in MOE_LEAVES and cfg.n_experts > 0
                  and nd >= 3 and leaf.shape[nd - 3] == cfg.n_experts)
        if is_moe and cfg.moe_shard == "ep":
            dim = nd - 3
            if leaf.shape[dim] % msize == 0:
                out[dim] = axis
                return P(*out)
        if name in PARAM_DIM:
            dim = PARAM_DIM[name]
            dim = dim if dim >= 0 else nd + dim
            if 0 <= dim < nd and leaf.shape[dim] % msize == 0:
                out[dim] = axis
        return P(*out)

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def param_specs(cfg: ModelConfig, params_shape, mesh):
    """params_shape: pytree of ShapeDtypeStruct (or arrays)."""
    if cfg.shard_mode == "fsdp":
        return _fsdp_param_specs(params_shape, mesh)
    return tp_specs(cfg, params_shape, mesh.shape.get("model", 1))


def _fsdp_param_specs(params_shape, mesh):
    """ZeRO-3 style: every parameter fully sharded over ('data','model')
    along its largest divisible dim; XLA all-gathers per use.  The model
    axis carries extra data parallelism instead of TP — the right trade for
    small-d_model archs whose TP activation all-reduces dwarf their compute
    (§Perf hillclimb #1)."""
    axes = ("data", "model")
    total = int(np.prod([mesh.shape[a] for a in axes]))
    dsize = mesh.shape.get("data", 1)

    def spec(path, leaf):
        nd = len(leaf.shape)
        out = [None] * nd
        # prefer the largest dim; fall back to 'data'-only, then replicate
        order = sorted(range(nd), key=lambda i: -leaf.shape[i])
        for i in order:
            if leaf.shape[i] % total == 0:
                out[i] = axes
                return P(*out)
        for i in order:
            if leaf.shape[i] % dsize == 0:
                out[i] = "data"
                return P(*out)
        return P(*out)

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def batch_specs(cfg: ModelConfig, batch_shape, mesh):
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    if cfg.shard_mode == "fsdp":
        dp = dp + ("model",)
    sizes = [int(np.prod([mesh.shape[a] for a in dp[:k]]))
             for k in range(len(dp), 0, -1)]

    def spec(path, leaf):
        b = leaf.shape[0]
        lead = None
        for k, size in zip(range(len(dp), 0, -1), sizes):
            if b % size == 0:
                lead = dp[:k]
                break
        return P(lead, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(spec, batch_shape)


def cache_specs(cfg: ModelConfig, cache_shape, mesh, *, shard_seq: bool):
    """KV caches: batch along data axes (hd along model); if shard_seq
    (batch=1 long-context decode) shard the sequence axis instead."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    msize = mesh.shape.get("model", 1)

    def spec(path, leaf):
        name = _leaf_name(path)
        nd = len(leaf.shape)
        out = [None] * nd
        # layouts (with leading superblock axis for decoder-only, or layer
        # axis for encdec):  k/v: (L,B,S,KV,hd)  h: (L,B,di,st)
        # conv: (L,B,k,di)  C: (L,B,H,hd,hd)  n/c/h/m: (L,B,H,hd) or (L,B,H)
        if name in ("k", "v", "xk", "xv") and nd == 5:
            if shard_seq:
                seq_axes = dp + ("model",) if cfg.cache_shard == "seq" else dp
                seq_total = dp_size * (msize if cfg.cache_shard == "seq" else 1)
                if leaf.shape[2] % seq_total == 0:
                    out[2] = seq_axes
                elif leaf.shape[2] % dp_size == 0:
                    # seq not divisible by the widened data+model product:
                    # fall back to data-only — but only if THAT divides;
                    # otherwise replicate (the dp fallback used to be
                    # unconditional, producing invalid specs for odd S)
                    out[2] = dp
                if cfg.cache_shard == "hd" and leaf.shape[4] % msize == 0:
                    out[4] = "model"
                return P(*out)
            if leaf.shape[1] % dp_size == 0:
                out[1] = dp
            if cfg.cache_shard == "hd" and leaf.shape[4] % msize == 0:
                out[4] = "model"
            elif cfg.cache_shard == "seq" and leaf.shape[2] % msize == 0:
                out[2] = "model"
        elif name == "h" and nd == 4:        # mamba hidden (L,B,di,st)
            if leaf.shape[1] % dp_size == 0 and not shard_seq:
                out[1] = dp
            if leaf.shape[2] % msize == 0:
                out[2] = "model"
        elif name == "conv" and nd == 4:
            if leaf.shape[1] % dp_size == 0 and not shard_seq:
                out[1] = dp
            if leaf.shape[3] % msize == 0:
                out[3] = "model"
        elif name == "C" and nd == 5:        # mLSTM matrix memory
            if leaf.shape[1] % dp_size == 0 and not shard_seq:
                out[1] = dp
            if leaf.shape[3] % msize == 0:
                out[3] = "model"
        elif nd >= 2:
            if leaf.shape[1] % dp_size == 0 and not shard_seq:
                out[1] = dp
            if nd >= 4 and leaf.shape[-1] % msize == 0:
                out[-1] = "model"
        return P(*out)

    return jax.tree_util.tree_map_with_path(spec, cache_shape)


def to_named(mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


# ---------------------------------------------------- FL member-axis planes
# Specs for the mesh-sharded dispatch path (core/server.py): cluster members
# shard along `data` on every leading axis — shard packs (capacity, N, …),
# step masks (capacity, S), weights (capacity,).  The plane-shaped buffers
# (global plane (D,), member/bank planes (capacity, D), teacher/history
# stacks (R, D)) get their split from ``core.plane.plane_specs`` — the
# param_specs analogue for the FL plane world: on a 1D mesh the plane is
# replicated; on a 2D (data × model) mesh its COLUMNS shard along `model`.
# (Import it from ``repro.core.plane``: a re-export here would close the
# sharding → core package → server → sharding import cycle.)


def member_specs(tree, axis: str = "data"):
    """P(axis) on the leading (member) axis of every leaf; None subtrees
    pass through (absent class tables on non-balanced levels)."""
    return jax.tree.map(lambda _: P(axis), tree)


def replicated_specs(tree):
    """P() on every leaf (params/planes broadcast to all devices)."""
    return jax.tree.map(lambda _: P(), tree)


def shard_member_tree(mesh, tree, axis: str = "data"):
    """device_put every leaf row-sharded along the member axis — used to
    place cached shard packs on the mesh ONCE so repeated dispatch calls
    skip the implicit jit reshard."""
    return jax.tree.map(
        lambda x: jax.device_put(x, NamedSharding(mesh, P(axis))), tree)
