"""pjit training driver.

On real hardware this runs the production mesh; on this CPU container the
same code path runs a 1×1 mesh with reduced (``--smoke``) configs — the
end-to-end example (examples/fedrac_lm_train.py) drives it.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
      --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint
from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.data.synthetic import lm_batches, make_lm_corpus
from repro.launch import sharding
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.optim import optimizers, schedules


def build_step(cfg, opt, sched, grad_clip=1.0):
    def train_step(params, opt_state, batch, step):
        (loss, ce), grads = jax.value_and_grad(
            lambda p: registry.loss_fn(cfg, p, batch), has_aux=True)(params)
        grads = optimizers.clip_by_global_norm(grads, grad_clip)
        params, opt_state = opt.update(grads, opt_state, params, sched(step))
        return params, opt_state, ce
    return train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="olmo-1b")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="wsd",
                    choices=["constant", "cosine", "wsd"])
    ap.add_argument("--optimizer", default="adamw",
                    choices=["sgd", "momentum", "adamw"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh(1, 1)
    key = jax.random.PRNGKey(args.seed)
    params = registry.init_params(cfg, key)
    opt = optimizers.get(args.optimizer)
    opt_state = opt.init(params)
    sched = schedules.get(args.schedule, args.lr, args.steps,
                          warmup=max(1, args.steps // 10))
    step_fn = jax.jit(build_step(cfg, opt, sched), donate_argnums=(0, 1))

    corpus = make_lm_corpus(cfg.vocab_size, 200_000, seed=args.seed)
    n_params = registry.param_count(params)
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"vocab={cfg.vocab_size} mesh={dict(mesh.shape)}", flush=True)

    t0 = time.time()
    losses = []
    for step in range(args.steps):
        toks = lm_batches(corpus, args.batch, args.seq, 1,
                          seed=args.seed + step)[0]
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.frontend:
            batch["embeds"] = jnp.zeros((args.batch, 8, cfg.d_model), cfg.dtype)
        params, opt_state, ce = step_fn(params, opt_state, batch,
                                        jnp.asarray(step))
        losses.append(float(ce))
        if (step + 1) % args.log_every == 0:
            rate = args.batch * args.seq * args.log_every / (time.time() - t0)
            print(f"step {step+1:5d}  ce={np.mean(losses[-args.log_every:]):.4f}"
                  f"  tok/s={rate:,.0f}", flush=True)
            t0 = time.time()
    if args.ckpt_dir:
        path = checkpoint.save_step(args.ckpt_dir, args.steps,
                                    {"params": params})
        print("saved", path)
    print(f"final ce: first10={np.mean(losses[:10]):.4f} "
          f"last10={np.mean(losses[-10:]):.4f}")
    return losses


if __name__ == "__main__":
    main()
