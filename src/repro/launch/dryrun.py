import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
against ShapeDtypeStruct inputs (no allocation), then extract
memory_analysis / cost_analysis / collective traffic for §Roofline.

MUST set XLA_FLAGS above BEFORE any jax import — jax locks the device count
on first init.  Do not import this module from tests/benchmarks (they need
to see 1 device); invoke as ``python -m repro.launch.dryrun``.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--out benchmarks/results/dryrun]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.configs.base import ModelConfig
from repro.core.scaling import active_param_count, param_count
from repro.launch import hlo_analysis, sharding, specs
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.optim import optimizers


def make_train_step(cfg: ModelConfig, lr: float = 1e-4):
    opt = optimizers.adamw()

    def train_step(params, opt_state, batch):
        (loss, ce), grads = jax.value_and_grad(
            lambda p: registry.loss_fn(cfg, p, batch), has_aux=True)(params)
        grads = optimizers.clip_by_global_norm(grads, 1.0)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        return params, opt_state, ce

    return train_step, opt


def make_prefill_step(cfg: ModelConfig):
    def prefill(params, batch):
        logits, _ = registry.forward(cfg, params, batch)
        return logits[:, -1]
    return prefill


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, token, pos):
        return registry.decode_step(cfg, params, cache, token, pos)
    return serve_step


def make_kd_train_step(cfg_t: ModelConfig, cfg_s: ModelConfig,
                       lr: float = 1e-4, chunk: int = 0):
    """Master-slave KD training step (the paper's technique on an LM):
    teacher forward (frozen) + student update under the Hinton KD loss over
    the full (padded-)vocab logits.  chunk>0 computes the loss in sequence
    chunks from the final hiddens, never materializing both (B,S,V) logit
    tensors at once (§Perf hillclimb #3)."""
    from repro.core.distill import kd_loss
    from repro.models import transformer
    opt = optimizers.adamw()

    def full_loss(sp, t_params, batch):
        t_logits, _ = registry.forward(cfg_t, t_params, batch)
        s_logits, aux = registry.forward(cfg_s, sp, batch)
        lbl = batch["tokens"][:, 1:]
        mask = transformer.vocab_mask(cfg_s)[None, None]
        l = kd_loss(s_logits[:, :-1], lbl,
                    jax.lax.stop_gradient(t_logits[:, :-1]),
                    T=2.0, alpha=0.3, valid_mask=mask)
        return l + cfg_s.router_aux_coef * aux, l

    def chunked_loss(sp, t_params, batch):
        h_t, _ = transformer.forward(cfg_t, t_params, batch["tokens"],
                                     return_hidden=True)
        h_s, aux = transformer.forward(cfg_s, sp, batch["tokens"],
                                       return_hidden=True)
        head_t = (t_params["embed"] if cfg_t.tie_embeddings
                  else t_params["lm_head"])
        head_s = sp["embed"] if cfg_s.tie_embeddings else sp["lm_head"]
        B, S, _ = h_s.shape
        n = (S - 1) // chunk
        cut = n * chunk
        tail = (S - 1) - cut
        resh = lambda t: jnp.moveaxis(
            t[:, :cut].reshape(B, n, chunk, -1), 1, 0)
        lbl = jnp.moveaxis(batch["tokens"][:, 1:cut + 1].reshape(B, n, chunk),
                           1, 0)
        mask = transformer.vocab_mask(cfg_s)[None, None]

        def body(acc, xs):
            ht_c, hs_c, lbl_c = xs
            tl = jax.lax.stop_gradient(ht_c @ head_t.T.astype(ht_c.dtype))
            sl = hs_c @ head_s.T.astype(hs_c.dtype)
            l = kd_loss(sl, lbl_c, tl, T=2.0, alpha=0.3, valid_mask=mask)
            return acc + l, None

        if n:       # chunk > S-1: everything is tail, nothing to scan
            total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                    (resh(h_t), resh(h_s), lbl))
        else:
            total = jnp.zeros((), jnp.float32)
        # kd_loss is a MEAN over its positions, so chunk means combine by
        # token-count weighting; the (S-1) mod chunk remainder gets its own
        # (static-shape) chunk outside the scan instead of being dropped
        l = total * chunk
        if tail:
            ht_c, hs_c = h_t[:, cut:S - 1], h_s[:, cut:S - 1]
            tl = jax.lax.stop_gradient(ht_c @ head_t.T.astype(ht_c.dtype))
            sl = hs_c @ head_s.T.astype(hs_c.dtype)
            l = l + tail * kd_loss(sl, batch["tokens"][:, cut + 1:], tl,
                                   T=2.0, alpha=0.3, valid_mask=mask)
        l = l / (S - 1)
        return l + cfg_s.router_aux_coef * aux, l

    def cached_loss(sp, t_logits, batch):
        """Paper-faithful schedule (§IV-C): the trained master's logits are
        computed ONCE and broadcast to every slave cluster — the teacher
        forward amortizes over (m-1) slaves × R_f rounds, so the KD step
        consumes logits as an INPUT instead of recomputing them."""
        s_logits, aux = registry.forward(cfg_s, sp, batch)
        lbl = batch["tokens"][:, 1:]
        mask = transformer.vocab_mask(cfg_s)[None, None]
        l = kd_loss(s_logits[:, :-1], lbl, t_logits[:, :-1],
                    T=2.0, alpha=0.3, valid_mask=mask)
        return l + cfg_s.router_aux_coef * aux, l

    loss = chunked_loss if chunk else full_loss

    def kd_step(t_params, s_params, opt_state, batch):
        (tot, l), grads = jax.value_and_grad(loss, has_aux=True)(
            s_params, t_params, batch)
        grads = optimizers.clip_by_global_norm(grads, 1.0)
        s_params, opt_state = opt.update(grads, opt_state, s_params, lr)
        return s_params, opt_state, l

    def kd_step_cached(t_logits, s_params, opt_state, batch):
        (tot, l), grads = jax.value_and_grad(cached_loss, has_aux=True)(
            s_params, t_logits, batch)
        grads = optimizers.clip_by_global_norm(grads, 1.0)
        s_params, opt_state = opt.update(grads, opt_state, s_params, lr)
        return s_params, opt_state, l

    return kd_step, kd_step_cached


def make_fl_round_step(cfg: ModelConfig, lr: float = 0.05):
    """One Fed-RAC communication round ON the pod: C client replicas of a
    cluster model train locally (vmap over the client axis, sharded along
    `data`), then the n_i-weighted FedAvg aggregation runs as an all-reduce
    and the global model is re-broadcast.  This is the paper's §III-B
    workflow as a single pjit program — the FL analogue of train_step."""
    from repro.core.client import local_update

    def round_step(stack, batches, weights):
        upd = lambda p, b: local_update(
            lambda pp, bb: registry.loss_fn(cfg, pp, bb), p, b, lr)
        new_stack, losses = jax.vmap(upd)(stack, batches)
        agg = jax.tree.map(
            lambda x: jnp.tensordot(weights.astype(jnp.float32),
                                    x.astype(jnp.float32),
                                    axes=(0, 0)).astype(x.dtype), new_stack)
        C = weights.shape[0]
        stack = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (C,) + a.shape), agg)
        return stack, jnp.mean(losses)

    return round_step


def fl_client_config(cfg: ModelConfig) -> ModelConfig:
    """Edge-client-sized cluster model of the same family (~30M params)."""
    kw = dict(name=cfg.name + "-flclient", n_layers=2 * cfg.period,
              d_model=512, n_heads=8, n_kv_heads=min(8, cfg.n_kv_heads),
              head_dim=64, vocab_size=min(cfg.vocab_size, 32768),
              scan_unroll=True, remat=False)
    if cfg.d_ff:
        kw["d_ff"] = 2048
    if cfg.n_experts:
        kw.update(n_experts=8, experts_per_tok=min(2, cfg.experts_per_tok),
                  moe_impl="dense")
    if cfg.mrope_sections:
        kw["mrope_sections"] = (8, 12, 12)
    c = cfg.replace(**kw)
    c.validate()
    return c


def lower_fl_round(cfg: ModelConfig, mesh, *, clients: int = 256,
                   local_batch: int = 4, seq: int = 512, steps: int = 1):
    fcfg = fl_client_config(cfg)
    p1 = specs.params_shape(fcfg)
    stack_shape = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((clients,) + l.shape, l.dtype), p1)
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    stack_spec = jax.tree.map(lambda _: P(dp), stack_shape)
    batches = {"tokens": jax.ShapeDtypeStruct(
        (clients, steps, local_batch, seq), jnp.int32)}
    if fcfg.frontend:
        batches["embeds"] = jax.ShapeDtypeStruct(
            (clients, steps, local_batch, 8, fcfg.d_model),
            jnp.dtype(fcfg.dtype))
    b_spec = jax.tree.map(lambda _: P(dp), batches)
    weights = jax.ShapeDtypeStruct((clients,), jnp.float32)
    step = make_fl_round_step(fcfg)
    jitted = jax.jit(step,
                     in_shardings=sharding.to_named(mesh, (stack_spec, b_spec, P())),
                     out_shardings=sharding.to_named(mesh, (stack_spec, P())),
                     donate_argnums=(0,))
    with mesh:
        return jitted.lower(stack_shape, batches, weights), fcfg


def prefill_out_spec(cfg: ModelConfig, shape, mesh, dp):
    """Prefill logit out-spec: the two divisibility guards COMPOSE — the
    batch axis splits along ``dp`` only when global_batch divides it, and
    the vocab axis splits along `model` only when padded_vocab divides;
    a non-divisible batch must not resurrect a vocab split the vocab
    guard already rejected (it used to: the batch fallback overwrote the
    whole spec with P(None, 'model') unconditionally)."""
    vocab_ok = cfg.padded_vocab % mesh.shape.get("model", 1) == 0
    dp_total = int(np.prod([mesh.shape[a] for a in dp]))
    batch_ok = shape.global_batch % dp_total == 0
    return P(dp if batch_ok else None, "model" if vocab_ok else None)


def lower_one(cfg: ModelConfig, shape_name: str, mesh, *, lr: float = 1e-4,
              kd: bool = False, kd_chunk: int = 0):
    """Returns (lowered, meta).  Raises on sharding/lowering bugs."""
    shape = INPUT_SHAPES[shape_name]
    p_shape = specs.params_shape(cfg)
    p_spec = sharding.param_specs(cfg, p_shape, mesh)
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))

    if kd:
        from repro.core.scaling import compress_config
        assert shape.kind == "train", "KD dry-run uses a train shape"
        cfg_s = compress_config(cfg, 0.5, 1).replace(
            remat=cfg.remat, scan_unroll=cfg.scan_unroll,
            shard_mode=cfg.shard_mode)
        s_shape = specs.params_shape(cfg_s)
        s_spec = sharding.param_specs(cfg_s, s_shape, mesh)
        opt_shape = jax.eval_shape(optimizers.adamw().init, s_shape)
        o_spec = {"m": s_spec, "v": s_spec, "t": P()}
        batch = specs.train_inputs(cfg, shape)
        b_spec = sharding.batch_specs(cfg, batch, mesh)
        step, step_cached = make_kd_train_step(cfg, cfg_s, lr, chunk=kd_chunk)
        if kd_chunk == -1:                      # cached-teacher variant
            dpb = b_spec["tokens"][0]
            tl_shape = jax.ShapeDtypeStruct(
                (shape.global_batch, shape.seq_len, cfg.padded_vocab),
                jnp.dtype(cfg.dtype))
            tl_spec = P(dpb, None, "model")
            jitted = jax.jit(step_cached,
                             in_shardings=sharding.to_named(
                                 mesh, (tl_spec, s_spec, o_spec, b_spec)),
                             out_shardings=sharding.to_named(
                                 mesh, (s_spec, o_spec, P())),
                             donate_argnums=(1, 2))
            with mesh:
                return (jitted.lower(tl_shape, s_shape, opt_shape, batch),
                        {"kind": "kd_cached"})
        jitted = jax.jit(step,
                         in_shardings=sharding.to_named(
                             mesh, (p_spec, s_spec, o_spec, b_spec)),
                         out_shardings=sharding.to_named(
                             mesh, (s_spec, o_spec, P())),
                         donate_argnums=(1, 2))
        with mesh:
            return jitted.lower(p_shape, s_shape, opt_shape, batch), {"kind": "kd"}

    if shape.kind == "train":
        opt_shape = jax.eval_shape(optimizers.adamw().init, p_shape)
        o_spec = {"m": p_spec, "v": p_spec, "t": P()}
        batch = specs.train_inputs(cfg, shape)
        b_spec = sharding.batch_specs(cfg, batch, mesh)
        step, _ = make_train_step(cfg, lr)
        jitted = jax.jit(step,
                         in_shardings=sharding.to_named(mesh, (p_spec, o_spec, b_spec)),
                         out_shardings=sharding.to_named(mesh, (p_spec, o_spec, P())),
                         donate_argnums=(0, 1))
        with mesh:
            return jitted.lower(p_shape, opt_shape, batch), {"kind": "train"}

    if shape.kind == "prefill":
        batch = specs.train_inputs(cfg, shape)
        b_spec = sharding.batch_specs(cfg, batch, mesh)
        step = make_prefill_step(cfg)
        out_spec = prefill_out_spec(cfg, shape, mesh, dp)
        jitted = jax.jit(step,
                         in_shardings=sharding.to_named(mesh, (p_spec, b_spec)),
                         out_shardings=sharding.to_named(mesh, out_spec))
        with mesh:
            return jitted.lower(p_shape, batch), {"kind": "prefill"}

    # decode
    token, pos, cache_shape = specs.decode_inputs(cfg, shape)
    shard_seq = shape.global_batch == 1
    c_spec = sharding.cache_specs(cfg, cache_shape, mesh, shard_seq=shard_seq)
    t_spec = sharding.batch_specs(cfg, {"t": token}, mesh)["t"]
    step = make_serve_step(cfg)
    logit_spec = P(None, None, "model") if cfg.padded_vocab % mesh.shape.get("model", 1) == 0 else P()
    jitted = jax.jit(step,
                     in_shardings=sharding.to_named(mesh, (p_spec, c_spec, t_spec, P())),
                     out_shardings=sharding.to_named(mesh, (logit_spec, c_spec)),
                     donate_argnums=(1,))
    with mesh:
        return jitted.lower(p_shape, cache_shape, token, pos), {"kind": "decode"}


def _depth_cfg(cfg: ModelConfig, n_sb: int) -> ModelConfig:
    if cfg.family == "encdec":
        return cfg.replace(n_layers=n_sb, n_enc_layers=n_sb,
                           name=f"{cfg.name}@d{n_sb}")
    return cfg.replace(n_layers=n_sb * cfg.period, name=f"{cfg.name}@d{n_sb}")


def _measure(cfg: ModelConfig, shape_name: str, mesh, **kw):
    """(flops, bytes_accessed, collective_total, coll_detail, compiled)."""
    lowered, _ = lower_one(cfg, shape_name, mesh, **kw)
    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    coll = hlo_analysis.collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(coll["total"]), coll, compiled)


def analyze(cfg: ModelConfig, shape_name: str, mesh, **lower_kw) -> dict:
    """Compile at full depth (memory truth) + depths 1·period and 2·period.

    XLA's cost_analysis does NOT multiply while-loop (scan) bodies by trip
    count, so flops/bytes/collectives of the scanned stack are extrapolated:
    corrected = f(1) + (n_sb-1)·(f(2)-f(1)).  Inner TIME recurrences
    (mamba chunk scan, m/sLSTM step scans) are still undercounted inside the
    body — the analytic cross-check (scaling.analytic_step_flops) covers
    those; the roofline uses max(hlo_corrected, analytic).
    """
    chips = mesh.devices.size
    shape = INPUT_SHAPES[shape_name]
    n_sb = (cfg.n_layers if cfg.family == "encdec" else cfg.n_superblocks)

    f_full, b_full, c_full, coll_full, compiled = _measure(
        cfg, shape_name, mesh, **lower_kw)
    # depth-1/2 UNROLLED programs make loop trip counts explicit in the HLO
    # (at scan depth the cost analyzer sees the body once, whatever the depth).
    u1 = _depth_cfg(cfg, 1).replace(scan_unroll=True)
    u2 = _depth_cfg(cfg, 2).replace(scan_unroll=True)
    f1, b1, c1, _, _ = _measure(u1, shape_name, mesh, **lower_kw)
    f2, b2, c2, _, _ = _measure(u2, shape_name, mesh, **lower_kw)
    # clamp: XLA sometimes CSEs the unrolled depth-2 program below depth-1
    # (seen with FSDP all-gathers) — never extrapolate below the direct
    # measurements.
    extrap = lambda x1, x2, xf: max(x1 + (n_sb - 1) * (x2 - x1), x2, xf, 0.0)
    flops, bytes_acc, coll_b = (extrap(f1, f2, f_full), extrap(b1, b2, b_full),
                                extrap(c1, c2, c_full))
    depth_meas = {"d1": [f1, b1, c1], "d2": [f2, b2, c2]}

    analytic = scaling_analytic(cfg, shape, chips)
    roof = hlo_analysis.Roofline(
        flops_per_device=max(flops, analytic["flops_per_device"]),
        bytes_per_device=bytes_acc,
        collective_bytes_per_device=coll_b,
        chips=chips, model_flops_total=analytic["model_flops_total"])
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
    except Exception as e:                                   # CPU backend quirk
        mem["error"] = str(e)
    mem["params_total_bytes"] = param_count(cfg) * (2 if cfg.dtype == "bfloat16" else 4)
    mem["params_bytes_per_chip"] = mem["params_total_bytes"] / chips
    hbm = mem.get("temp_size_in_bytes", 0) + mem.get("argument_size_in_bytes", 0)
    mem["hbm_per_chip_est"] = hbm
    mem["fits_16g"] = bool(hbm < 16e9)
    return {
        "arch": cfg.name, "shape": shape_name, "chips": chips,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "kind": shape.kind, "remat": cfg.remat, "moe_shard": cfg.moe_shard,
        "hlo_raw": {"flops": f_full, "bytes": b_full, "collective": c_full},
        "hlo_depth": depth_meas,
        "hlo_corrected": {"flops": flops, "bytes": bytes_acc,
                          "collective": coll_b},
        "analytic": analytic,
        "collectives": coll_full,
        "memory": mem,
        "roofline": roof.as_dict(),
        "params": param_count(cfg),
        "active_params": active_param_count(cfg),
    }


def scaling_analytic(cfg: ModelConfig, shape, chips: int) -> dict:
    from repro.core.scaling import analytic_step_flops
    total = analytic_step_flops(cfg, shape.kind, shape.global_batch,
                                shape.seq_len, remat=cfg.remat)
    if shape.kind == "train":
        mf = 6.0 * active_param_count(cfg) * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        mf = 2.0 * active_param_count(cfg) * shape.global_batch * shape.seq_len
    else:
        mf = 2.0 * active_param_count(cfg) * shape.global_batch
    return {"flops_total": total, "flops_per_device": total / chips,
            "model_flops_total": mf}


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            *, force: bool = False, variant: str = "", **cfg_overrides) -> dict:
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch}_{shape_name}_{mesh_tag}" + (f"_{variant}" if variant else "")
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch)
    # Production default: rematerialize superblocks in training (without it
    # the 4k×256 train activations do not fit 16 GB HBM — see §Perf).
    if INPUT_SHAPES[shape_name].kind == "train" and "remat" not in cfg_overrides:
        cfg = cfg.replace(remat=True)
    ok, why = specs.applicable(cfg, shape_name)
    os.makedirs(out_dir, exist_ok=True)
    if not ok:
        res = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "skipped": why}
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        return res
    lower_kw = {}
    for k in ("kd", "kd_chunk"):
        if k in cfg_overrides:
            lower_kw[k] = cfg_overrides.pop(k)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        res = analyze(cfg, shape_name, mesh, **lower_kw)
        res.update(wall_s=round(time.time() - t0, 1), variant=variant)
    except Exception:
        res = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "error": traceback.format_exc()}
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    return res


def run_fl(arch: str, multi_pod: bool, out_dir: str, force: bool = False) -> dict:
    """Dry-run one Fed-RAC FL round (client-parallel) on the production mesh."""
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    path = os.path.join(out_dir, f"{arch}_fl-round_{mesh_tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    os.makedirs(out_dir, exist_ok=True)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        clients, B, S, steps = 256, 4, 512, 1
        lowered, fcfg = lower_fl_round(get_config(arch), mesh, clients=clients,
                                       local_batch=B, seq=S, steps=steps)
        compiled = lowered.compile()
        cost = compiled.cost_analysis() or {}
        coll = hlo_analysis.collective_bytes(compiled.as_text())
        chips = mesh.devices.size
        n_p = param_count(fcfg)
        analytic = 6.0 * n_p * clients * B * S * steps
        roof = hlo_analysis.Roofline(
            flops_per_device=max(float(cost.get("flops", 0.0)), analytic / chips),
            bytes_per_device=float(cost.get("bytes accessed", 0.0)),
            collective_bytes_per_device=float(coll["total"]),
            chips=chips, model_flops_total=analytic)
        res = {"arch": arch, "shape": "fl_round", "mesh": mesh_tag,
               "kind": "fl_round", "client_params": n_p, "clients": clients,
               "collectives": coll, "roofline": roof.as_dict(),
               "wall_s": round(time.time() - t0, 1)}
    except Exception:
        res = {"arch": arch, "shape": "fl_round", "mesh": mesh_tag,
               "error": traceback.format_exc()}
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="")
    ap.add_argument("--moe-shard", choices=["tp", "ep"])
    ap.add_argument("--moe-chunk", type=int, default=0)
    ap.add_argument("--mlstm-chunk", action="store_true")
    ap.add_argument("--attn-blocked", action="store_true")
    ap.add_argument("--shard-mode", choices=["tp", "fsdp"])
    ap.add_argument("--cache-shard", choices=["hd", "seq", "batch"])
    ap.add_argument("--kd", action="store_true",
                    help="lower the master-slave KD train step")
    ap.add_argument("--fl", action="store_true",
                    help="lower one client-parallel Fed-RAC FL round")
    ap.add_argument("--kd-chunk", type=int, default=0)
    ap.add_argument("--kd-cached", action="store_true",
                    help="teacher logits as input (paper's broadcast schedule)")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args()

    overrides = {}
    if args.moe_shard:
        overrides["moe_shard"] = args.moe_shard
    if args.moe_chunk:
        overrides["moe_chunk_groups"] = args.moe_chunk
    if args.mlstm_chunk:
        overrides["mlstm_impl"] = "chunk"
    if args.attn_blocked:
        overrides["attn_impl"] = "blocked"
    if args.shard_mode:
        overrides["shard_mode"] = args.shard_mode
    if args.cache_shard:
        overrides["cache_shard"] = args.cache_shard
    if args.kd:
        overrides["kd"] = True
        if args.kd_cached:
            overrides["kd_chunk"] = -1
        elif args.kd_chunk:
            overrides["kd_chunk"] = args.kd_chunk
    if args.remat:
        overrides["remat"] = True
    if args.no_remat:
        overrides["remat"] = False

    if args.fl:
        res = run_fl(args.arch, args.multi_pod, args.out, force=args.force)
        status = "ERROR" if "error" in res else "OK"
        dom = res.get("roofline", {}).get("dominant", "-")
        print(f"{args.arch:26s} fl_round     "
              f"{'2x16x16' if args.multi_pod else '16x16':8s} {status:6s} "
              f"dom={dom}", flush=True)
        if status == "ERROR":
            print(res["error"].splitlines()[-1])
        return

    combos = []
    if args.all:
        for arch in list_archs():
            for shape in INPUT_SHAPES:
                for mp in (False, True):
                    combos.append((arch, shape, mp))
    else:
        combos = [(args.arch, args.shape, args.multi_pod)]

    for arch, shape, mp in combos:
        t0 = time.time()
        res = run_one(arch, shape, mp, args.out, force=args.force,
                      variant=args.variant, **overrides)
        status = ("SKIP" if "skipped" in res
                  else "ERROR" if "error" in res else "OK")
        dom = res.get("roofline", {}).get("dominant", "-")
        print(f"{arch:26s} {shape:12s} {'2x16x16' if mp else '16x16':8s} "
              f"{status:6s} dom={dom:10s} {time.time() - t0:6.1f}s", flush=True)
        if status == "ERROR":
            print(res["error"].splitlines()[-1], flush=True)


if __name__ == "__main__":
    main()
