"""HLO-text analysis: collective-operand bytes + roofline terms.

cost_analysis() gives per-device FLOPs / bytes-accessed but NOT collective
traffic; we parse the post-SPMD HLO for all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute result shapes and sum bytes.

Hardware constants (TPU v5e targets, per chip):
  197 TFLOP/s bf16  ·  819 GB/s HBM  ·  ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.  %all-reduce.5 = bf16[256,4096]{1,0} all-reduce(...)
#       %ar = (f32[8,128]{1,0}, f32[8]{0}) all-reduce(...)
_LINE_RE = re.compile(
    r"=\s*(?P<rtype>\([^)]*\)|[a-z0-9]+\[[^\]]*\][^\s]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<suffix>-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(tok: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(tok):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind result-operand bytes (per device program)."""
    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for m in _LINE_RE.finditer(hlo_text):
        op = m.group("op")
        # `-done` ops alias the `-start` buffer; count once (start only).
        if m.group("suffix") == "-done":
            continue
        out[op] += _shape_bytes(m.group("rtype"))
        counts[op] += 1
    return {"bytes": out, "counts": counts, "total": sum(out.values())}


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    chips: int
    model_flops_total: float = 0.0       # 6·N_active·D (analytic)

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        hw = self.flops_per_device * self.chips
        return self.model_flops_total / hw if hw else 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_total": self.model_flops_total,
            "useful_flops_ratio": self.useful_flops_ratio,
        }
