"""Heterogeneity-simulation launcher: Fed-RAC under an event trace.

  PYTHONPATH=src python -m repro.launch.sim_run --trace dropout \
      --participants 16 --rounds 8 --mar-policy drop --dropout-rate 0.2

Builds the usual Fed-RAC pipeline (clustering → compaction → Procedure-2
assignment) on synthetic federated data, then hands it to
``repro.sim.HeterogeneitySim``: per-round MAR deadline enforcement,
dropouts/arrivals, resource drift through dynamic reassignment, straggler
spikes — and prints the per-round timeline plus summary (optionally JSON).

``--mode async`` swaps the global round barrier for the continuous-time
async parameter server: per-cluster clocks, pull-version/push-delta
dispatch, streaming staleness-discounted merges, with ``--max-staleness``
bounding how far any cluster may lead the slowest (0 = synchronized
arrivals ≡ the sync buffered path, bit-for-bit).

``--fleet-size N`` switches to the vectorized orchestration simulator
(``repro.sim.FleetSim``): N Table-III-resampled participants as a struct-of-
arrays ``Fleet``, columnar traces, sampled-Dunn Procedure 1, FedCS
selection — no model training, fleet-scale scheduling/accounting only.

  PYTHONPATH=src python -m repro.launch.sim_run --fleet-size 100000 \
      --rounds 3 --trace mixed --select fedcs --select-budget 64

The crash-safety surface lives here too: ``--ckpt-dir`` arms round-boundary
run-state checkpoints (cadence ``--ckpt-every``, retention ``--ckpt-keep``),
``--resume`` continues from the newest *valid* one bit-identically, SIGTERM/
SIGINT flush telemetry and write a final checkpoint before exiting
``128+signum``, and the fault-injection knobs (``--kill-at-round``,
``--kill-mid-block``, ``--corrupt-ckpt``) drive the kill-and-resume CI lane.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import signal
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.run_state import make_checkpointer
from repro.core import server as srv
from repro.core.families import cnn_family
from repro.core.resources import (LAMBDA_EQUAL, LAMBDA_PAPER, Fleet,
                                  participants_from_matrix)
from repro.launch.mesh import make_sim_mesh
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import SPECS, make_classification, train_test_split
from repro.obs import make_observability
from repro.sim import (SCENARIOS, FleetSim, FleetSimConfig, HeterogeneitySim,
                       SimConfig, make_fleet_trace, make_trace,
                       sample_profiles, scenario_knobs)
from repro.sim.faults import (CORRUPTION_MODES, FaultInjector, FaultPlan,
                              GracefulShutdown, corrupt_checkpoint)


def _trace_knobs(args) -> dict:
    """CLI rate knobs the chosen scenario accepts, only when explicitly set
    (``make_trace`` rejects unknown knobs — a typo'd ``--dropout-rate`` on a
    drift trace must fail loudly, not silently no-op)."""
    knobs = {"dropout_rate": args.dropout_rate, "drift_rate": args.drift_rate,
             "spike_rate": args.spike_rate}
    explicit = {k: v for k, v in knobs.items() if v is not None}
    unknown = set(explicit) - scenario_knobs(args.trace)
    if unknown:
        raise SystemExit(
            f"--{sorted(unknown)[0].replace('_', '-')} does not apply to "
            f"trace {args.trace!r} (knobs: "
            f"{sorted(scenario_knobs(args.trace)) or 'none'})")
    return explicit


def _crash_harness(args):
    """(RunCheckpointer | None, FaultInjector | None) from the crash-safety
    flags; ``--corrupt-ckpt`` damages the newest checkpoint *before* the
    resume read so the degrade-to-previous-valid path is exercised."""
    if args.resume and not args.ckpt_dir:
        raise SystemExit("--resume requires --ckpt-dir")
    if args.corrupt_ckpt and not args.ckpt_dir:
        raise SystemExit("--corrupt-ckpt requires --ckpt-dir")
    if args.kill_mid_block is not None:
        if args.fleet_size:
            raise SystemExit("--kill-mid-block does not apply to the fleet "
                             "simulator (no dispatch blocks)")
        if args.rounds_per_dispatch <= 1:
            raise SystemExit("--kill-mid-block needs --rounds-per-dispatch "
                             ">1 (mid-block faults live inside fused blocks)")
    if args.corrupt_ckpt:
        path = corrupt_checkpoint(args.ckpt_dir, args.corrupt_ckpt)
        print(f"# corrupted newest checkpoint ({args.corrupt_ckpt}): {path}")
    ckpt = None
    if args.ckpt_dir:
        ckpt = make_checkpointer(args.ckpt_dir, every=args.ckpt_every,
                                 keep=args.ckpt_keep, resume=args.resume)
    faults = None
    if args.kill_at_round is not None or args.kill_mid_block is not None:
        faults = FaultInjector(FaultPlan(kill_at_round=args.kill_at_round,
                                         kill_mid_block=args.kill_mid_block))
    return ckpt, faults


@contextlib.contextmanager
def _graceful_signals():
    """SIGTERM/SIGINT raise ``GracefulShutdown`` inside the run loop so the
    launcher can flush telemetry and write a final checkpoint; original
    handlers are restored on exit."""
    def handler(signum, frame):
        raise GracefulShutdown(signum)
    old = {s: signal.signal(s, handler)
           for s in (signal.SIGTERM, signal.SIGINT)}
    try:
        yield
    finally:
        for s, h in old.items():
            signal.signal(s, h)


def _params_crc32(params: dict) -> dict:
    """Per-level CRC32 over the raveled parameter bytes — the report's
    bit-exactness witness for the kill-and-resume CI comparison."""
    out = {}
    for lvl in sorted(params):
        crc = 0
        for leaf in jax.tree.leaves(params[lvl]):
            crc = zlib.crc32(np.ascontiguousarray(leaf).tobytes(), crc)
        out[str(lvl)] = crc
    return out


def _flush_obs(args, obs) -> None:
    if obs is None:
        return
    if args.metrics_out:
        n = obs.registry.to_jsonl(args.metrics_out)
        print(f"# metrics: {n} lines -> {args.metrics_out}")
    if args.trace_out:
        obs.tracer.write(args.trace_out)
        print(f"# trace: {len(obs.tracer.events())} spans -> "
              f"{args.trace_out}"
              + (" (fenced timings)" if args.fence else ""))


def _graceful_exit(args, sim, obs, signum) -> None:
    """The SIGTERM/SIGINT path: final checkpoint, telemetry flush, partial
    report, nonzero exit (128+signum, the shell convention)."""
    step = sim.save_now()
    print(f"# signal {signum}: "
          + (f"final checkpoint at round {step}" if step is not None
             else "no checkpoint written (none armed or no round done)"))
    _flush_obs(args, obs)
    if args.report_out and sim.report is not None:
        rep = sim.report
        doc = rep.to_dict() if hasattr(rep, "to_dict") else rep.summary()
        doc["interrupted"] = signum
        with open(args.report_out, "w") as f:
            json.dump(doc, f, default=float)
        print(f"# partial report -> {args.report_out}")
    raise SystemExit(128 + signum)


def build(args):
    ds = make_classification(args.dataset, args.samples, seed=args.seed)
    train, test = train_test_split(ds)
    idx = dirichlet_partition(train.y, args.participants,
                              alpha=args.dirichlet, seed=args.seed)
    V = sample_profiles(args.participants, seed=args.seed)
    parts = participants_from_matrix(V, n_data=[len(p) for p in idx])
    client_data = [{"x": train.x[p], "y": train.y[p]} for p in idx]
    shape, classes = SPECS[args.dataset]
    fam = cnn_family(classes=classes, in_channels=shape[-1],
                     alpha=args.alpha, base_width=args.base_width,
                     input_hw=shape[0])
    lam = LAMBDA_PAPER if args.lam == "paper" else LAMBDA_EQUAL
    cfg = srv.FLConfig(alpha=args.alpha, steps_per_round=args.steps_per_round,
                       lr=args.lr, lam=lam, compact_to=args.compact_to,
                       seed=args.seed, E=args.epochs, mar=args.mar,
                       kappa=args.kappa, pad_clusters=not args.no_pad,
                       aggregation=("buffered" if args.mar_policy == "buffer"
                                    else "sync"),
                       staleness_discount=args.staleness_discount,
                       rounds_per_dispatch=args.rounds_per_dispatch,
                       tp_forward=args.tp_forward)
    mesh = make_sim_mesh(args.mesh_shape) if args.mesh_shape else None
    eng = srv.FedRAC(parts, client_data, fam, cfg, classes=classes,
                     mesh=mesh).setup()
    testb = {"x": jnp.asarray(test.x), "y": jnp.asarray(test.y)}
    return eng, testb


def run_fleet(args):
    """Vectorized fleet path: Fleet + FleetTrace + FleetSim, no training."""
    n = args.fleet_size
    ckpt, faults = _crash_harness(args)
    fleet = Fleet.from_matrix(sample_profiles(n, seed=args.seed))
    trace = make_fleet_trace(args.trace, n, args.rounds, seed=args.seed,
                             **_trace_knobs(args))
    lam = LAMBDA_PAPER if args.lam == "paper" else LAMBDA_EQUAL
    sim = FleetSim(fleet, trace, FleetSimConfig(
        rounds=args.rounds, mar_policy=args.mar_policy, select=args.select,
        select_budget=args.select_budget, schedule=args.schedule,
        mar=args.mar or 0.0, kappa=args.kappa, lam=lam, seed=args.seed,
        mode=args.mode), checkpoint=ckpt, faults=faults)
    with _graceful_signals():
        try:
            report = sim.run()
        except GracefulShutdown as e:
            _graceful_exit(args, sim, None, e.signum)
    s = report.summary()
    print(f"fleet={n} k={report.k} MAR={report.mar} "
          f"cluster_sizes={s['cluster_sizes']}")
    for r in report.rows:
        print(f"r{r.round:03d}  Δ={r.duration:8.3f}s  events={r.events}  "
              f"active={int(r.active.sum())} masked={int(r.masked.sum())} "
              f"dropped={int(r.dropped.sum())} off={int(r.offline.sum())} "
              f"unsel={int(r.unselected.sum())} "
              f"banked={int(r.banked.sum())} flushed={int(r.flushed.sum())}")
    if args.json:
        print(json.dumps(s, default=float))
    if args.report_out:
        with open(args.report_out, "w") as f:
            json.dump(s, f, default=float)
        print(f"# report -> {args.report_out}")
    return report


def run(args):
    if args.fleet_size:
        return run_fleet(args)
    ckpt, faults = _crash_harness(args)
    eng, testb = build(args)
    print(f"k_optimal={eng.k_optimal} compacted_to={eng.m} "
          f"MAR(master)={eng.specs[0].mar:.2f}s "
          f"members={ {l: len(v) for l, v in eng.assignment.members.items()} }")
    if eng.mesh is not None:
        plane_txt = (f", plane columns sharded {eng._mesh_m}-way"
                     if eng._mesh_m > 1 else "")
        fwd_txt = (", TP member forward" if eng._tp else
                   ", replicated member forward" if eng._mesh_m > 1 else "")
        print(f"mesh={dict(eng.mesh.shape)} "
              f"(member axis sharded {eng._mesh_n}-way{plane_txt}{fwd_txt})")
    trace = make_trace(args.trace, args.participants, args.rounds,
                       seed=args.seed, **_trace_knobs(args))
    obs = None
    if args.metrics_out or args.trace_out or args.fence:
        obs = make_observability(fence=args.fence)
    sim = HeterogeneitySim(eng, trace, SimConfig(
        rounds=args.rounds, mar_policy=args.mar_policy,
        schedule=args.schedule, eval_every=args.eval_every,
        select=args.select, select_budget=args.select_budget,
        mode=args.mode, max_staleness=args.max_staleness), obs=obs,
        checkpoint=ckpt, faults=faults)
    with _graceful_signals():
        try:
            report = sim.run(testb)
        except GracefulShutdown as e:
            _graceful_exit(args, sim, obs, e.signum)
    print(report.timeline())
    try:
        stats = eng.compile_stats()
        print(f"# round programs={len(stats)} "
              f"xla_compiles={sum(stats.values())} "
              f"(padding {'on' if eng.cfg.pad_clusters else 'off'})")
    except RuntimeError:
        print("# compile telemetry unavailable on this jax build")
    _flush_obs(args, obs)
    if args.report_out:
        doc = report.to_dict()
        doc["params_crc32"] = _params_crc32(sim.params)
        with open(args.report_out, "w") as f:
            json.dump(doc, f, default=float)
        print(f"# report -> {args.report_out}")
    if args.json:
        print(json.dumps(report.to_dict(), default=float))
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="dropout", choices=sorted(SCENARIOS))
    ap.add_argument("--mar-policy", default="drop",
                    choices=["drop", "mask", "wait", "buffer"])
    ap.add_argument("--staleness-discount", type=float, default=0.6,
                    help="per-round weight decay of banked async updates "
                         "(buffer policy)")
    ap.add_argument("--no-pad", action="store_true",
                    help="disable compile-stable capacity padding "
                         "(retraces on every cluster-cardinality change)")
    ap.add_argument("--rounds-per-dispatch", type=int, default=1,
                    help=">1 runs the device-resident pipeline: up to that "
                         "many rounds fused per cluster into one scan "
                         "program between events (in-program sampling, "
                         "flat-plane aggregation, donated buffers)")
    ap.add_argument("--mesh-shape", default=None, metavar="DATA[xMODEL]",
                    help="shard the dispatch path over a device mesh, e.g. "
                         "'8', '8x1' (member axis only) or '4x2' (members "
                         "along data AND plane/bank/teacher columns along "
                         "model — for member models too large to replicate "
                         "per device).  Requires --rounds-per-dispatch >1; "
                         "per-round plane aggregation becomes local "
                         "(data × model)-subgrid reduce + one psum over "
                         "data; on CPU force devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8")
    ap.add_argument("--tp-forward", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="on a 2D mesh, run the member FORWARD tensor-"
                         "parallel over the model axis (GSPMD-partitioned "
                         "member step: per-layer activation collectives "
                         "only, no transient full-plane all-gather); "
                         "--no-tp-forward keeps the legacy shard_map path "
                         "that gathers plane columns and replicates the "
                         "forward per device")
    ap.add_argument("--schedule", default="parallel",
                    choices=["parallel", "sequential"])
    ap.add_argument("--mode", default="sync", choices=["sync", "async"],
                    help="async: continuous-time parameter server — each "
                         "cluster runs on its own clock, pulls the plane "
                         "version, pushes its delta at its own completion "
                         "time (streaming staleness-discounted merge); "
                         "requires --schedule parallel")
    ap.add_argument("--max-staleness", type=int, default=None, metavar="K",
                    help="async: max version lead of any cluster over the "
                         "slowest one; 0 = synchronized arrivals "
                         "(reproduces the sync buffered path bit-exactly), "
                         "omitted = unbounded")
    ap.add_argument("--dropout-rate", type=float, default=None,
                    help="per-round dropout probability (dropout/mixed "
                         "traces; scenario default when omitted)")
    ap.add_argument("--drift-rate", type=float, default=None,
                    help="per-round resource-drift probability (drift/mixed)")
    ap.add_argument("--spike-rate", type=float, default=None,
                    help="per-round straggler-spike probability "
                         "(straggler/mixed)")
    ap.add_argument("--fleet-size", type=int, default=0, metavar="N",
                    help="run the vectorized FleetSim over N resampled "
                         "participants instead of the training simulator")
    ap.add_argument("--select", default="all", choices=["all", "fedcs"],
                    help="per-cluster client selection (fedcs: greedy "
                         "deadline-aware admission, arXiv:1804.08333)")
    ap.add_argument("--select-budget", type=int, default=0,
                    help="fedcs: max clients admitted per cluster per round "
                         "(0 = deadline-bounded only)")
    ap.add_argument("--dataset", default="synth-mnist", choices=list(SPECS))
    ap.add_argument("--participants", type=int, default=16)
    ap.add_argument("--samples", type=int, default=1600)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--steps-per-round", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--base-width", type=float, default=0.25)
    ap.add_argument("--dirichlet", type=float, default=1.0)
    ap.add_argument("--compact-to", type=int, default=3)
    ap.add_argument("--lam", default="paper", choices=["paper", "equal"])
    ap.add_argument("--mar", type=float, default=None,
                    help="explicit MAR budget (s); default auto-calibrates")
    ap.add_argument("--kappa", type=float, default=0.7)
    ap.add_argument("--eval-every", type=int, default=2)
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="export the metrics registry (counters, gauges, "
                         "per-round tables) as JSON Lines")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export a Chrome-trace/Perfetto JSON of the round "
                         "pipeline (engine rounds, dispatch blocks, "
                         "compiles, transfers)")
    ap.add_argument("--fence", action="store_true",
                    help="block_until_ready inside spans so timings cover "
                         "device execution, not just dispatch (serializes "
                         "the pipeline — measurement mode)")
    ap.add_argument("--report-out", default=None, metavar="PATH",
                    help="write report.to_dict() JSON (summary + rows) — "
                         "pairs with repro.obs.validate --report")
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--ckpt-dir", default=None, metavar="DIR",
                    help="arm crash-safe run-state checkpoints: versioned "
                         "manifest + CRC32 snapshots of planes, bank, "
                         "sampler position, event queue, fleet arrays and "
                         "metrics tables at round boundaries")
    ap.add_argument("--ckpt-every", type=int, default=1, metavar="R",
                    help="checkpoint cadence in rounds (default 1)")
    ap.add_argument("--ckpt-keep", type=int, default=3, metavar="K",
                    help="retain the last K checkpoints (default 3)")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the newest VALID checkpoint under "
                         "--ckpt-dir (corrupt/truncated ones are skipped "
                         "with a warning); bit-identical to the "
                         "uninterrupted run")
    ap.add_argument("--kill-at-round", type=int, default=None, metavar="R",
                    help="fault injection: SIGKILL this process at the "
                         "first round boundary >= R (after the boundary "
                         "checkpoint); with --mode async, R counts MERGE "
                         "EVENTS (the async checkpoint cadence)")
    ap.add_argument("--kill-mid-block", type=int, default=None, metavar="R",
                    help="fault injection: SIGKILL inside the dispatch "
                         "block covering round R, after the fused program "
                         "ran but before its rounds are recorded")
    ap.add_argument("--corrupt-ckpt", default=None, choices=CORRUPTION_MODES,
                    help="damage the newest checkpoint under --ckpt-dir "
                         "before anything else runs (degradation testing)")
    args = ap.parse_args(argv)
    return run(args)


if __name__ == "__main__":
    main()
