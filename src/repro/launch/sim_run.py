"""Heterogeneity-simulation launcher: Fed-RAC under an event trace.

  PYTHONPATH=src python -m repro.launch.sim_run --trace dropout \
      --participants 16 --rounds 8 --mar-policy drop --dropout-rate 0.2

Builds the usual Fed-RAC pipeline (clustering → compaction → Procedure-2
assignment) on synthetic federated data, then hands it to
``repro.sim.HeterogeneitySim``: per-round MAR deadline enforcement,
dropouts/arrivals, resource drift through dynamic reassignment, straggler
spikes — and prints the per-round timeline plus summary (optionally JSON).

``--fleet-size N`` switches to the vectorized orchestration simulator
(``repro.sim.FleetSim``): N Table-III-resampled participants as a struct-of-
arrays ``Fleet``, columnar traces, sampled-Dunn Procedure 1, FedCS
selection — no model training, fleet-scale scheduling/accounting only.

  PYTHONPATH=src python -m repro.launch.sim_run --fleet-size 100000 \
      --rounds 3 --trace mixed --select fedcs --select-budget 64
"""
from __future__ import annotations

import argparse
import json

import jax.numpy as jnp

from repro.core import server as srv
from repro.core.families import cnn_family
from repro.core.resources import (LAMBDA_EQUAL, LAMBDA_PAPER, Fleet,
                                  participants_from_matrix)
from repro.launch.mesh import make_sim_mesh
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import SPECS, make_classification, train_test_split
from repro.obs import make_observability
from repro.sim import (SCENARIOS, FleetSim, FleetSimConfig, HeterogeneitySim,
                       SimConfig, make_fleet_trace, make_trace,
                       sample_profiles, scenario_knobs)


def _trace_knobs(args) -> dict:
    """CLI rate knobs the chosen scenario accepts, only when explicitly set
    (``make_trace`` rejects unknown knobs — a typo'd ``--dropout-rate`` on a
    drift trace must fail loudly, not silently no-op)."""
    knobs = {"dropout_rate": args.dropout_rate, "drift_rate": args.drift_rate,
             "spike_rate": args.spike_rate}
    explicit = {k: v for k, v in knobs.items() if v is not None}
    unknown = set(explicit) - scenario_knobs(args.trace)
    if unknown:
        raise SystemExit(
            f"--{sorted(unknown)[0].replace('_', '-')} does not apply to "
            f"trace {args.trace!r} (knobs: "
            f"{sorted(scenario_knobs(args.trace)) or 'none'})")
    return explicit


def build(args):
    ds = make_classification(args.dataset, args.samples, seed=args.seed)
    train, test = train_test_split(ds)
    idx = dirichlet_partition(train.y, args.participants,
                              alpha=args.dirichlet, seed=args.seed)
    V = sample_profiles(args.participants, seed=args.seed)
    parts = participants_from_matrix(V, n_data=[len(p) for p in idx])
    client_data = [{"x": train.x[p], "y": train.y[p]} for p in idx]
    shape, classes = SPECS[args.dataset]
    fam = cnn_family(classes=classes, in_channels=shape[-1],
                     alpha=args.alpha, base_width=args.base_width,
                     input_hw=shape[0])
    lam = LAMBDA_PAPER if args.lam == "paper" else LAMBDA_EQUAL
    cfg = srv.FLConfig(alpha=args.alpha, steps_per_round=args.steps_per_round,
                       lr=args.lr, lam=lam, compact_to=args.compact_to,
                       seed=args.seed, E=args.epochs, mar=args.mar,
                       kappa=args.kappa, pad_clusters=not args.no_pad,
                       aggregation=("buffered" if args.mar_policy == "buffer"
                                    else "sync"),
                       staleness_discount=args.staleness_discount,
                       rounds_per_dispatch=args.rounds_per_dispatch)
    mesh = make_sim_mesh(args.mesh_shape) if args.mesh_shape else None
    eng = srv.FedRAC(parts, client_data, fam, cfg, classes=classes,
                     mesh=mesh).setup()
    testb = {"x": jnp.asarray(test.x), "y": jnp.asarray(test.y)}
    return eng, testb


def run_fleet(args):
    """Vectorized fleet path: Fleet + FleetTrace + FleetSim, no training."""
    n = args.fleet_size
    fleet = Fleet.from_matrix(sample_profiles(n, seed=args.seed))
    trace = make_fleet_trace(args.trace, n, args.rounds, seed=args.seed,
                             **_trace_knobs(args))
    lam = LAMBDA_PAPER if args.lam == "paper" else LAMBDA_EQUAL
    sim = FleetSim(fleet, trace, FleetSimConfig(
        rounds=args.rounds, mar_policy=args.mar_policy, select=args.select,
        select_budget=args.select_budget, schedule=args.schedule,
        mar=args.mar or 0.0, kappa=args.kappa, lam=lam, seed=args.seed))
    report = sim.run()
    s = report.summary()
    print(f"fleet={n} k={report.k} MAR={report.mar} "
          f"cluster_sizes={s['cluster_sizes']}")
    for r in report.rows:
        print(f"r{r.round:03d}  Δ={r.duration:8.3f}s  events={r.events}  "
              f"active={int(r.active.sum())} masked={int(r.masked.sum())} "
              f"dropped={int(r.dropped.sum())} off={int(r.offline.sum())} "
              f"unsel={int(r.unselected.sum())} "
              f"banked={int(r.banked.sum())} flushed={int(r.flushed.sum())}")
    if args.json:
        print(json.dumps(s, default=float))
    if args.report_out:
        with open(args.report_out, "w") as f:
            json.dump(s, f, default=float)
        print(f"# report -> {args.report_out}")
    return report


def run(args):
    if args.fleet_size:
        return run_fleet(args)
    eng, testb = build(args)
    print(f"k_optimal={eng.k_optimal} compacted_to={eng.m} "
          f"MAR(master)={eng.specs[0].mar:.2f}s "
          f"members={ {l: len(v) for l, v in eng.assignment.members.items()} }")
    if eng.mesh is not None:
        plane_txt = (f", plane columns sharded {eng._mesh_m}-way"
                     if eng._mesh_m > 1 else "")
        print(f"mesh={dict(eng.mesh.shape)} "
              f"(member axis sharded {eng._mesh_n}-way{plane_txt})")
    trace = make_trace(args.trace, args.participants, args.rounds,
                       seed=args.seed, **_trace_knobs(args))
    obs = None
    if args.metrics_out or args.trace_out or args.fence:
        obs = make_observability(fence=args.fence)
    sim = HeterogeneitySim(eng, trace, SimConfig(
        rounds=args.rounds, mar_policy=args.mar_policy,
        schedule=args.schedule, eval_every=args.eval_every,
        select=args.select, select_budget=args.select_budget), obs=obs)
    report = sim.run(testb)
    print(report.timeline())
    try:
        stats = eng.compile_stats()
        print(f"# round programs={len(stats)} "
              f"xla_compiles={sum(stats.values())} "
              f"(padding {'on' if eng.cfg.pad_clusters else 'off'})")
    except RuntimeError:
        print("# compile telemetry unavailable on this jax build")
    if args.metrics_out:
        n = obs.registry.to_jsonl(args.metrics_out)
        print(f"# metrics: {n} lines -> {args.metrics_out}")
    if args.trace_out:
        obs.tracer.write(args.trace_out)
        print(f"# trace: {len(obs.tracer.events())} spans -> "
              f"{args.trace_out}"
              + (" (fenced timings)" if args.fence else ""))
    if args.report_out:
        with open(args.report_out, "w") as f:
            json.dump(report.to_dict(), f, default=float)
        print(f"# report -> {args.report_out}")
    if args.json:
        print(json.dumps(report.to_dict(), default=float))
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="dropout", choices=sorted(SCENARIOS))
    ap.add_argument("--mar-policy", default="drop",
                    choices=["drop", "mask", "wait", "buffer"])
    ap.add_argument("--staleness-discount", type=float, default=0.6,
                    help="per-round weight decay of banked async updates "
                         "(buffer policy)")
    ap.add_argument("--no-pad", action="store_true",
                    help="disable compile-stable capacity padding "
                         "(retraces on every cluster-cardinality change)")
    ap.add_argument("--rounds-per-dispatch", type=int, default=1,
                    help=">1 runs the device-resident pipeline: up to that "
                         "many rounds fused per cluster into one scan "
                         "program between events (in-program sampling, "
                         "flat-plane aggregation, donated buffers)")
    ap.add_argument("--mesh-shape", default=None, metavar="DATA[xMODEL]",
                    help="shard the dispatch path over a device mesh, e.g. "
                         "'8', '8x1' (member axis only) or '4x2' (members "
                         "along data AND plane/bank/teacher columns along "
                         "model — for member models too large to replicate "
                         "per device).  Requires --rounds-per-dispatch >1; "
                         "per-round plane aggregation becomes local "
                         "(data × model)-subgrid reduce + one psum over "
                         "data; on CPU force devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8")
    ap.add_argument("--schedule", default="parallel",
                    choices=["parallel", "sequential"])
    ap.add_argument("--dropout-rate", type=float, default=None,
                    help="per-round dropout probability (dropout/mixed "
                         "traces; scenario default when omitted)")
    ap.add_argument("--drift-rate", type=float, default=None,
                    help="per-round resource-drift probability (drift/mixed)")
    ap.add_argument("--spike-rate", type=float, default=None,
                    help="per-round straggler-spike probability "
                         "(straggler/mixed)")
    ap.add_argument("--fleet-size", type=int, default=0, metavar="N",
                    help="run the vectorized FleetSim over N resampled "
                         "participants instead of the training simulator")
    ap.add_argument("--select", default="all", choices=["all", "fedcs"],
                    help="per-cluster client selection (fedcs: greedy "
                         "deadline-aware admission, arXiv:1804.08333)")
    ap.add_argument("--select-budget", type=int, default=0,
                    help="fedcs: max clients admitted per cluster per round "
                         "(0 = deadline-bounded only)")
    ap.add_argument("--dataset", default="synth-mnist", choices=list(SPECS))
    ap.add_argument("--participants", type=int, default=16)
    ap.add_argument("--samples", type=int, default=1600)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--steps-per-round", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--base-width", type=float, default=0.25)
    ap.add_argument("--dirichlet", type=float, default=1.0)
    ap.add_argument("--compact-to", type=int, default=3)
    ap.add_argument("--lam", default="paper", choices=["paper", "equal"])
    ap.add_argument("--mar", type=float, default=None,
                    help="explicit MAR budget (s); default auto-calibrates")
    ap.add_argument("--kappa", type=float, default=0.7)
    ap.add_argument("--eval-every", type=int, default=2)
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="export the metrics registry (counters, gauges, "
                         "per-round tables) as JSON Lines")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export a Chrome-trace/Perfetto JSON of the round "
                         "pipeline (engine rounds, dispatch blocks, "
                         "compiles, transfers)")
    ap.add_argument("--fence", action="store_true",
                    help="block_until_ready inside spans so timings cover "
                         "device execution, not just dispatch (serializes "
                         "the pipeline — measurement mode)")
    ap.add_argument("--report-out", default=None, metavar="PATH",
                    help="write report.to_dict() JSON (summary + rows) — "
                         "pairs with repro.obs.validate --report")
    ap.add_argument("--seed", type=int, default=3)
    args = ap.parse_args(argv)
    return run(args)


if __name__ == "__main__":
    main()
