"""Fed-RAC end-to-end launcher (Algorithm 1 on synthetic federated data).

  PYTHONPATH=src python -m repro.launch.fl_train --dataset synth-mnist \
      --participants 40 --rounds 10 --compact-to 4

Drives: resource-aware clustering (Procedure 1, Table III vectors) →
compaction → participant assignment (Procedure 2) → master FedAvg →
slave KD training, and prints per-cluster / global accuracy + MAR analysis
(Eq. 9 parallel vs Eq. 10 sequential).
"""
from __future__ import annotations

import argparse
import json

import jax.numpy as jnp
import numpy as np

from repro.core import cost_model, server as srv
from repro.core.families import cnn_family, lm_family
from repro.core.resources import (LAMBDA_EQUAL, LAMBDA_PAPER, TABLE_III,
                                  participants_from_matrix)
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import SPECS, make_classification, train_test_split


def run(args):
    ds = make_classification(args.dataset, args.samples, seed=args.seed)
    train, test = train_test_split(ds)
    parts_idx = dirichlet_partition(train.y, args.participants,
                                    alpha=args.dirichlet, seed=args.seed)
    V = TABLE_III
    if args.participants != 40:
        rng = np.random.default_rng(args.seed)
        V = TABLE_III[rng.integers(0, 40, args.participants)]
    parts = participants_from_matrix(V, n_data=[len(p) for p in parts_idx])
    client_data = [{"x": train.x[p], "y": train.y[p]} for p in parts_idx]

    shape, classes = SPECS[args.dataset]
    fam = cnn_family(classes=classes, in_channels=shape[-1],
                     alpha=args.alpha, base_width=args.base_width,
                     input_hw=shape[0])
    lam = LAMBDA_PAPER if args.lam == "paper" else LAMBDA_EQUAL
    cfg = srv.FLConfig(alpha=args.alpha, rounds=args.rounds,
                       steps_per_round=args.steps_per_round, lr=args.lr,
                       lam=lam, compact_to=args.compact_to, seed=args.seed,
                       use_kd=not args.no_kd, kd_T=args.kd_t,
                       kd_alpha=args.kd_alpha, E=args.epochs)
    eng = srv.FedRAC(parts, client_data, fam, cfg, classes=classes).setup()
    print(f"dataset={args.dataset}  k_optimal={eng.k_optimal} (DI per k: "
          f"{ {k: round(v, 4) for k, v in eng.di_values.items()} })")
    print(f"compacted to m={eng.m}; members per cluster: "
          f"{ {l: len(v) for l, v in eng.assignment.members.items()} }; "
          f"demotions={eng.assignment.demotions}")
    testb = {"x": jnp.asarray(test.x), "y": jnp.asarray(test.y)}
    res = eng.train(testb)
    for lvl in range(eng.m):
        h = res.history.get(lvl, [])
        print(f"cluster C{lvl + 1}: final_acc="
              f"{res.final_acc.get(lvl, float('nan')):.4f}  "
              f"curve={[round(a, 3) for a in h]}")
    print(f"GLOBAL accuracy: {res.global_acc:.4f}")

    # MAR analysis (Eq. 9 vs Eq. 10)
    T_m = eng.specs[-1].mar
    par = cost_model.mar_parallel(T_m, cfg.kappa, eng.m)
    seq = cost_model.mar_sequential(T_m, cfg.kappa, eng.m)
    print(f"MAR: parallel(Eq.9)={par:.2f}s  sequential(Eq.10)={seq:.2f}s  "
          f"speedup={seq / par:.2f}x")
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="synth-mnist", choices=list(SPECS))
    ap.add_argument("--participants", type=int, default=40)
    ap.add_argument("--samples", type=int, default=2400)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--steps-per-round", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--base-width", type=float, default=0.25)
    ap.add_argument("--dirichlet", type=float, default=1.0)
    ap.add_argument("--compact-to", type=int, default=4)
    ap.add_argument("--lam", default="paper", choices=["paper", "equal"])
    ap.add_argument("--kd-t", type=float, default=2.0)
    ap.add_argument("--kd-alpha", type=float, default=0.3)
    ap.add_argument("--no-kd", action="store_true")
    ap.add_argument("--seed", type=int, default=3)
    args = ap.parse_args(argv)
    return run(args)


if __name__ == "__main__":
    main()
