"""Seeded device-profile and event-trace generation.

Profiles resample the paper's Table III (processing GHz, Mbps, GB) with
multiplicative jitter so any participant count keeps the paper's marginal
resource distribution.  Event traces are pre-scheduled at trace-build time
from a single ``numpy`` generator — two traces built with the same arguments
are identical, which the determinism tests pin down.

Event timestamps are in round units (see ``sim.clock``).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.resources import TABLE_III
from repro.sim.events import (Arrival, Departure, Event, ResourceDrift,
                              StragglerSpike)


@dataclass
class Trace:
    name: str
    events: list = field(default_factory=list)       # [(time, Event)]
    initially_offline: frozenset = frozenset()       # pids joining late


def sample_profiles(n: int, seed: int = 0, jitter: float = 0.15) -> np.ndarray:
    """(n, 3) resource matrix resampled from Table III with ±jitter."""
    rng = np.random.default_rng(seed)
    rows = TABLE_III[rng.integers(0, len(TABLE_III), n)]
    return rows * rng.uniform(1.0 - jitter, 1.0 + jitter, rows.shape)


# ------------------------------------------------------------ event makers
def dropout_events(n: int, rounds: int, rate: float, seed: int = 0,
                   rejoin_after: float = 2.0,
                   permanent_frac: float = 0.1) -> list:
    """Per-participant per-round Bernoulli(rate) dropouts; most rejoin after
    ``rejoin_after`` rounds, a ``permanent_frac`` share never come back."""
    rng = np.random.default_rng(seed)
    out = []
    for r in range(rounds):
        for pid in range(n):
            if rng.random() < rate:
                perm = rng.random() < permanent_frac
                out.append((float(r), Departure(
                    pid, rejoin_after=None if perm else rejoin_after)))
    return out


def drift_events(n: int, rounds: int, rate: float, seed: int = 0,
                 scale: float = 0.35) -> list:
    """Multiplicative log-normal random-walk steps on (s, r); memory drifts
    an order of magnitude slower (apps release RAM rarely)."""
    rng = np.random.default_rng(seed)
    out = []
    for r in range(rounds):
        for pid in range(n):
            if rng.random() < rate:
                out.append((float(r), ResourceDrift(
                    pid,
                    s_mult=float(np.exp(rng.normal(0.0, scale))),
                    r_mult=float(np.exp(rng.normal(0.0, scale))),
                    a_mult=float(np.exp(rng.normal(0.0, scale * 0.1))))))
    return out


def straggler_events(n: int, rounds: int, rate: float, seed: int = 0,
                     factor_range=(2.0, 8.0), duration: float = 1.0) -> list:
    rng = np.random.default_rng(seed)
    out = []
    for r in range(rounds):
        for pid in range(n):
            if rng.random() < rate:
                out.append((float(r), StragglerSpike(
                    pid, factor=float(rng.uniform(*factor_range)),
                    duration=duration)))
    return out


def late_arrivals(n: int, rounds: int, frac: float, seed: int = 0) -> tuple:
    """A ``frac`` share of participants join uniformly over the first half of
    the horizon.  Returns (initially_offline, events)."""
    rng = np.random.default_rng(seed)
    late = rng.permutation(n)[: int(round(n * frac))]
    evs = [(float(rng.integers(1, max(2, rounds // 2 + 1))), Arrival(int(pid)))
           for pid in late]
    return frozenset(int(p) for p in late), evs


# ------------------------------------------------------------ scenarios
def _stable(n, rounds, seed, **kw):
    return Trace("stable")


def _dropout(n, rounds, seed, *, dropout_rate=0.15, rejoin_after=2.0, **kw):
    return Trace("dropout", dropout_events(n, rounds, dropout_rate, seed,
                                           rejoin_after=rejoin_after))


def _drift(n, rounds, seed, *, drift_rate=0.1, drift_scale=0.35, **kw):
    return Trace("drift", drift_events(n, rounds, drift_rate, seed,
                                       scale=drift_scale))


def _straggler(n, rounds, seed, *, spike_rate=0.15, spike_duration=1.0, **kw):
    return Trace("straggler", straggler_events(n, rounds, spike_rate, seed,
                                               duration=spike_duration))


def _flash_crowd(n, rounds, seed, *, late_frac=0.4, **kw):
    off, evs = late_arrivals(n, rounds, late_frac, seed)
    return Trace("flash-crowd", evs, initially_offline=off)


def _mixed(n, rounds, seed, *, dropout_rate=0.08, drift_rate=0.05,
           spike_rate=0.08, **kw):
    evs = (dropout_events(n, rounds, dropout_rate, seed)
           + drift_events(n, rounds, drift_rate, seed + 1)
           + straggler_events(n, rounds, spike_rate, seed + 2))
    return Trace("mixed", evs)


SCENARIOS = {
    "stable": _stable,
    "dropout": _dropout,
    "drift": _drift,
    "straggler": _straggler,
    "flash-crowd": _flash_crowd,
    "mixed": _mixed,
}


def make_trace(scenario: str, n: int, rounds: int, seed: int = 0,
               **knobs) -> Trace:
    if scenario not in SCENARIOS:
        raise ValueError(f"unknown scenario {scenario!r}; "
                         f"choose from {sorted(SCENARIOS)}")
    return SCENARIOS[scenario](n, rounds, seed, **knobs)
