"""Seeded device-profile and event-trace generation — vectorized.

Profiles resample the paper's Table III (processing GHz, Mbps, GB) with
multiplicative jitter so any participant count keeps the paper's marginal
resource distribution.  Event traces are pre-scheduled at trace-build time
from a single ``numpy`` generator — two traces built with the same arguments
are identical, which the determinism tests pin down.

Generation is batched: every maker draws one block of variates and decodes
it into a columnar event table (``FleetTrace``), never looping per
(round, pid).  The decoded stream is BIT-IDENTICAL to the original scalar
loops (kept as ``legacy_*_events`` references, pinned by
``tests/test_fleet.py``): ``numpy.random.Generator`` fills batched draws
element-sequentially, so a batch of K uniforms equals K scalar calls, and
the interleaved conditional pattern ``u = rng.random(); if u < rate:
v = rng.random()`` is replayed from one batch by run-parity decoding —
a position is a gate draw iff the run of sub-``rate`` values immediately
before it has even length (gates and their extra value draws alternate
inside such a run).

One stream changed shape to make this possible: resource-drift normals.
Scalar Gaussians consume a variable number of generator words (ziggurat
rejection), so an interleaved uniform/normal stream cannot be decoded
positionally; ``drift_events`` now draws its gate uniforms first and then
the fired slots' normals (three per slot, slot order) — still one seeded
generator, still loop-replayable (``legacy_drift_events``).

Event timestamps are in round units (see ``sim.clock``).
"""
from __future__ import annotations

import inspect
import math
from dataclasses import dataclass, field

import numpy as np

from repro.core.resources import TABLE_III
from repro.sim.events import (Arrival, Departure, Event, ResourceDrift,
                              StragglerSpike)


@dataclass
class Trace:
    name: str
    events: list = field(default_factory=list)       # [(time, Event)]
    initially_offline: frozenset = frozenset()       # pids joining late


def sample_profiles(n: int, seed: int = 0, jitter: float = 0.15) -> np.ndarray:
    """(n, 3) resource matrix resampled from Table III with ±jitter."""
    rng = np.random.default_rng(seed)
    rows = TABLE_III[rng.integers(0, len(TABLE_III), n)]
    return rows * rng.uniform(1.0 - jitter, 1.0 + jitter, rows.shape)


# ------------------------------------------------------------ columnar form
def _table(**cols) -> dict:
    return {k: np.asarray(v) for k, v in cols.items()}


def _empty(*names) -> dict:
    return {k: np.empty(0, np.int64 if k == "pid" else np.float64)
            for k in names}


@dataclass
class FleetTrace:
    """Columnar event tables for a whole trace — the fleet-scale form.

    Each table is a dict of equal-length 1-D arrays sorted by slot order
    (time ascending, pid ascending within a round; arrivals keep their
    draw order, which fixes FIFO tie-breaking).  ``to_trace()`` materializes
    the legacy ``Trace`` object list in the exact order the scalar makers
    used to append (dropouts, then drifts, then spikes, then arrivals) —
    the bridge for the event-queue engine and the equivalence tests.
    Vectorized engines (``sim.fleet.FleetSim``) consume the tables directly
    and never materialize per-event objects.
    """
    name: str
    n: int
    rounds: int
    dropouts: dict = field(default_factory=lambda: _empty(
        "time", "pid", "rejoin"))                  # rejoin: nan = permanent
    drifts: dict = field(default_factory=lambda: _empty(
        "time", "pid", "s_mult", "r_mult", "a_mult"))
    spikes: dict = field(default_factory=lambda: _empty(
        "time", "pid", "factor", "duration"))
    arrivals: dict = field(default_factory=lambda: _empty("time", "pid"))
    initially_offline: frozenset = frozenset()

    @property
    def n_events(self) -> int:
        return sum(len(t["time"]) for t in
                   (self.dropouts, self.drifts, self.spikes, self.arrivals))

    def to_trace(self) -> Trace:
        ev = []
        d = self.dropouts
        for t, pid, rj in zip(d["time"], d["pid"], d["rejoin"]):
            ev.append((float(t), Departure(
                int(pid), rejoin_after=None if math.isnan(rj) else float(rj))))
        d = self.drifts
        for t, pid, sm, rm, am in zip(d["time"], d["pid"], d["s_mult"],
                                      d["r_mult"], d["a_mult"]):
            ev.append((float(t), ResourceDrift(int(pid), s_mult=float(sm),
                                               r_mult=float(rm),
                                               a_mult=float(am))))
        d = self.spikes
        for t, pid, f, dur in zip(d["time"], d["pid"], d["factor"],
                                  d["duration"]):
            ev.append((float(t), StragglerSpike(int(pid), factor=float(f),
                                                duration=float(dur))))
        d = self.arrivals
        for t, pid in zip(d["time"], d["pid"]):
            ev.append((float(t), Arrival(int(pid))))
        return Trace(self.name, ev,
                     initially_offline=self.initially_offline)


# ------------------------------------------------------------ batched draws
def _decode_gated(seed: int, n_slots: int, rate: float):
    """Replay ``for slot: u = rng.random(); if u < rate: v = rng.random()``
    from one batched draw.

    Run-parity decode: a position is a gate iff the run of consecutive
    sub-``rate`` values immediately before it has EVEN length — a gate that
    fires is followed by exactly one value position, and only a firing gate
    produces one, so gates/values alternate inside every such run.  Returns
    (fired slot ordinals ascending, their value draws).  Over-draws a
    generous block and doubles it in the rare case the decode comes up
    short; re-creating the generator keeps the stream prefix identical.
    """
    if n_slots == 0 or rate <= 0.0:
        return np.empty(0, np.int64), np.empty(0, np.float64)
    K = int(n_slots * (1.0 + rate)
            + 10.0 * math.sqrt(max(n_slots * rate, 1.0)) + 64)
    while True:
        U = np.random.default_rng(seed).random(K)
        H = np.flatnonzero(U < rate)         # sub-rate ("hit") positions
        if len(H) == 0:                      # K ≥ n_slots gates, none fired
            return np.empty(0, np.int64), np.empty(0, np.float64)
        # Sparse run-parity: work on the ~rate·K hits, not all K positions.
        # Within each maximal hit-run, even offsets are fired gates, odd
        # offsets their values; the position right AFTER an odd-length run
        # (a miss, or one past the draw) is the trailing gate's value too.
        brk = np.empty(len(H), bool)         # True at each run's first hit
        brk[0] = True
        np.greater(np.diff(H), 1, out=brk[1:])
        rid = np.cumsum(brk) - 1                     # run id per hit
        start = np.flatnonzero(brk)                  # run starts (in H index)
        off = H - H[start][rid]                      # offset within run
        ev = (off & 1) == 0                          # even offset = fired gate
        H_ev = H[ev]
        L = np.diff(start, append=len(H))            # run lengths
        odd_run = (L & 1).astype(bool)               # odd run → trailing value
        n_E = int(odd_run.sum()) - (odd_run[-1] and H[-1] == K - 1)
        n_odd = len(H) - len(H_ev)                   # odd-offset hits = values
        if K - n_odd - n_E >= n_slots:       # enough gates decoded
            # gate ordinal = position − (# value positions before it):
            # ceil(L/2) values per earlier run + off/2 inside this run
            vals = (L + 1) >> 1
            prev = np.cumsum(vals) - vals
            ordv = H_ev - prev[rid[ev]] - (off[ev] >> 1)
            sel = ordv < n_slots
            val_pos = H_ev[sel] + 1
            if len(val_pos) == 0 or val_pos[-1] < K:
                return ordv[sel], U[val_pos]
        K *= 2


def _slot_time_pid(slots: np.ndarray, n: int):
    return ((slots // n).astype(np.float64), (slots % n).astype(np.int64))


def dropout_table(n: int, rounds: int, rate: float, seed: int = 0,
                  rejoin_after: float = 2.0,
                  permanent_frac: float = 0.1) -> dict:
    """Columnar per-participant per-round Bernoulli(rate) dropouts; most
    rejoin after ``rejoin_after`` rounds (``rejoin`` column; nan = the
    ``permanent_frac`` share that never come back)."""
    fired, v = _decode_gated(seed, n * rounds, rate)
    time, pid = _slot_time_pid(fired, n)
    return _table(time=time, pid=pid,
                  rejoin=np.where(v < permanent_frac, np.nan,
                                  float(rejoin_after)))


def drift_table(n: int, rounds: int, rate: float, seed: int = 0,
                scale: float = 0.35) -> dict:
    """Columnar multiplicative log-normal random-walk steps on (s, r);
    memory drifts an order of magnitude slower (apps release RAM rarely).
    Gate uniforms are drawn first, then the fired slots' standard normals
    (3 per slot, slot order) — see the module docstring."""
    rng = np.random.default_rng(seed)
    u = rng.random(n * rounds)
    fired = np.flatnonzero(u < rate).astype(np.int64)
    g = rng.standard_normal((len(fired), 3))
    time, pid = _slot_time_pid(fired, n)
    return _table(time=time, pid=pid,
                  s_mult=np.exp(g[:, 0] * scale),
                  r_mult=np.exp(g[:, 1] * scale),
                  a_mult=np.exp(g[:, 2] * (scale * 0.1)))


def straggler_table(n: int, rounds: int, rate: float, seed: int = 0,
                    factor_range=(2.0, 8.0), duration: float = 1.0) -> dict:
    fired, v = _decode_gated(seed, n * rounds, rate)
    time, pid = _slot_time_pid(fired, n)
    lo, hi = factor_range
    return _table(time=time, pid=pid, factor=lo + (hi - lo) * v,
                  duration=np.full(len(fired), float(duration)))


def arrival_table(n: int, rounds: int, frac: float, seed: int = 0) -> tuple:
    """A ``frac`` share of participants join uniformly over the first half
    of the horizon.  Returns (initially_offline frozenset, table); the table
    keeps permutation order (insertion order fixes FIFO tie-breaks)."""
    rng = np.random.default_rng(seed)
    late = rng.permutation(n)[: int(round(n * frac))]
    times = rng.integers(1, max(2, rounds // 2 + 1),
                         size=len(late)).astype(np.float64)
    return (frozenset(int(p) for p in late),
            _table(time=times, pid=late.astype(np.int64)))


# ------------------------------------------------------------ event makers
# List-of-events API on top of the columnar builders: identical streams
# (pinned against the legacy_* scalar loops below), but the O(n·rounds)
# draw/decode is batched — only realized events materialize objects.
def dropout_events(n: int, rounds: int, rate: float, seed: int = 0,
                   rejoin_after: float = 2.0,
                   permanent_frac: float = 0.1) -> list:
    return FleetTrace("dropout", n, rounds, dropouts=dropout_table(
        n, rounds, rate, seed, rejoin_after, permanent_frac)).to_trace().events


def drift_events(n: int, rounds: int, rate: float, seed: int = 0,
                 scale: float = 0.35) -> list:
    return FleetTrace("drift", n, rounds, drifts=drift_table(
        n, rounds, rate, seed, scale)).to_trace().events


def straggler_events(n: int, rounds: int, rate: float, seed: int = 0,
                     factor_range=(2.0, 8.0), duration: float = 1.0) -> list:
    return FleetTrace("straggler", n, rounds, spikes=straggler_table(
        n, rounds, rate, seed, factor_range, duration)).to_trace().events


def late_arrivals(n: int, rounds: int, frac: float, seed: int = 0) -> tuple:
    off, tab = arrival_table(n, rounds, frac, seed)
    return off, FleetTrace("flash-crowd", n, rounds,
                           arrivals=tab).to_trace().events


# ------------------------------------------------------ legacy references
# The original per-(round, pid) scalar loops.  They define the event stream
# the vectorized makers must reproduce bit-identically (equivalence tests)
# and anchor the trace-generation speedup row in ``bench_sim --mode fleet``.
def legacy_dropout_events(n: int, rounds: int, rate: float, seed: int = 0,
                          rejoin_after: float = 2.0,
                          permanent_frac: float = 0.1) -> list:
    rng = np.random.default_rng(seed)
    out = []
    for r in range(rounds):
        for pid in range(n):
            if rng.random() < rate:
                perm = rng.random() < permanent_frac
                out.append((float(r), Departure(
                    pid, rejoin_after=None if perm else rejoin_after)))
    return out


def legacy_drift_events(n: int, rounds: int, rate: float, seed: int = 0,
                        scale: float = 0.35) -> list:
    rng = np.random.default_rng(seed)
    fired = [(r, pid) for r in range(rounds) for pid in range(n)
             if rng.random() < rate]
    out = []
    for r, pid in fired:
        out.append((float(r), ResourceDrift(
            pid,
            s_mult=float(np.exp(rng.normal(0.0, scale))),
            r_mult=float(np.exp(rng.normal(0.0, scale))),
            a_mult=float(np.exp(rng.normal(0.0, scale * 0.1))))))
    return out


def legacy_straggler_events(n: int, rounds: int, rate: float, seed: int = 0,
                            factor_range=(2.0, 8.0),
                            duration: float = 1.0) -> list:
    rng = np.random.default_rng(seed)
    out = []
    for r in range(rounds):
        for pid in range(n):
            if rng.random() < rate:
                out.append((float(r), StragglerSpike(
                    pid, factor=float(rng.uniform(*factor_range)),
                    duration=duration)))
    return out


def legacy_late_arrivals(n: int, rounds: int, frac: float,
                         seed: int = 0) -> tuple:
    rng = np.random.default_rng(seed)
    late = rng.permutation(n)[: int(round(n * frac))]
    evs = [(float(rng.integers(1, max(2, rounds // 2 + 1))), Arrival(int(pid)))
           for pid in late]
    return frozenset(int(p) for p in late), evs


# ------------------------------------------------------------ scenarios
def _stable(n, rounds, seed):
    return FleetTrace("stable", n, rounds)


def _dropout(n, rounds, seed, *, dropout_rate=0.15, rejoin_after=2.0):
    return FleetTrace("dropout", n, rounds, dropouts=dropout_table(
        n, rounds, dropout_rate, seed, rejoin_after=rejoin_after))


def _drift(n, rounds, seed, *, drift_rate=0.1, drift_scale=0.35):
    return FleetTrace("drift", n, rounds, drifts=drift_table(
        n, rounds, drift_rate, seed, scale=drift_scale))


def _straggler(n, rounds, seed, *, spike_rate=0.15, spike_duration=1.0):
    return FleetTrace("straggler", n, rounds, spikes=straggler_table(
        n, rounds, spike_rate, seed, duration=spike_duration))


def _flash_crowd(n, rounds, seed, *, late_frac=0.4):
    off, tab = arrival_table(n, rounds, late_frac, seed)
    return FleetTrace("flash-crowd", n, rounds, arrivals=tab,
                      initially_offline=off)


def _mixed(n, rounds, seed, *, dropout_rate=0.08, drift_rate=0.05,
           spike_rate=0.08):
    return FleetTrace(
        "mixed", n, rounds,
        dropouts=dropout_table(n, rounds, dropout_rate, seed),
        drifts=drift_table(n, rounds, drift_rate, seed + 1),
        spikes=straggler_table(n, rounds, spike_rate, seed + 2))


SCENARIOS = {
    "stable": _stable,
    "dropout": _dropout,
    "drift": _drift,
    "straggler": _straggler,
    "flash-crowd": _flash_crowd,
    "mixed": _mixed,
}


def scenario_knobs(scenario: str) -> frozenset:
    """The keyword knobs a scenario accepts (its keyword-only parameters)."""
    sig = inspect.signature(SCENARIOS[scenario])
    return frozenset(p.name for p in sig.parameters.values()
                     if p.kind is inspect.Parameter.KEYWORD_ONLY)


def _check_knobs(scenario: str, knobs: dict) -> None:
    if scenario not in SCENARIOS:
        raise ValueError(f"unknown scenario {scenario!r}; "
                         f"choose from {sorted(SCENARIOS)}")
    unknown = set(knobs) - scenario_knobs(scenario)
    if unknown:
        raise TypeError(
            f"scenario {scenario!r} does not accept "
            f"{sorted(unknown)}; valid knobs: "
            f"{sorted(scenario_knobs(scenario)) or 'none'}")


def make_fleet_trace(scenario: str, n: int, rounds: int, seed: int = 0,
                     **knobs) -> FleetTrace:
    """Columnar trace for the vectorized engines.  Unknown knobs raise
    (a typo'd ``--dropout-rate`` must not silently no-op)."""
    _check_knobs(scenario, knobs)
    return SCENARIOS[scenario](n, rounds, seed, **knobs)


def make_trace(scenario: str, n: int, rounds: int, seed: int = 0,
               **knobs) -> Trace:
    return make_fleet_trace(scenario, n, rounds, seed, **knobs).to_trace()
