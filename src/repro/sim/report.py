"""Per-round telemetry for the heterogeneity simulator.

One ``RoundRecord`` per communication round, holding per-cluster
``ClusterRoundStats``; ``SimReport`` aggregates the timeline, renders it as
text (the CLI/example output) and summarizes totals.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field


@dataclass
class ClusterRoundStats:
    level: int
    time: float                    # cluster round duration (s)
    active: list = field(default_factory=list)     # pids that contributed
    dropped: list = field(default_factory=list)    # MAR-dropped this round
    offline: list = field(default_factory=list)    # not online this round
    masked: dict = field(default_factory=dict)     # pid -> steps granted (<S)
    violations: list = field(default_factory=list)  # pids with T_i > MAR
    banked: list = field(default_factory=list)     # late updates buffered
    flushed: int = 0                               # stale updates merged
    bytes: float = 0.0
    mean_loss: float = float("nan")
    acc: float | None = None


@dataclass
class RoundRecord:
    round: int
    t_start: float
    duration: float                # schedule-combined round time (s)
    clusters: list = field(default_factory=list)   # [ClusterRoundStats]
    events: list = field(default_factory=list)     # human-readable strings

    @property
    def t_end(self) -> float:
        return self.t_start + self.duration

    @property
    def dropped(self) -> list:
        return [p for c in self.clusters for p in c.dropped]

    @property
    def violations(self) -> list:
        return [p for c in self.clusters for p in c.violations]

    @property
    def bytes(self) -> float:
        return sum(c.bytes for c in self.clusters)


@dataclass
class SimReport:
    scenario: str
    mar_policy: str
    schedule: str
    rows: list = field(default_factory=list)       # [RoundRecord]
    final_acc: dict = field(default_factory=dict)  # level -> accuracy

    def add(self, row: RoundRecord) -> None:
        self.rows.append(row)

    # ------------------------------------------------------------ summaries
    def summary(self) -> dict:
        n_parts = {p for r in self.rows for c in r.clusters
                   for p in (c.active + c.dropped + c.offline + c.banked)}
        total_slots = sum(
            len(c.active) + len(c.dropped) + len(c.offline) + len(c.banked)
            for r in self.rows for c in r.clusters)
        # banked members participate — their (late) update reaches the next
        # round's aggregate
        active_slots = sum(len(c.active) + len(c.banked)
                           for r in self.rows for c in r.clusters)
        return {
            "scenario": self.scenario,
            "mar_policy": self.mar_policy,
            "schedule": self.schedule,
            "rounds": len(self.rows),
            "wall_clock_s": round(sum(r.duration for r in self.rows), 3),
            "total_bytes": float(sum(r.bytes for r in self.rows)),
            "participants": len(n_parts),
            "participation_rate": round(active_slots / total_slots, 4)
                                  if total_slots else 0.0,
            "mar_violations": sum(len(r.violations) for r in self.rows),
            "dropped_total": sum(len(r.dropped) for r in self.rows),
            "banked_total": sum(len(c.banked) for r in self.rows
                                for c in r.clusters),
            "flushed_total": sum(c.flushed for r in self.rows
                                 for c in r.clusters),
            "final_acc": {k: round(v, 4) for k, v in self.final_acc.items()},
        }

    def timeline(self) -> str:
        lines = [f"# scenario={self.scenario} policy={self.mar_policy} "
                 f"schedule={self.schedule}"]
        for r in self.rows:
            cl = []
            for c in r.clusters:
                bits = f"C{c.level + 1} {len(c.active)}a"
                if c.dropped:
                    bits += f" {len(c.dropped)}drop"
                if c.masked:
                    bits += f" {len(c.masked)}mask"
                if c.banked:
                    bits += f" {len(c.banked)}bank"
                if c.flushed:
                    bits += f" {c.flushed}flush"
                if c.offline:
                    bits += f" {len(c.offline)}off"
                if c.violations:
                    bits += f" viol={c.violations}"
                if c.acc is not None:
                    bits += f" acc={c.acc:.3f}"
                cl.append(bits)
            ev = ("  events: " + "; ".join(r.events)) if r.events else ""
            lines.append(
                f"r{r.round:03d}  t={r.t_start:8.1f}s  Δ={r.duration:7.2f}s  "
                f"{self._fmt_bytes(r.bytes):>9}  | " + " | ".join(cl) + ev)
        s = self.summary()
        lines.append(
            f"TOTAL wall-clock={s['wall_clock_s']:.1f}s  "
            f"bytes={self._fmt_bytes(s['total_bytes'])}  "
            f"participation={s['participation_rate']:.0%}  "
            f"mar_violations={s['mar_violations']}  "
            f"dropped={s['dropped_total']}  final_acc={s['final_acc']}")
        return "\n".join(lines)

    def to_dict(self) -> dict:
        return {"summary": self.summary(),
                "rows": [asdict(r) for r in self.rows]}

    @staticmethod
    def _fmt_bytes(b: float) -> str:
        for unit in ("B", "KB", "MB", "GB"):
            if abs(b) < 1024.0:
                return f"{b:.1f}{unit}"
            b /= 1024.0
        return f"{b:.1f}TB"
