"""Per-round telemetry for the heterogeneity simulator.

One ``RoundRecord`` per communication round, holding per-cluster
``ClusterRoundStats``; ``SimReport`` aggregates the timeline, renders it as
text (the CLI/example output) and summarizes totals.

``SimReport`` is now a thin view over the obs metrics registry: ``add()``
appends one columnar row per cluster-round to the ``sim/cluster_rounds``
table (struct-of-arrays ring buffer) and one per round to ``sim/rounds``,
and ``summary()`` derives its numeric totals from those columns rather than
iterating Python objects — the registry is the sink that scales to fleet
sizes, the dataclasses remain for text/timeline rendering and per-pid sets.
Passing an ``Observability`` bundle shares the registry with the engine so
``--metrics-out`` exports reproduce ``summary()`` exactly.
"""
from __future__ import annotations

import math
from dataclasses import asdict, dataclass, field

from ..obs import MetricsRegistry

_CLUSTER_COLS = {
    "round": "int64", "level": "int64", "time": "float64",
    "bytes": "float64", "active": "int64", "masked": "int64",
    "dropped": "int64", "offline": "int64", "banked": "int64",
    "unselected": "int64", "violations": "int64", "flushed": "int64",
    "mean_loss": "float64", "acc": "float64",
}
_ROUND_COLS = {"round": "int64", "t_start": "float64",
               "duration": "float64", "events": "int64"}


@dataclass
class ClusterRoundStats:
    level: int
    time: float                    # cluster round duration (s)
    active: list = field(default_factory=list)     # pids that contributed
    dropped: list = field(default_factory=list)    # MAR-dropped this round
    offline: list = field(default_factory=list)    # not online this round
    masked: dict = field(default_factory=dict)     # pid -> steps granted (<S)
    violations: list = field(default_factory=list)  # pids with T_i > MAR
    banked: list = field(default_factory=list)     # late updates buffered
    unselected: list = field(default_factory=list)  # FedCS left out this round
    flushed: int = 0                               # stale updates merged
    bytes: float = 0.0
    mean_loss: float = float("nan")
    acc: float | None = None

    @property
    def participating(self) -> set:
        """Pids that contributed an update this round: fully active ones
        plus masked members (partial ⌊S·(MAR−T_c)/T_a⌋-step updates still
        reach the aggregate, whether or not the engine also listed them in
        ``active``)."""
        return set(self.active) | set(self.masked)


def encode_stats(c: "ClusterRoundStats") -> dict:
    """JSON-safe form of one ``ClusterRoundStats``.  ``masked`` is flattened
    to ``[pid, granted]`` pairs — JSON object keys are strings, so a plain
    ``asdict`` would silently stringify the pids."""
    return {
        "level": c.level, "time": c.time,
        "active": list(c.active), "dropped": list(c.dropped),
        "offline": list(c.offline),
        "masked": [[int(p), int(g)] for p, g in c.masked.items()],
        "violations": list(c.violations), "banked": list(c.banked),
        "unselected": list(c.unselected), "flushed": c.flushed,
        "bytes": c.bytes, "mean_loss": c.mean_loss, "acc": c.acc,
    }


def decode_stats(c: dict) -> "ClusterRoundStats":
    """Inverse of ``encode_stats``."""
    return ClusterRoundStats(
        level=int(c["level"]), time=float(c["time"]),
        active=[int(p) for p in c["active"]],
        dropped=[int(p) for p in c["dropped"]],
        offline=[int(p) for p in c["offline"]],
        masked={int(p): int(g) for p, g in c["masked"]},
        violations=[int(p) for p in c["violations"]],
        banked=[int(p) for p in c["banked"]],
        unselected=[int(p) for p in c["unselected"]],
        flushed=int(c["flushed"]), bytes=float(c["bytes"]),
        mean_loss=float(c["mean_loss"]),
        acc=None if c["acc"] is None else float(c["acc"]))


def encode_rows(rows: list) -> list:
    """JSON-safe form of ``[RoundRecord]`` for run-state checkpoints."""
    out = []
    for r in rows:
        out.append({
            "round": r.round, "t_start": r.t_start, "duration": r.duration,
            "events": list(r.events),
            "clusters": [encode_stats(c) for c in r.clusters],
        })
    return out


def decode_rows(data: list) -> list:
    """Inverse of ``encode_rows``."""
    rows = []
    for r in data:
        rows.append(RoundRecord(round=int(r["round"]),
                                t_start=float(r["t_start"]),
                                duration=float(r["duration"]),
                                clusters=[decode_stats(c)
                                          for c in r["clusters"]],
                                events=[str(e) for e in r["events"]]))
    return rows


@dataclass
class RoundRecord:
    round: int
    t_start: float
    duration: float                # schedule-combined round time (s)
    clusters: list = field(default_factory=list)   # [ClusterRoundStats]
    events: list = field(default_factory=list)     # human-readable strings

    @property
    def t_end(self) -> float:
        return self.t_start + self.duration

    @property
    def dropped(self) -> list:
        return [p for c in self.clusters for p in c.dropped]

    @property
    def violations(self) -> list:
        return [p for c in self.clusters for p in c.violations]

    @property
    def bytes(self) -> float:
        return sum(c.bytes for c in self.clusters)


@dataclass
class SimReport:
    scenario: str
    mar_policy: str
    schedule: str
    rows: list = field(default_factory=list)       # [RoundRecord]
    final_acc: dict = field(default_factory=dict)  # level -> accuracy
    obs: object = None             # Observability bundle (shared registry)

    def __post_init__(self):
        reg = self.obs.registry if self.obs is not None else MetricsRegistry()
        self._registry = reg
        self._t_clusters = reg.table("sim/cluster_rounds", _CLUSTER_COLS,
                                     defaults={"acc": math.nan,
                                               "mean_loss": math.nan})
        self._t_rounds = reg.table("sim/rounds", _ROUND_COLS)
        # a report's lifetime is one run: never mix rows from a prior run
        # that shared the same registry
        self._t_clusters.reset()
        self._t_rounds.reset()

    @property
    def registry(self) -> MetricsRegistry:
        return self._registry

    def add(self, row: RoundRecord) -> None:
        self.rows.append(row)
        self._t_rounds.append(round=row.round, t_start=row.t_start,
                              duration=row.duration, events=len(row.events))
        for c in row.clusters:
            self._t_clusters.append(
                round=row.round, level=c.level, time=c.time, bytes=c.bytes,
                active=len(c.participating), masked=len(c.masked),
                dropped=len(c.dropped), offline=len(c.offline),
                banked=len(c.banked), unselected=len(c.unselected),
                violations=len(c.violations),
                flushed=c.flushed, mean_loss=c.mean_loss,
                acc=math.nan if c.acc is None else c.acc)

    def bump_flushed(self, level: int, delta: int) -> None:
        """Credit ``delta`` terminal bank flushes to the newest recorded
        round for ``level`` — in both the dataclass view and the registry
        table, keeping summary/export parity."""
        if not self.rows:
            return
        for c in self.rows[-1].clusters:
            if c.level == level:
                c.flushed += delta
                break
        self._t_clusters.bump_last(
            "flushed", delta,
            match={"round": self.rows[-1].round, "level": level})

    # ------------------------------------------------------------ summaries
    def summary(self) -> dict:
        n_parts = {p for r in self.rows for c in r.clusters
                   for p in (list(c.participating) + c.dropped
                             + c.offline + c.banked + c.unselected)}
        t = self._t_clusters
        col = t.column
        # Python sum over .tolist() keeps the sequential summation order the
        # JSONL validator uses, so recomputed totals match bit-exactly.
        active = int(sum(col("active").tolist()))
        banked = int(sum(col("banked").tolist()))
        total_slots = (active + banked + int(sum(col("dropped").tolist()))
                       + int(sum(col("offline").tolist()))
                       + int(sum(col("unselected").tolist())))
        # banked members participate — their (late) update reaches the next
        # round's aggregate
        active_slots = active + banked
        return {
            "scenario": self.scenario,
            "mar_policy": self.mar_policy,
            "schedule": self.schedule,
            "rounds": len(self._t_rounds),
            "wall_clock_s": round(
                float(sum(self._t_rounds.column("duration").tolist())), 3),
            "total_bytes": float(sum(col("bytes").tolist())),
            "participants": len(n_parts),
            "participation_rate": round(active_slots / total_slots, 4)
                                  if total_slots else 0.0,
            "mar_violations": int(sum(col("violations").tolist())),
            "dropped_total": int(sum(col("dropped").tolist())),
            "unselected_total": int(sum(col("unselected").tolist())),
            "banked_total": banked,
            "flushed_total": int(sum(col("flushed").tolist())),
            "final_acc": {k: round(v, 4) for k, v in self.final_acc.items()},
        }

    def timeline(self) -> str:
        lines = [f"# scenario={self.scenario} policy={self.mar_policy} "
                 f"schedule={self.schedule}"]
        for r in self.rows:
            cl = []
            for c in r.clusters:
                bits = f"C{c.level + 1} {len(c.active)}a"
                if c.dropped:
                    bits += f" {len(c.dropped)}drop"
                if c.masked:
                    bits += f" {len(c.masked)}mask"
                if c.banked:
                    bits += f" {len(c.banked)}bank"
                if c.unselected:
                    bits += f" {len(c.unselected)}unsel"
                if c.flushed:
                    bits += f" {c.flushed}flush"
                if c.offline:
                    bits += f" {len(c.offline)}off"
                if c.violations:
                    bits += f" viol={c.violations}"
                if c.acc is not None:
                    bits += f" acc={c.acc:.3f}"
                cl.append(bits)
            ev = ("  events: " + "; ".join(r.events)) if r.events else ""
            lines.append(
                f"r{r.round:03d}  t={r.t_start:8.1f}s  Δ={r.duration:7.2f}s  "
                f"{self._fmt_bytes(r.bytes):>9}  | " + " | ".join(cl) + ev)
        s = self.summary()
        lines.append(
            f"TOTAL wall-clock={s['wall_clock_s']:.1f}s  "
            f"bytes={self._fmt_bytes(s['total_bytes'])}  "
            f"participation={s['participation_rate']:.0%}  "
            f"mar_violations={s['mar_violations']}  "
            f"dropped={s['dropped_total']}  final_acc={s['final_acc']}")
        return "\n".join(lines)

    def to_dict(self) -> dict:
        return {"summary": self.summary(),
                "rows": [asdict(r) for r in self.rows]}

    @staticmethod
    def _fmt_bytes(b: float) -> str:
        for unit in ("B", "KB", "MB", "GB"):
            if abs(b) < 1024.0:
                return f"{b:.1f}{unit}"
            b /= 1024.0
        return f"{b:.1f}TB"
