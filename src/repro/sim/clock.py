"""Deterministic discrete-event clocks.

Event timestamps are in *round units* for the participant-lifecycle queue
(the FL server only observes device state at dispatch boundaries, so an
event stamped t=3.4 becomes visible at the start of round 4) and in
simulated *seconds* for the async completion queue; the two domains never
share a queue.  Total order is the explicit heap key ``(time, priority,
seq)`` — ``priority`` is a fixed per-event-type tie-break
(:func:`repro.sim.events.event_priority`: arrivals sort before everything
else at the same instant) and ``seq`` is a monotonically increasing
insertion counter, which makes replay under a fixed seed exactly
reproducible across platforms.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass

from .events import decode_event, encode_event, event_priority


class EventQueue:
    """Min-heap of ``(time, priority, seq, event)`` with a deterministic
    total order: time, then event-class priority, then FIFO insertion."""

    def __init__(self):
        self._heap: list = []
        self._seq = 0

    def push(self, time: float, event, priority: int | None = None) -> None:
        if priority is None:
            priority = event_priority(event)
        heapq.heappush(self._heap, (float(time), int(priority), self._seq, event))
        self._seq += 1

    def next_time(self) -> float | None:
        """Peek the earliest pending event time (None when empty) — the
        dispatch-mode engine caps fused blocks so no event can land inside
        one."""
        return self._heap[0][0] if self._heap else None

    def pop(self):
        """Pop the single earliest ``(time, event)`` (None when empty)."""
        if not self._heap:
            return None
        t, _, _, ev = heapq.heappop(self._heap)
        return t, ev

    def pop_due(self, now: float) -> list:
        """Pop every (time, event) with time <= now, in heap-key order."""
        due = []
        while self._heap and self._heap[0][0] <= now:
            t, _, _, ev = heapq.heappop(self._heap)
            due.append((t, ev))
        return due

    def pop_due_where(self, now: float, pred) -> list:
        """Pop every (time, event) with time <= now AND ``pred(event)``,
        preserving heap-key order among the popped entries.  Non-matching
        due entries keep their original (priority, seq) key, so a later
        :meth:`pop_due` / :meth:`pop_due_where` sees them in the same total
        order — this is what lets async clusters consume only their own
        participants' events without perturbing everyone else's."""
        due, keep = [], []
        while self._heap and self._heap[0][0] <= now:
            entry = heapq.heappop(self._heap)
            if pred(entry[3]):
                due.append((entry[0], entry[3]))
            else:
                keep.append(entry)
        for entry in keep:
            heapq.heappush(self._heap, entry)
        return due

    def __len__(self) -> int:
        return len(self._heap)

    # ------------------------------------------------------------ checkpoint
    def state(self) -> tuple[list, int]:
        """Pending ``(time, priority, seq, event)`` entries in heap-key order
        plus the sequence counter — enough to rebuild the queue with
        identical tie-breaking after a resume."""
        return sorted(self._heap), self._seq

    def load_state(self, entries: list, seq: int) -> None:
        heap = []
        for entry in entries:
            if len(entry) == 3:         # pre-priority checkpoints: (t, s, ev)
                t, s, ev = entry
                heap.append((float(t), event_priority(ev), int(s), ev))
            else:
                t, p, s, ev = entry
                heap.append((float(t), int(p), int(s), ev))
        heapq.heapify(heap)
        self._heap = heap
        self._seq = int(seq)

    def encode(self) -> dict:
        """JSON-safe ``{"seq", "entries"}`` snapshot (events encoded)."""
        entries, seq = self.state()
        return {"seq": seq,
                "entries": [[t, p, s, encode_event(ev)]
                            for t, p, s, ev in entries]}

    def load_encoded(self, rec: dict) -> None:
        entries = []
        for entry in rec["entries"]:
            if len(entry) == 3:
                t, s, enc = entry
                entries.append((float(t), int(s), decode_event(enc)))
            else:
                t, p, s, enc = entry
                entries.append((float(t), int(p), int(s), decode_event(enc)))
        self.load_state(entries, rec["seq"])


@dataclass
class SimClock:
    """Accumulated simulated wall-clock seconds."""
    now: float = 0.0

    def advance(self, dt: float) -> None:
        self.now += float(dt)


@dataclass
class ClusterClock:
    """One cluster's independent clock in async mode: simulated seconds
    accumulated by *this* cluster's dispatch blocks plus its local round
    cursor (== the cluster's committed server version)."""
    now: float = 0.0
    round: int = 0

    def advance(self, dt: float, rounds: int = 0) -> None:
        self.now += float(dt)
        self.round += int(rounds)
