"""Deterministic discrete-event clock.

Event timestamps are in *round units* (the FL server only observes device
state at round synchronization barriers, so an event stamped t=3.4 becomes
visible at the start of round 4); the wall-clock in seconds is accumulated
separately from the cost model's per-round durations.  Ties are broken by
insertion order (a monotonically increasing sequence number), which makes
replay under a fixed seed exactly reproducible.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass


class EventQueue:
    """Min-heap of (time, seq, event) with deterministic FIFO tie-breaking."""

    def __init__(self):
        self._heap: list = []
        self._seq = 0

    def push(self, time: float, event) -> None:
        heapq.heappush(self._heap, (float(time), self._seq, event))
        self._seq += 1

    def next_time(self) -> float | None:
        """Peek the earliest pending event time (None when empty) — the
        dispatch-mode engine caps fused blocks so no event can land inside
        one."""
        return self._heap[0][0] if self._heap else None

    def pop_due(self, now: float) -> list:
        """Pop every (time, event) with time <= now, in (time, seq) order."""
        due = []
        while self._heap and self._heap[0][0] <= now:
            t, _, ev = heapq.heappop(self._heap)
            due.append((t, ev))
        return due

    def __len__(self) -> int:
        return len(self._heap)

    # ------------------------------------------------------------ checkpoint
    def state(self) -> tuple[list, int]:
        """Pending ``(time, seq, event)`` entries in (time, seq) order plus
        the sequence counter — enough to rebuild the queue with identical
        FIFO tie-breaking after a resume."""
        return sorted(self._heap), self._seq

    def load_state(self, entries: list, seq: int) -> None:
        self._heap = [(float(t), int(s), ev) for t, s, ev in entries]
        heapq.heapify(self._heap)
        self._seq = int(seq)


@dataclass
class SimClock:
    """Accumulated simulated wall-clock seconds."""
    now: float = 0.0

    def advance(self, dt: float) -> None:
        self.now += float(dt)
