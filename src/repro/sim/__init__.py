"""Event-driven heterogeneity simulator for Fed-RAC.

The paper's claims are about *time* — straggler-bound round time (Eq. 2),
the MAR deadline, parallel vs sequential master–slave schedules (Eq. 9/10) —
while plain ``FedRAC.train`` only reports accuracy-per-round.  ``repro.sim``
adds the missing axis: a deterministic discrete-event engine that drives
Fed-RAC round-by-round under participant arrivals, dropouts, resource drift
(Procedure-2 reassignment) and straggler spikes, enforces each cluster's MAR
budget (drop / mask / wait policies), and records a per-round timeline of
wall-clock, stragglers, bytes and MAR violations.

Straggler and dropout decisions become ``step_mask`` rows of the batched
vmap cluster update (``core.client.make_cluster_update``), so the simulator
and the fast training path share one program.

At fleet scale (10⁴–10⁶ participants) the object-per-participant engine
gives way to the vectorized stack: columnar traces (``FleetTrace`` /
``make_fleet_trace``) over a struct-of-arrays ``core.resources.Fleet``,
driven by ``FleetSim`` — same scenarios, same seeds, whole-fleet numpy ops.
"""
from repro.sim.async_server import AsyncPlaneServer, MasterBlock
from repro.sim.clock import ClusterClock, EventQueue, SimClock
from repro.sim.engine import HeterogeneitySim, SimConfig
from repro.sim.events import (Arrival, ClusterDone, Departure, Event,
                              ResourceDrift, SpikeEnd, StragglerSpike,
                              event_priority)
from repro.sim.fleet import (FleetReport, FleetRoundRecord, FleetSim,
                             FleetSimConfig)
from repro.sim.report import ClusterRoundStats, RoundRecord, SimReport
from repro.sim.traces import (SCENARIOS, FleetTrace, Trace, make_fleet_trace,
                              make_trace, sample_profiles, scenario_knobs)

__all__ = [
    "Arrival", "AsyncPlaneServer", "ClusterClock", "ClusterDone",
    "ClusterRoundStats", "Departure", "Event", "EventQueue",
    "FleetReport", "FleetRoundRecord", "FleetSim", "FleetSimConfig",
    "FleetTrace", "HeterogeneitySim", "MasterBlock", "ResourceDrift",
    "RoundRecord", "SCENARIOS", "SimClock", "SimConfig", "SimReport",
    "SpikeEnd", "StragglerSpike", "Trace", "event_priority",
    "make_fleet_trace", "make_trace", "sample_profiles", "scenario_knobs",
]
