"""Vectorized fleet simulator: Fed-RAC orchestration at 10⁴–10⁶ devices.

``HeterogeneitySim`` exercises the full training path (per-cluster vmap
updates, KD, buffered aggregation) but walks Python objects per participant
— fine at paper scale (10–10³), hopeless at fleet scale.  ``FleetSim`` is
the orchestration-layer counterpart: the whole population lives in a
``Fleet`` struct-of-arrays, events come from ``FleetTrace`` columnar tables,
and every round is a handful of whole-fleet numpy ops — event application,
Eq. 2 pricing, FedCS selection, MAR policy, telemetry — with no O(n²) array
and no per-participant Python loop anywhere:

* setup runs the fleet-scale Procedure 1 (``fleet_optimal_clusters``:
  subsampled k-means + sampled Dunn) and orders clusters master-first;
* drift re-placement is the vectorized Procedure 2
  (``reassign_by_centroids`` — one argmin over the frozen centroids);
* client selection implements FedCS (arXiv:1804.08333) per cluster as a
  sort + prefix scan: admit in ascending round-time order while
  Θ = max(T_train) + Σ T_comm stays within the cluster MAR;
* all four MAR policies (drop / mask / wait / buffer) apply as boolean
  masks; ``buffer`` banks each round's violators and credits them to the
  next round's flush count (no model state at this scale — weights and
  step-masks are what the training path would consume).

Model updates themselves are NOT simulated here — this is the server's
scheduling/accounting view, the layer whose cost ceiling used to be Python.
"""
from __future__ import annotations

import logging
from dataclasses import dataclass, field

import numpy as np

from repro.core import cost_model
from repro.core.assignment import build_cluster_specs, reassign_by_centroids
from repro.core.clustering import fleet_optimal_clusters
from repro.core.resources import Fleet
from repro.core.rounds import ConvergenceConstants
from repro.sim.faults import NULL_FAULTS
from repro.sim.traces import FleetTrace

log = logging.getLogger("repro.sim")

# FleetRoundRecord fields that are per-level arrays (serialized stacked as
# (rounds, m) in run-state checkpoints; round/duration/events go in meta)
_ROW_ARRAY_FIELDS = ("time", "active", "masked", "dropped", "offline",
                     "unselected", "violations", "banked", "flushed", "bytes")


@dataclass
class FleetSimConfig:
    rounds: int = 3
    mar_policy: str = "drop"          # drop | mask | wait | buffer
    select: str = "all"               # all | fedcs
    select_budget: int = 0            # fedcs: max clients/cluster (0 = ∞)
    schedule: str = "parallel"        # Eq. 9 | Eq. 10 round-duration combine
    steps_per_round: int = 20
    mar: float = 0.0                  # master budget; 0 → auto percentile
    mar_percentile: float = 40.0
    kappa: float = 0.7
    lam: tuple = (1 / 3, 1 / 3, 1 / 3)
    k_cap: int = 8
    seed: int = 0
    base_model_bytes: float = 4e5     # level-l model: base · 0.5^l
    base_flops: float = 2e6
    E: int = 5
    batch_size: int = 32
    min_speed: float = 0.05           # drift floors, as in SimConfig
    min_rate: float = 0.1
    min_mem: float = 0.25
    mode: str = "sync"                # sync (global barrier) | async
    #   async: clusters advance on independent cumulative clocks; a round's
    #   wall-clock charge is the increment of the SLOWEST cumulative clock,
    #   so total wall-clock = max_l Σ_r t[l,r] ≤ the barrier's Σ_r max_l —
    #   the no-global-straggler-bound accounting of the async server


@dataclass
class FleetRoundRecord:
    """Per-round per-level counts — the columnar analogue of a
    ``RoundRecord`` full of ``ClusterRoundStats`` (arrays of length m)."""
    round: int
    duration: float
    time: np.ndarray            # per-cluster round duration
    active: np.ndarray
    masked: np.ndarray
    dropped: np.ndarray
    offline: np.ndarray
    unselected: np.ndarray
    violations: np.ndarray
    banked: np.ndarray
    flushed: np.ndarray
    bytes: np.ndarray
    events: int                 # trace events applied this round


@dataclass
class FleetReport:
    scenario: str
    mar_policy: str
    select: str
    n: int
    k: int
    di_values: dict
    mar: list
    rows: list = field(default_factory=list)
    levels: np.ndarray | None = None     # final per-participant level

    def summary(self) -> dict:
        tot = lambda name: int(sum(int(getattr(r, name).sum())
                                   for r in self.rows))
        active = tot("active") + tot("masked")   # masked still contribute
        banked = tot("banked")
        slots = (active + banked + tot("dropped") + tot("offline")
                 + tot("unselected"))
        return {
            "scenario": self.scenario,
            "mar_policy": self.mar_policy,
            "select": self.select,
            "fleet_size": self.n,
            "k": self.k,
            "rounds": len(self.rows),
            "wall_clock_s": round(sum(r.duration for r in self.rows), 3),
            "total_bytes": float(sum(float(r.bytes.sum())
                                     for r in self.rows)),
            "participation_rate": round((active + banked) / slots, 4)
                                  if slots else 0.0,
            "mar_violations": tot("violations"),
            "dropped_total": tot("dropped"),
            "unselected_total": tot("unselected"),
            "banked_total": banked,
            "flushed_total": tot("flushed"),
            "cluster_sizes": (np.bincount(self.levels, minlength=self.k)
                              .tolist() if self.levels is not None else []),
        }


def _sorted_table(tab: dict) -> dict:
    order = np.argsort(tab["time"], kind="stable")
    return {k: v[order] for k, v in tab.items()}


class FleetSim:
    """Couples a ``Fleet`` with a ``FleetTrace`` and runs vectorized rounds.

    ``checkpoint``/``faults`` mirror ``HeterogeneitySim``: a
    ``RunCheckpointer`` snapshots the whole-fleet arrays (V, online, spike,
    levels, dropout/rejoin state, trace cursors, per-round records) at round
    boundaries and resumes bit-identically; a ``FaultInjector`` SIGKILLs at
    boundaries for the kill-and-resume harness."""

    KIND = "fleet-sim"

    def __init__(self, fleet: Fleet, trace: FleetTrace, cfg: FleetSimConfig,
                 checkpoint=None, faults=None):
        if cfg.mar_policy not in ("drop", "mask", "wait", "buffer"):
            raise ValueError(f"unknown mar_policy {cfg.mar_policy!r}")
        if cfg.select not in ("all", "fedcs"):
            raise ValueError(f"unknown select {cfg.select!r}")
        if cfg.schedule not in ("parallel", "sequential"):
            raise ValueError(f"unknown schedule {cfg.schedule!r}")
        if cfg.mode not in ("sync", "async"):
            raise ValueError(f"unknown mode {cfg.mode!r}")
        if cfg.mode == "async" and cfg.schedule == "sequential":
            raise ValueError('mode "async" requires schedule "parallel"')
        self.fleet, self.trace, self.cfg = fleet, trace, cfg
        n = len(fleet)

        # ---- Procedure 1 (fleet path) + master-first cluster ordering
        self.clustering = fleet_optimal_clusters(
            fleet.V, cfg.lam, seed=cfg.seed, k_cap=cfg.k_cap)
        self.m = max(self.clustering.k, 1)
        lab = self.clustering.labels
        lam_a = np.asarray(cfg.lam, np.float64)
        Vb = (fleet.V - self.clustering.lo) / self.clustering.span
        score = np.full(self.m, -np.inf)
        wsum = (Vb * lam_a).sum(axis=1)
        cnt = np.bincount(lab, minlength=self.m)
        tot = np.bincount(lab, weights=wsum, minlength=self.m)
        score[cnt > 0] = tot[cnt > 0] / cnt[cnt > 0]
        self.level_of_cluster = np.empty(self.m, np.int64)
        self.level_of_cluster[np.argsort(-score)] = np.arange(self.m)
        self.levels = self.level_of_cluster[lab]

        # ---- per-level specs (geometric model family) with auto-MAR:
        # the paper's §V default — the 40th percentile of the master
        # cluster's round times, scaled per level by κ (§IV-C)
        sizes = [(cfg.base_model_bytes * 0.5 ** l, cfg.base_flops * 0.5 ** l)
                 for l in range(self.m)]
        self.specs = build_cluster_specs(
            sizes, ConvergenceConstants(), E=cfg.E, mar=1.0,
            kappa=cfg.kappa, batch_size=cfg.batch_size)
        self.model_bytes = np.array([s.model_bytes for s in self.specs])
        self.flops = np.array([s.flops_per_sample for s in self.specs])
        if cfg.mar > 0.0:
            master_mar = cfg.mar
        else:
            mem0 = self.levels == 0
            t0 = (cost_model.train_time_vec(
                      fleet.V[mem0, 0], self.flops[0], cfg.E,
                      fleet.n_data[mem0])
                  + cost_model.comm_time_vec(fleet.V[mem0, 1],
                                             self.model_bytes[0]))
            master_mar = (float(np.percentile(t0, cfg.mar_percentile))
                          if mem0.any() else 1.0)
        # build_cluster_specs takes the LAST level's budget and applies
        # T_{f-1} = κ T_f upward; master_mar / κ^{m-1} pins level 0
        self.specs = build_cluster_specs(
            sizes, ConvergenceConstants(), E=cfg.E,
            mar=master_mar / cfg.kappa ** (self.m - 1),
            kappa=cfg.kappa, batch_size=cfg.batch_size)
        self.mar = np.array([s.mar for s in self.specs])

        # ---- dynamic state (whole-fleet arrays; V/online/spike live on
        # the Fleet so row views stay coherent)
        off = np.zeros(n, bool)
        if trace.initially_offline:
            off[np.fromiter(trace.initially_offline, np.int64)] = True
        fleet.online[:] = ~off
        self.gone = np.zeros(n, bool)
        self.rejoin_round = np.full(n, np.inf)
        self.spike_end = np.full(n, -np.inf)
        self._banked_prev = np.zeros(self.m, np.int64)
        # async mode: per-cluster cumulative clocks (simulated seconds)
        self.cluster_time = np.zeros(self.m)

        self._tabs = {"dropouts": _sorted_table(trace.dropouts),
                      "drifts": _sorted_table(trace.drifts),
                      "spikes": _sorted_table(trace.spikes),
                      "arrivals": _sorted_table(trace.arrivals)}
        self._cur = {k: 0 for k in self._tabs}
        self.checkpoint = checkpoint
        self.faults = faults if faults is not None else NULL_FAULTS
        self.report: FleetReport | None = None
        self._pending_state = None

    # ------------------------------------------------------------ events
    def _due(self, name: str, r: int) -> dict:
        tab, lo = self._tabs[name], self._cur[name]
        hi = int(np.searchsorted(tab["time"], float(r), side="right"))
        self._cur[name] = max(hi, lo)
        return {k: v[lo:hi] for k, v in tab.items()} if hi > lo else None

    def _apply_events(self, r: int) -> int:
        fleet, cfg = self.fleet, self.cfg
        applied = 0
        # spike expiry first, then this round's events overwrite
        expired = (fleet.spike != 1.0) & (self.spike_end <= r)
        fleet.spike[expired] = 1.0
        # arrivals before departures at equal timestamps (same netting rule
        # as the event-queue engine): trace arrivals re-register, scheduled
        # rejoins only fire for non-permanent departures
        tab = self._due("arrivals", r)
        if tab is not None:
            pid = tab["pid"]
            self.gone[pid] = False
            fleet.online[pid] = True
            self.rejoin_round[pid] = np.inf
            applied += len(pid)
        rj = (self.rejoin_round <= r) & ~self.gone
        if rj.any():
            fleet.online |= rj
            self.rejoin_round[rj] = np.inf
        tab = self._due("dropouts", r)
        if tab is not None:
            live = ~self.gone[tab["pid"]]      # noise for permanently-gone
            pid, rejoin = tab["pid"][live], tab["rejoin"][live]
            fleet.online[pid] = False
            perm = np.isnan(rejoin)
            self.gone[pid[perm]] = True
            self.rejoin_round[pid[perm]] = np.inf
            self.rejoin_round[pid[~perm]] = r + rejoin[~perm]
            applied += len(pid)
        tab = self._due("spikes", r)
        if tab is not None:
            pid = tab["pid"]
            fleet.spike[pid] = tab["factor"]
            self.spike_end[pid] = r + tab["duration"]
            applied += len(pid)
        tab = self._due("drifts", r)
        if tab is not None:
            pid = tab["pid"]
            V = fleet.V
            V[pid, 0] = np.maximum(V[pid, 0] * tab["s_mult"], cfg.min_speed)
            V[pid, 1] = np.maximum(V[pid, 1] * tab["r_mult"], cfg.min_rate)
            V[pid, 2] = np.maximum(V[pid, 2] * tab["a_mult"], cfg.min_mem)
            # vectorized Procedure 2: drifted rows re-place in one argmin
            self.levels[pid] = reassign_by_centroids(
                V[pid], self.clustering, self.level_of_cluster)
            applied += len(pid)
        return applied

    # ------------------------------------------------------------ rounds
    def _price(self):
        fleet, lv = self.fleet, self.levels
        t_train = cost_model.train_time_vec(
            fleet.V[:, 0], self.flops[lv], self.cfg.E, fleet.n_data,
            compute_slowdown=fleet.spike)
        t_comm = cost_model.comm_time_vec(fleet.V[:, 1],
                                          self.model_bytes[lv])
        return t_train, t_comm

    def _fedcs_unselected(self, t_train, t_comm, online) -> np.ndarray:
        """Per-cluster FedCS admission (sort + prefix Θ scan); True where an
        online member is NOT admitted this round."""
        cfg = self.cfg
        out = np.zeros(len(self.levels), bool)
        t = t_train + t_comm
        for lvl in range(self.m):
            mem = np.flatnonzero((self.levels == lvl) & online)
            if len(mem) == 0:
                continue
            order = mem[np.lexsort((mem, t[mem]))]
            theta = (np.maximum.accumulate(t_train[order])
                     + np.cumsum(t_comm[order]))
            take = int(np.searchsorted(theta, self.specs[lvl].mar,
                                       side="right"))
            if cfg.select_budget:
                take = min(take, cfg.select_budget)
            out[order[take:]] = True
        return out

    def _round(self, r: int, applied: int) -> FleetRoundRecord:
        cfg, m = self.cfg, self.m
        S = cfg.steps_per_round
        lv = self.levels
        t_train, t_comm = self._price()
        t = t_train + t_comm
        mar = self.mar[lv]
        online = self.fleet.online
        offline = ~online

        unselected = np.zeros(len(lv), bool)
        if cfg.select == "fedcs":
            unselected = self._fedcs_unselected(t_train, t_comm, online)
        sel = online & ~unselected
        viol = sel & (t > mar)

        dropped = np.zeros(len(lv), bool)
        banked = np.zeros(len(lv), bool)
        is_masked = np.zeros(len(lv), bool)
        contrib_t = np.where(sel, t, 0.0)
        weights = np.where(sel, self.fleet.n_data, 0).astype(np.float64)
        if cfg.mar_policy == "drop":
            dropped = viol
        elif cfg.mar_policy == "buffer":
            banked = viol
            contrib_t[viol] = 0.0     # late upload is off the critical path
            weights[viol] = 0.0
        elif cfg.mar_policy == "mask":
            with np.errstate(divide="ignore", invalid="ignore"):
                granted = np.floor(S * (mar - t_comm)
                                   / np.where(t_train > 0, t_train, np.inf))
            granted = np.clip(np.nan_to_num(granted, nan=0.0,
                                            neginf=0.0), 0, S)
            is_masked = viol & (granted > 0)
            dropped = viol & (granted == 0)
            frac = granted / S
            weights[is_masked] = (self.fleet.n_data[is_masked]
                                  * frac[is_masked])
            contrib_t[is_masked] = (t_train[is_masked] * frac[is_masked]
                                    + t_comm[is_masked])
        # wait: violators contribute in full, the round runs straggler-bound
        contrib_t[dropped] = 0.0
        weights[dropped] = 0.0

        active = sel & (weights > 0) & ~is_masked
        ct = np.zeros(m)
        contributing = contrib_t > 0
        np.maximum.at(ct, lv[contributing], contrib_t[contributing])
        if cfg.mode == "async":
            # independent cluster clocks: each cluster accumulates its OWN
            # round time; the round's wall-clock charge is the increment of
            # the slowest cumulative clock, so Σ durations telescopes to
            # max_l Σ_r t[l,r] — no global straggler bound
            prev = float(self.cluster_time.max(initial=0.0))
            self.cluster_time += ct
            duration = float(self.cluster_time.max(initial=0.0)) - prev
        else:
            duration = (float(ct.max(initial=0.0))
                        if cfg.schedule == "parallel" else float(ct.sum()))

        cnt = lambda mask: np.bincount(lv[mask], minlength=m)
        n_active, n_masked = cnt(active), cnt(is_masked)
        n_dropped, n_banked = cnt(dropped), cnt(banked)
        if cfg.mode == "async":
            # conservation re-derived per merge event: every participant in
            # exactly one bucket of its cluster's merge
            buckets = (n_active + n_masked + n_dropped + n_banked
                       + cnt(offline) + cnt(unselected & online)
                       + cnt(sel & (weights <= 0) & ~is_masked & ~dropped
                             & ~banked))
            n_lv = np.bincount(lv, minlength=m)
            if not np.array_equal(buckets, n_lv):
                raise RuntimeError(
                    f"conservation violated at round {r}: per-level buckets "
                    f"{buckets.tolist()} != membership {n_lv.tolist()}")
        rec = FleetRoundRecord(
            round=r, duration=duration, time=ct,
            active=n_active, masked=n_masked, dropped=n_dropped,
            offline=cnt(offline), unselected=cnt(unselected & online),
            violations=cnt(viol), banked=n_banked,
            flushed=self._banked_prev,
            bytes=self.model_bytes * (
                2.0 * (n_active + n_masked + n_banked) + 1.0 * n_dropped),
            events=applied)
        self._banked_prev = n_banked
        return rec

    def run(self) -> FleetReport:
        report = FleetReport(
            scenario=self.trace.name, mar_policy=self.cfg.mar_policy,
            select=self.cfg.select, n=len(self.fleet), k=self.m,
            di_values=self.clustering.di_values,
            mar=[round(float(v), 4) for v in self.mar])
        self.report = report
        r0 = self._maybe_resume(report)
        for r in range(r0, self.cfg.rounds):
            applied = self._apply_events(r)
            report.rows.append(self._round(r, applied))
            self._round_boundary(r + 1, report)
        # terminal flush: updates banked in the last round still merge
        if self._banked_prev.any() and report.rows:
            report.rows[-1].flushed = (report.rows[-1].flushed
                                       + self._banked_prev)
            self._banked_prev = np.zeros(self.m, np.int64)
        report.levels = self.levels
        return report

    # ------------------------------------------------------------ checkpoint
    def _round_boundary(self, r: int, report: FleetReport) -> None:
        if self.checkpoint is not None:
            meta, arrays = self._capture_state(r, report.rows)
            self._pending_state = (r, meta, arrays)
            if self.checkpoint.due(r):
                self.checkpoint.save(r, self.KIND, meta, arrays)
        self.faults.round_boundary(r)

    def save_now(self):
        """Write the newest retained boundary snapshot (graceful shutdown);
        returns the step written, or None."""
        if self.checkpoint is None or self._pending_state is None:
            return None
        r, meta, arrays = self._pending_state
        self.checkpoint.save(r, self.KIND, meta, arrays)
        return r

    def _capture_state(self, r: int, rows: list) -> tuple[dict, dict]:
        fleet = self.fleet
        meta = {
            "round": int(r),
            "seed": int(self.cfg.seed),
            "rows": [{"round": int(x.round), "duration": float(x.duration),
                      "events": int(x.events)} for x in rows],
        }
        arrays = {
            "fleet/V": fleet.V.copy(),
            "fleet/n_data": fleet.n_data.copy(),
            "fleet/online": fleet.online.copy(),
            "fleet/spike": fleet.spike.copy(),
            "levels": self.levels.copy(),
            "gone": self.gone.copy(),
            "rejoin_round": self.rejoin_round.copy(),
            "spike_end": self.spike_end.copy(),
            "banked_prev": self._banked_prev.copy(),
            "cluster_time": self.cluster_time.copy(),
            "cur": np.array([self._cur[k] for k in sorted(self._tabs)],
                            np.int64),
        }
        for f in _ROW_ARRAY_FIELDS:
            arrays[f"rows/{f}"] = (
                np.stack([np.asarray(getattr(x, f)) for x in rows])
                if rows else np.zeros((0, self.m)))
        return meta, arrays

    def _maybe_resume(self, report: FleetReport) -> int:
        ck = self.checkpoint
        if ck is None or not ck.resume:
            return 0
        got = ck.load_latest(self.KIND)
        if got is None:
            log.warning("resume requested but no valid checkpoint under "
                        "%s; starting from round 0", ck.manager.dir)
            return 0
        _, meta, arrays = got
        return self._load_state(meta, arrays, report)

    def _load_state(self, meta: dict, arrays: dict,
                    report: FleetReport) -> int:
        fleet = self.fleet
        fleet.V[:] = arrays["fleet/V"]
        fleet.n_data[:] = arrays["fleet/n_data"]
        fleet.online[:] = arrays["fleet/online"].astype(bool)
        fleet.spike[:] = arrays["fleet/spike"]
        self.levels[:] = arrays["levels"]
        self.gone[:] = arrays["gone"].astype(bool)
        self.rejoin_round[:] = arrays["rejoin_round"]
        self.spike_end[:] = arrays["spike_end"]
        self._banked_prev = arrays["banked_prev"].astype(np.int64).copy()
        if "cluster_time" in arrays:     # absent in pre-async checkpoints
            self.cluster_time[:] = arrays["cluster_time"]
        for k, v in zip(sorted(self._tabs), arrays["cur"]):
            self._cur[k] = int(v)
        report.rows = [
            FleetRoundRecord(
                round=int(rm["round"]), duration=float(rm["duration"]),
                events=int(rm["events"]),
                **{f: arrays[f"rows/{f}"][i].copy()
                   for f in _ROW_ARRAY_FIELDS})
            for i, rm in enumerate(meta["rows"])]
        r0 = int(meta["round"])
        log.info("resumed fleet run at round %d from %s", r0,
                 self.checkpoint.manager.dir)
        return r0
