"""Fault-injection harness for the crash-safety subsystem.

``FaultInjector`` hooks into the engines' round pipeline at the two places a
real server dies: round boundaries (after the boundary checkpoint is
written) and mid-dispatch-block (after the fused program ran, before its
rounds are recorded — the on-disk state is strictly older than the lost
work).  A triggered fault delivers an un-catchable ``SIGKILL`` to the
process, exactly what the kill-and-resume CI lane and the equivalence
matrix's resume column need; tests that must stay in-process set
``raise_instead`` to get a ``SimulatedCrash`` exception with identical
placement instead.

``corrupt_checkpoint`` damages the newest checkpoint in a manifest
directory in controlled ways (truncation, bit garbage, deleted leaf file,
manifest corruption) so the degrade-to-previous-valid restore path is
testable from both pytest and the ``sim_run --corrupt-ckpt`` CLI.

``python -m repro.sim.faults --compare-reports a.json b.json`` is the CI
oracle: exits nonzero unless two ``--report-out`` JSON documents are
bit-identical (floats round-trip JSON via ``repr``, so document equality IS
bit-equality of every loss/duration/byte count and the params CRC).
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import sys
from dataclasses import dataclass

from repro.ckpt.manifest import ARRAYS_FILE, MANIFEST, CheckpointManager


class SimulatedCrash(RuntimeError):
    """In-process stand-in for SIGKILL (``FaultPlan.raise_instead``)."""


class GracefulShutdown(Exception):
    """Raised by the sim_run SIGTERM/SIGINT handler; the launcher catches
    it, flushes telemetry, writes a final checkpoint, and exits nonzero."""

    def __init__(self, signum: int):
        super().__init__(f"signal {signum}")
        self.signum = signum


@dataclass
class FaultPlan:
    kill_at_round: int | None = None    # die at the first boundary >= this
    kill_mid_block: int | None = None   # die inside the block covering this
    raise_instead: bool = False         # SimulatedCrash instead of SIGKILL


class FaultInjector:
    """Engine-side fault hooks; a default-constructed plan never fires."""

    def __init__(self, plan: FaultPlan | None = None):
        self.plan = plan or FaultPlan()

    def _die(self, where: str) -> None:
        if self.plan.raise_instead:
            raise SimulatedCrash(where)
        sys.stdout.flush()
        sys.stderr.flush()
        os.kill(os.getpid(), signal.SIGKILL)

    def round_boundary(self, r: int) -> None:
        """Called with ``r`` = rounds completed, right after the boundary
        snapshot is retained/written."""
        k = self.plan.kill_at_round
        if k is not None and r >= k:
            self._die(f"round boundary {r}")

    def mid_block(self, r0: int, r1: int) -> None:
        """Called inside a dispatch block spanning rounds [r0, r1), after
        the fused program executed but before its rounds are recorded."""
        k = self.plan.kill_mid_block
        if k is not None and r0 <= k < r1:
            self._die(f"mid-block [{r0}, {r1})")


NULL_FAULTS = FaultInjector()

CORRUPTION_MODES = ("truncate", "garbage", "delete", "manifest")


def corrupt_checkpoint(ckpt_dir: str, mode: str = "garbage") -> str:
    """Damage the newest checkpoint under ``ckpt_dir``; returns the path
    touched.  ``truncate`` halves ``arrays.ckpt`` (short-read artifact),
    ``garbage`` flips payload bytes in place (CRC mismatch at equal size),
    ``delete`` removes the leaf file entirely, ``manifest`` mangles
    MANIFEST.json (restore falls back to the directory scan)."""
    if mode not in CORRUPTION_MODES:
        raise ValueError(f"unknown corruption mode {mode!r}")
    if mode == "manifest":
        path = os.path.join(ckpt_dir, MANIFEST)
        with open(path, "w") as f:
            f.write('{"format": 1, "checkpoints": [truncated')
        return path
    entries = CheckpointManager(ckpt_dir)._manifest_entries()
    if not entries:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, entries[-1]["dir"], ARRAYS_FILE)
    if mode == "delete":
        os.remove(path)
        return path
    with open(path, "rb") as f:
        data = f.read()
    if mode == "truncate":
        data = data[:len(data) // 2]
    else:  # garbage: size-preserving bit damage beyond the msgpack header
        mid = len(data) // 2
        data = data[:mid] + bytes(b ^ 0xFF for b in data[mid:mid + 64]) \
            + data[mid + 64:]
    with open(path, "wb") as f:
        f.write(data)
    return path


def compare_reports(path_a: str, path_b: str) -> list[str]:
    """Differences between two report JSON documents (empty = identical)."""
    with open(path_a) as f:
        a = json.load(f)
    with open(path_b) as f:
        b = json.load(f)
    diffs: list[str] = []
    _diff("", a, b, diffs)
    return diffs


def _diff(prefix: str, a, b, out: list[str], limit: int = 40) -> None:
    if len(out) >= limit:
        return
    if type(a) is not type(b):
        out.append(f"{prefix or '/'}: type {type(a).__name__} != "
                   f"{type(b).__name__}")
    elif isinstance(a, dict):
        for k in sorted(set(a) | set(b)):
            if k not in a or k not in b:
                out.append(f"{prefix}/{k}: only in "
                           f"{'second' if k not in a else 'first'}")
            else:
                _diff(f"{prefix}/{k}", a[k], b[k], out, limit)
    elif isinstance(a, list):
        if len(a) != len(b):
            out.append(f"{prefix or '/'}: length {len(a)} != {len(b)}")
        for i, (x, y) in enumerate(zip(a, b)):
            _diff(f"{prefix}[{i}]", x, y, out, limit)
    elif a != b and not (a != a and b != b):   # NaN == NaN for our purposes
        out.append(f"{prefix or '/'}: {a!r} != {b!r}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fault-injection utilities (corrupt checkpoints, "
                    "compare run reports bit-exactly)")
    ap.add_argument("--corrupt", metavar="CKPT_DIR",
                    help="damage the newest checkpoint in this directory")
    ap.add_argument("--mode", choices=CORRUPTION_MODES, default="garbage")
    ap.add_argument("--compare-reports", nargs=2, metavar=("A", "B"),
                    help="exit 1 unless two --report-out JSONs are "
                         "bit-identical")
    args = ap.parse_args(argv)
    if args.corrupt:
        path = corrupt_checkpoint(args.corrupt, args.mode)
        print(f"corrupted ({args.mode}): {path}")
    if args.compare_reports:
        diffs = compare_reports(*args.compare_reports)
        if diffs:
            for d in diffs:
                print(f"DIFF {d}")
            print(f"reports differ ({len(diffs)} diffs shown)")
            return 1
        print("reports bit-identical")
    return 0


if __name__ == "__main__":
    sys.exit(main())
