"""Participant lifecycle events consumed by the simulation engine.

All events are frozen dataclasses keyed by participant id; the engine
dispatches on type.  Timestamps live in the queue, not the event, so the
same event object can be rescheduled (e.g. an auto-rejoin ``Arrival``).
"""
from __future__ import annotations

from dataclasses import asdict, dataclass


@dataclass(frozen=True)
class Event:
    pid: int


@dataclass(frozen=True)
class Arrival(Event):
    """Participant comes online.  Trace-authored arrivals (late joiners)
    carry ``token=None`` and always apply; engine-scheduled rejoins carry the
    departure generation that queued them, so a newer ``Departure`` landing
    inside the rejoin window supersedes the stale rejoin."""
    token: int | None = None


@dataclass(frozen=True)
class Departure(Event):
    """Participant goes offline.  ``rejoin_after`` (round units) schedules an
    automatic ``Arrival``; ``None`` means a permanent dropout."""
    rejoin_after: float | None = None


@dataclass(frozen=True)
class ResourceDrift(Event):
    """§IV-A dynamic resources: multiplicative change to (s, r, a).  The
    engine mutates the participant and re-runs Procedure-2 placement, so the
    participant may migrate clusters."""
    s_mult: float = 1.0
    r_mult: float = 1.0
    a_mult: float = 1.0


@dataclass(frozen=True)
class StragglerSpike(Event):
    """Transient slowdown: compute time is multiplied by ``factor`` for
    ``duration`` rounds (thermal throttling, co-located load, ...)."""
    factor: float = 4.0
    duration: float = 1.0


@dataclass(frozen=True)
class SpikeEnd(Event):
    """Internal: clears the straggler spike identified by ``token`` (scheduled
    by the engine; a stale SpikeEnd must not clear a newer spike)."""
    token: int = 0


@dataclass(frozen=True)
class ClusterDone(Event):
    """Internal async-server event: cluster ``level``'s in-flight dispatch
    block completes and its delta is ready to merge.  Lives on the
    *completion* queue (timestamps in simulated seconds, not round units);
    ``pid`` is unused and pinned to -1."""
    level: int = 0


# name -> class registry for checkpoint (de)serialization of pending events
EVENT_TYPES = {cls.__name__: cls
               for cls in (Arrival, Departure, ResourceDrift,
                           StragglerSpike, SpikeEnd, ClusterDone)}


def event_priority(ev: Event) -> int:
    """Fixed per-type heap tie-break: at equal timestamps an ``Arrival``
    must be visible before any other event (a rejoin landing at the same
    instant as a drift/departure would otherwise be masked); every other
    type keeps FIFO order via the sequence number.  This makes merge order
    in the async server seed-stable across platforms rather than an
    artifact of insertion order."""
    return 0 if isinstance(ev, Arrival) else 1


def encode_event(ev: Event) -> list:
    """JSON-safe ``[type_name, fields]`` form of one event."""
    return [type(ev).__name__, asdict(ev)]


def decode_event(rec: list) -> Event:
    name, fields = rec
    try:
        cls = EVENT_TYPES[name]
    except KeyError:
        raise ValueError(f"unknown event type {name!r} in checkpoint") from None
    return cls(**fields)
