"""Participant lifecycle events consumed by the simulation engine.

All events are frozen dataclasses keyed by participant id; the engine
dispatches on type.  Timestamps live in the queue, not the event, so the
same event object can be rescheduled (e.g. an auto-rejoin ``Arrival``).
"""
from __future__ import annotations

from dataclasses import asdict, dataclass


@dataclass(frozen=True)
class Event:
    pid: int


@dataclass(frozen=True)
class Arrival(Event):
    """Participant comes online.  Trace-authored arrivals (late joiners)
    carry ``token=None`` and always apply; engine-scheduled rejoins carry the
    departure generation that queued them, so a newer ``Departure`` landing
    inside the rejoin window supersedes the stale rejoin."""
    token: int | None = None


@dataclass(frozen=True)
class Departure(Event):
    """Participant goes offline.  ``rejoin_after`` (round units) schedules an
    automatic ``Arrival``; ``None`` means a permanent dropout."""
    rejoin_after: float | None = None


@dataclass(frozen=True)
class ResourceDrift(Event):
    """§IV-A dynamic resources: multiplicative change to (s, r, a).  The
    engine mutates the participant and re-runs Procedure-2 placement, so the
    participant may migrate clusters."""
    s_mult: float = 1.0
    r_mult: float = 1.0
    a_mult: float = 1.0


@dataclass(frozen=True)
class StragglerSpike(Event):
    """Transient slowdown: compute time is multiplied by ``factor`` for
    ``duration`` rounds (thermal throttling, co-located load, ...)."""
    factor: float = 4.0
    duration: float = 1.0


@dataclass(frozen=True)
class SpikeEnd(Event):
    """Internal: clears the straggler spike identified by ``token`` (scheduled
    by the engine; a stale SpikeEnd must not clear a newer spike)."""
    token: int = 0


# name -> class registry for checkpoint (de)serialization of pending events
EVENT_TYPES = {cls.__name__: cls
               for cls in (Arrival, Departure, ResourceDrift,
                           StragglerSpike, SpikeEnd)}


def encode_event(ev: Event) -> list:
    """JSON-safe ``[type_name, fields]`` form of one event."""
    return [type(ev).__name__, asdict(ev)]


def decode_event(rec: list) -> Event:
    name, fields = rec
    try:
        cls = EVENT_TYPES[name]
    except KeyError:
        raise ValueError(f"unknown event type {name!r} in checkpoint") from None
    return cls(**fields)
