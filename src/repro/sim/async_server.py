"""Continuous-time asynchronous parameter server (ROADMAP item 3).

One ``AsyncPlaneServer`` per cluster level owns that cluster's shared
parameter state — the flat ``(capacity, D)``-derived ``(D_pad,)`` aggregated
plane in dispatch mode, the params pytree in legacy mode — plus the two
counters that define async semantics:

* ``version`` — the number of committed communication rounds.  A dispatch
  block *pulls* ``(state, version)``, trains ``L`` fused rounds against that
  snapshot, and *commits* its result at its own completion time, advancing
  the version by ``L``.  Staleness is measured in server versions: a ledger
  entry tagged with the version it was banked at weighs
  ``n · discount**(V_merge − V_banked)``
  (:func:`repro.core.aggregation.version_staleness_weights`) when it merges
  at version ``V_merge``.  With versions advancing one per round this is
  numerically identical to the buffered path's round-age discount — the
  synchronized-arrival anchor that makes ``mode="async"`` with
  ``max_staleness=0`` reproduce the buffered engine bit-for-bit.
* ``merges`` — the merge-event counter.  Async mode has no global round
  barrier, so checkpoint cadence, fault-injection points and the
  conservation invariant all re-anchor on merge events instead of rounds.

The ledger IS the buffered engine's bank (the engine hands the same list
object to the server): entries ``{"pid", "round" (== version tag), "n_eff",
"plane"|"params"}`` are violators whose late update is in flight between
their dispatch and the cluster's next merge — the bank stops being a
round-boundary holding pen and becomes the server's in-flight delta ledger.

``MasterBlock`` records the master cluster's most recent dispatch (eagerly
computed, possibly not yet committed): block start round, length, the
pre-block state and the per-round post-round plane history.  A slave block
whose rounds align with it gets the exact per-round KD teacher stack the
synchronous schedule would have used; a misaligned slave (clusters drifted
apart under unbounded staleness) falls back to the master's latest
*committed* state broadcast across its rounds — a stale teacher, the KD
analogue of a stale gradient.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class MasterBlock:
    """The master cluster's most recent dispatch block (KD teacher source)."""
    r0: int                 # first round of the block
    length: int             # rounds in the block
    start: object           # pre-block plane / params (parallel cadence)
    hist: object = None     # (L, D0) per-round post planes (dispatch mode)


class AsyncPlaneServer:
    """Per-cluster shared-state owner for ``mode="async"``."""

    def __init__(self, level: int, state, ledger: list | None = None):
        self.level = level
        self.state = state
        self.version = 0         # committed rounds
        self.merges = 0          # merge events committed
        # in-flight delta ledger — aliases the engine's bank for this level
        self.ledger = ledger if ledger is not None else []

    # ------------------------------------------------------------ protocol
    def pull(self):
        """Snapshot for a new dispatch block: (state, version)."""
        return self.state, self.version

    def commit(self, state, n_rounds: int) -> None:
        """Merge event: install the block's resulting state, advance the
        version by the block length."""
        self.state = state
        self.version += int(n_rounds)
        self.merges += 1

    # ------------------------------------------------------------ ledger
    def ripe(self) -> list:
        """Ledger entries banked strictly before the current version —
        eligible to merge into the next dispatch at a discounted weight."""
        return [b for b in self.ledger if b["round"] < self.version]

    def drop_ripe(self) -> None:
        """Remove ripe entries in place (they merged); keeps the engine's
        aliased bank list consistent."""
        self.ledger[:] = [b for b in self.ledger if b["round"] >= self.version]

    def lag_of(self, entry: dict) -> int:
        """Version lag of one ledger entry at the current version."""
        return int(self.version) - int(entry["round"])
