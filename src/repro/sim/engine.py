"""Deadline-aware round engine: drives a ``FedRAC`` instance round-by-round
under an event trace, enforcing each cluster's MAR time budget.

Per round the engine (1) fires all due events — dropouts, arrivals, resource
drift through the Procedure-2 ``update_resources`` path (participants migrate
clusters in place), straggler spikes; (2) prices every member's round via the
cost model (Eq. 2, with transient slowdowns); (3) applies the MAR policy:

* ``drop``  — members with T_i > MAR are excluded this round (zero step-mask
  row, zero aggregation weight; partial aggregation renormalizes the rest);
* ``mask``  — they train only the ⌊S·(MAR − T_c)/T_a⌋ local steps whose
  (slowdown-adjusted) train time still fits the deadline after the fixed
  communication cost, down-weighted by the granted fraction (comm time
  alone blowing the budget degrades to a download-only drop);
* ``wait``  — nobody is cut; the round runs straggler-bound (Eq. 2), the
  violation is only recorded;
* ``buffer`` — violators train their full τ steps but miss the synchronous
  aggregate; their update is banked and joins the NEXT round's FedAvg at a
  staleness-discounted weight (``FLConfig(aggregation="buffered")``) — the
  round stays bounded by the on-time members, and the straggler's work is
  not thrown away.

Masks and weights feed ``FedRAC.cluster_round`` — one batched vmap update per
cluster per round — so the simulator exercises exactly the fast path.
"""
from __future__ import annotations

import logging
import math
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointError
from repro.core import aggregation, cost_model
from repro.core.server import FedRAC
from repro.data import device_sampler
from repro.obs import NULL_OBS
from repro.sim.async_server import AsyncPlaneServer, MasterBlock
from repro.sim.clock import ClusterClock, EventQueue, SimClock
from repro.sim.events import (Arrival, ClusterDone, Departure, ResourceDrift,
                              SpikeEnd, StragglerSpike)
from repro.sim.faults import NULL_FAULTS
from repro.sim.report import (ClusterRoundStats, RoundRecord, SimReport,
                              decode_rows, decode_stats, encode_rows,
                              encode_stats)
from repro.sim.traces import Trace

log = logging.getLogger("repro.sim")


@dataclass
class SimConfig:
    rounds: int = 10
    mar_policy: str = "drop"          # drop | mask | wait | buffer
    schedule: str = "parallel"        # Eq. 9 parallel | Eq. 10 sequential
    eval_every: int = 0               # 0 → evaluate only after the last round
    min_speed: float = 0.05           # drift clamps (GHz / Mbps / GB floors)
    min_rate: float = 0.1
    min_mem: float = 0.25
    select: str = "all"               # all | fedcs (per-cluster selection)
    select_budget: int = 0            # fedcs: max clients/cluster (0 = ∞)
    mode: str = "sync"                # sync | async (continuous-time server)
    max_staleness: int | None = None  # async: max committed-round lead over
    #                                   the slowest cluster; 0 = barrier
    #                                   (reproduces the sync buffered path),
    #                                   None = unbounded


class HeterogeneitySim:
    """Couples a set-up ``FedRAC`` with a ``Trace`` and runs the event loop.

    ``checkpoint`` (a ``repro.ckpt.run_state.RunCheckpointer``) arms
    crash-safe resumable runs: a versioned run-state snapshot — planes,
    buffered bank, sampler position, participant resources, assignment,
    event queue, clock, report rows, metrics tables — is captured at every
    round boundary, written at the configured cadence, and (with
    ``resume=True``) restored from the newest valid checkpoint so a killed
    run continues bit-identically.  ``faults`` (a
    ``repro.sim.faults.FaultInjector``) injects SIGKILLs at the boundary
    and mid-dispatch-block hook points for the kill-and-resume tests."""

    KIND = "hetero-sim"

    def __init__(self, fedrac: FedRAC, trace: Trace, cfg: SimConfig,
                 obs=None, checkpoint=None, faults=None):
        if cfg.mar_policy not in ("drop", "mask", "wait", "buffer"):
            raise ValueError(f"unknown mar_policy {cfg.mar_policy!r}")
        if cfg.schedule not in ("parallel", "sequential"):
            raise ValueError(f"unknown schedule {cfg.schedule!r}")
        if cfg.select not in ("all", "fedcs"):
            raise ValueError(f"unknown select {cfg.select!r}")
        if cfg.mar_policy == "buffer" and fedrac.cfg.aggregation != "buffered":
            raise ValueError(
                'mar_policy "buffer" needs FLConfig(aggregation="buffered")')
        if cfg.mode not in ("sync", "async"):
            raise ValueError(f"unknown mode {cfg.mode!r}")
        if cfg.mode == "async" and cfg.schedule == "sequential":
            # Eq. 10 serializes master → slaves inside every round — a
            # global order that contradicts independent cluster clocks
            raise ValueError('mode "async" requires schedule "parallel"')
        self.fl = fedrac
        self.trace = trace
        self.cfg = cfg
        self.obs = obs if obs is not None else NULL_OBS
        if obs is not None and getattr(fedrac, "obs", NULL_OBS) is NULL_OBS:
            fedrac.obs = obs     # share one registry/tracer across the stack
        self.clock = SimClock()
        self.queue = EventQueue()
        for t, ev in trace.events:
            self.queue.push(t, ev)
        self.online = {p.pid for p in fedrac.parts} - set(trace.initially_offline)
        self._spikes: dict[int, tuple[float, int]] = {}  # pid -> (factor, token)
        self._spike_seq = 0
        self._rejoin_token: dict[int, int] = {}          # pid -> departure gen
        self._gone: set[int] = set()                     # permanent dropouts
        # buffered async aggregation: level -> [{pid, params, n_eff, round}]
        self._bank: dict[int, list] = {lvl: [] for lvl in range(fedrac.m)}
        self.checkpoint = checkpoint
        self.faults = faults if faults is not None else NULL_FAULTS
        self.report: SimReport | None = None
        self._pending_state = None   # newest boundary snapshot (shutdown)

    # ------------------------------------------------------------ events
    def _apply_events(self, r: int) -> list[str]:
        """Fire every due event (sync engines; async barrier sweeps)."""
        # Arrivals first at equal timestamps: a scheduled rejoin and a fresh
        # trace Departure landing on the same round must net to "rejoined,
        # then dropped again" — otherwise the Departure (popped first, pid
        # still offline) would be silently discarded and churn understated.
        # The (time, priority, seq) heap key encodes exactly this order.
        return self._apply_event_list(self.queue.pop_due(float(r)))

    def _apply_events_for(self, lvl: int, r: int) -> list[str]:
        """Async per-cluster event visibility: fire only the due events whose
        participant currently belongs to cluster ``lvl`` (each cluster
        observes device state at ITS dispatch boundaries; a migration
        lands at the owning cluster's dispatch and becomes visible to the
        target cluster at its own next dispatch).  Non-matching entries
        keep their heap position, so the global total order is preserved."""
        owner = {pid: l for l, ms in self.fl.assignment.members.items()
                 for pid in ms}
        due = self.queue.pop_due_where(
            float(r), lambda ev: owner.get(ev.pid) == lvl)
        return self._apply_event_list(due)

    def _apply_event_list(self, due: list) -> list[str]:
        applied = []
        for t, ev in due:
            if isinstance(ev, Departure):
                # applies even while transiently offline: a fresh Departure
                # supersedes any pending rejoin (bumping the token below
                # invalidates it), so permanent dropouts landing inside a
                # rejoin window are not lost.  Later trace noise for a
                # permanently-departed pid is ignored — only an explicit
                # trace-authored Arrival re-registers the device.
                if ev.pid in self._gone:
                    continue
                if ev.rejoin_after is None:
                    self._gone.add(ev.pid)
                self.online.discard(ev.pid)
                tok = self._rejoin_token.get(ev.pid, 0) + 1
                self._rejoin_token[ev.pid] = tok
                if ev.rejoin_after is not None:
                    self.queue.push(t + ev.rejoin_after,
                                    Arrival(ev.pid, token=tok))
                applied.append(
                    f"drop(p{ev.pid}"
                    + ("" if ev.rejoin_after is not None else ", perm")
                    + ")")
            elif isinstance(ev, Arrival):
                stale = (ev.token is not None
                         and ev.token != self._rejoin_token.get(ev.pid, 0))
                if not stale and ev.pid not in self.online:
                    self._gone.discard(ev.pid)   # trace arrival re-registers
                    self.online.add(ev.pid)
                    applied.append(f"join(p{ev.pid})")
            elif isinstance(ev, StragglerSpike):
                self._spike_seq += 1
                self._spikes[ev.pid] = (ev.factor, self._spike_seq)
                self.queue.push(t + ev.duration,
                                SpikeEnd(ev.pid, token=self._spike_seq))
                applied.append(f"spike(p{ev.pid} ×{ev.factor:.1f})")
            elif isinstance(ev, SpikeEnd):
                if self._spikes.get(ev.pid, (0.0, -1))[1] == ev.token:
                    del self._spikes[ev.pid]
            elif isinstance(ev, ResourceDrift):
                p = self.fl.parts[ev.pid]
                old, new = self.fl.update_resources(
                    ev.pid,
                    s=max(self.cfg.min_speed, p.s * ev.s_mult),
                    r=max(self.cfg.min_rate, p.r * ev.r_mult),
                    a=max(self.cfg.min_mem, p.a * ev.a_mult))
                tag = (f"C{old + 1}→C{new + 1}" if old != new
                       else f"C{new + 1}")
                applied.append(f"drift(p{ev.pid} {tag})")
            else:
                raise TypeError(f"unhandled event {ev!r}")
        return applied

    # ------------------------------------------------------------ pricing
    def _price_round(self, level: int, members: list[int]):
        """Per-member Eq. 2 round time under current slowdowns."""
        spec = self.fl.specs[level]
        times = {}
        for pid in members:
            p = self.fl.parts[pid]
            times[pid] = cost_model.round_time(
                p, spec.flops_per_sample, spec.model_bytes, spec.E,
                n_i=self.fl.assignment.n_eff.get(pid, p.n_data),
                compute_slowdown=self._spikes.get(pid, (1.0, 0))[0])
        return spec, times

    def _fedcs_select(self, spec, members: list[int], times: dict) -> set:
        """FedCS-style deadline-aware client selection (Nishio & Yonetani,
        arXiv:1804.08333), adapted to the Eq. 2 cost model: training runs in
        parallel across the selected set while uploads are sequential, so
        the estimated cluster round time is Θ(S) = max_i T_train + Σ_i
        T_comm.  Admission is the longest prefix in ascending round-time
        order with Θ ≤ MAR (Θ grows monotonically along the prefix —
        exactly the sort/cumsum form the vectorized fleet engine uses),
        capped at ``select_budget``.  Every admitted member individually
        satisfies T_i ≤ Θ ≤ MAR, so a FedCS round never sees MAR
        violations among the selected."""
        cand = [pid for pid in members if pid in self.online]
        if not cand:
            return set()
        t_comm = np.array([cost_model.comm_time(self.fl.parts[pid],
                                                spec.model_bytes)
                           for pid in cand])
        t_total = np.array([times[pid] for pid in cand])
        order = np.lexsort((np.asarray(cand), t_total))
        theta = (np.maximum.accumulate((t_total - t_comm)[order])
                 + np.cumsum(t_comm[order]))
        take = int(np.searchsorted(theta, spec.mar, side="right"))
        if self.cfg.select_budget:
            take = min(take, self.cfg.select_budget)
        return {cand[i] for i in order[:take]}

    def _mar_decisions(self, level: int, members: list[int]):
        """Returns (stats, step_masks, weights, cluster_time)."""
        cfg, fl = self.cfg, self.fl
        S = fl.cfg.steps_per_round
        spec, times = self._price_round(level, members)
        stats = ClusterRoundStats(level=level, time=0.0)
        masks = np.zeros((len(members), S), np.float32)
        weights = np.zeros(len(members), np.float32)
        selected = (self._fedcs_select(spec, members, times)
                    if cfg.select == "fedcs" else None)
        contrib_times = []
        for i, pid in enumerate(members):
            if pid not in self.online:
                stats.offline.append(pid)
                continue
            if selected is not None and pid not in selected:
                # not admitted this round: selection precedes distribution,
                # so no bytes move and no MAR policy applies
                stats.unselected.append(pid)
                continue
            n_eff = fl.assignment.n_eff.get(pid, 1)
            t = times[pid]
            if t > spec.mar:
                stats.violations.append(pid)
                if cfg.mar_policy == "drop":
                    stats.dropped.append(pid)
                    stats.bytes += cost_model.round_bytes(
                        spec.model_bytes, upload=False)
                    continue
                if cfg.mar_policy == "buffer":
                    # full local work, zero sync weight: the update is banked
                    # after the round and joins the next aggregate discounted.
                    # The upload completes late, off this round's critical
                    # path, so it does not bound the cluster time.
                    masks[i] = 1.0
                    stats.banked.append(pid)
                    stats.bytes += cost_model.round_bytes(spec.model_bytes)
                    continue
                if cfg.mar_policy == "mask":
                    # only the train part scales with steps; comm is fixed,
                    # so grant ⌊S·(MAR − T_c)/T_a⌋ steps (0 if comm alone
                    # blows the deadline → download-only drop)
                    t_comm = cost_model.comm_time(fl.parts[pid],
                                                  spec.model_bytes)
                    t_train = t - t_comm
                    granted = (int(S * (spec.mar - t_comm) / t_train)
                               if spec.mar > t_comm and t_train > 0 else 0)
                    if granted == 0:
                        stats.dropped.append(pid)
                        stats.bytes += cost_model.round_bytes(
                            spec.model_bytes, upload=False)
                        continue
                    masks[i, :granted] = 1.0
                    weights[i] = n_eff * granted / S
                    stats.masked[pid] = granted
                    stats.active.append(pid)
                    stats.bytes += cost_model.round_bytes(spec.model_bytes)
                    contrib_times.append(t_train * granted / S + t_comm)
                    continue
                # wait: tolerated, falls through to a full contribution
            masks[i] = 1.0
            weights[i] = n_eff
            stats.active.append(pid)
            stats.bytes += cost_model.round_bytes(spec.model_bytes)
            contrib_times.append(t)
        stats.time = max(contrib_times, default=0.0)
        return stats, masks, weights, stats.time

    # ------------------------------------------------------------ round loop
    def run(self, test) -> SimReport:
        if self.cfg.mode == "async":
            return self._run_async(test)
        if self.fl.cfg.rounds_per_dispatch > 1:
            return self._run_dispatch(test)
        fl, cfg, tr = self.fl, self.cfg, self.obs.tracer
        report = SimReport(scenario=self.trace.name,
                           mar_policy=cfg.mar_policy, schedule=cfg.schedule,
                           obs=self.obs if self.obs.on else None)
        self.report = report
        with tr.span("sim.run", cat="engine", mode="legacy",
                     rounds=cfg.rounds):
            with tr.span("init_params", cat="engine"):
                resumed = self._maybe_resume(report, plane_mode=False)
                if resumed is None:
                    r0 = 0
                    params = {lvl: fl.family.init(
                        jax.random.PRNGKey(fl.cfg.seed + lvl), lvl)
                        for lvl in range(fl.m)}
                else:
                    r0, params = resumed
                tr.fence(params)
            for r in range(r0, cfg.rounds):
                with tr.span("round", cat="engine", round=r):
                    self._legacy_round(r, params, report, test)
                self._round_boundary(r + 1, params, report, plane_mode=False)
            with tr.span("terminal_flush", cat="engine"):
                self._terminal_flush(params, cfg.rounds, report)
            with tr.span("final_eval", cat="engine"):
                for lvl in range(fl.m):
                    if not fl.assignment.members.get(lvl):
                        continue
                    last = (report.rows[-1].clusters[lvl].acc
                            if report.rows else None)
                    report.final_acc[lvl] = (
                        last if last is not None
                        else fl.evaluate(lvl, params[lvl], test))
        self.params = params
        return report

    def _legacy_round(self, r: int, params: dict, report: SimReport,
                      test) -> None:
        """One legacy (per-round jit) communication round: MAR decisions,
        per-cluster vmap update, bank bookkeeping, record append."""
        fl, cfg, tr = self.fl, self.cfg, self.obs.tracer
        ev_log = self._apply_events(r)
        master_before = params[0]
        clusters, times = [], []
        for lvl in range(fl.m):
                members = list(fl.assignment.members.get(lvl, []))
                if not members:
                    clusters.append(ClusterRoundStats(level=lvl, time=0.0))
                    times.append(0.0)
                    continue
                stats, masks, weights, t_cluster = self._mar_decisions(
                    lvl, members)
                ripe = [b for b in self._bank[lvl] if b["round"] < r]
                live = float(weights.sum()) > 0.0
                if live or stats.banked or ripe:
                    teacher = None
                    if lvl > 0:
                        teacher = (master_before if cfg.schedule == "parallel"
                                   else params[0])
                    buffered = None
                    if ripe:
                        self._bank[lvl] = [b for b in self._bank[lvl]
                                           if b["round"] >= r]
                        stats.flushed = len(ripe)
                        if live:
                            us = aggregation.staleness_weights(
                                [b["n_eff"] for b in ripe],
                                [r - b["round"] for b in ripe],
                                fl.cfg.staleness_discount)
                            buffered = [(b["params"], u)
                                        for b, u in zip(ripe, us)]
                        else:
                            # no live contributor to anchor the convex
                            # combination inside cluster_round — anchor the
                            # current aggregate at the cluster's live weight,
                            # exactly as the terminal flush does
                            params[lvl] = self._anchored_merge(
                                params[lvl], ripe, r, lvl)
                    if live or stats.banked:
                        # buffered mode always requests the stack so one
                        # jitted program serves rounds with and without
                        # violators
                        want_stack = fl.cfg.aggregation == "buffered"
                        with tr.span("cluster_round", cat="engine",
                                     level=lvl, round=r):
                            out = fl.cluster_round(
                                lvl, members, params[lvl], r, teacher=teacher,
                                step_masks=masks, weights=weights,
                                buffered=buffered, return_stack=want_stack)
                            tr.fence(out[0])
                        params[lvl], losses = out[0], out[1]
                        if stats.banked:
                            stack = out[2]
                        for pid in stats.banked:
                            i = members.index(pid)
                            self._bank[lvl].append({
                                "pid": pid, "round": r,
                                "n_eff": fl.assignment.n_eff.get(pid, 1),
                                "params": jax.tree.map(lambda x: x[i], stack)})
                    contributing = weights > 0
                    if contributing.any():
                        stats.mean_loss = float(
                            np.mean(np.asarray(losses)[contributing]))
                if cfg.eval_every and (r + 1) % cfg.eval_every == 0:
                    stats.acc = fl.evaluate(lvl, params[lvl], test)
                clusters.append(stats)
                times.append(t_cluster)
        duration = (max(times, default=0.0) if cfg.schedule == "parallel"
                    else sum(times))
        report.add(RoundRecord(round=r, t_start=self.clock.now,
                               duration=duration, clusters=clusters,
                               events=ev_log))
        self.clock.advance(duration)

    # ------------------------------------------------------------ dispatch
    def _block_len(self, r: int) -> int:
        """Longest fused block starting at round r: capped by the dispatch
        width, the horizon, the next pending event (device/cluster state
        must be frozen across a block), and the next eval boundary
        (evaluation happens at block ends)."""
        cfg, fl = self.cfg, self.fl
        L = min(fl.cfg.rounds_per_dispatch, cfg.rounds - r)
        nt = self.queue.next_time()
        if nt is not None:
            L = min(L, max(1, math.ceil(nt) - r))
        if cfg.eval_every:
            e = cfg.eval_every
            L = min(L, (e - ((r + 1) % e)) % e + 1)
        return max(1, L)

    def _run_dispatch(self, test) -> SimReport:
        """Device-resident block mode (``FLConfig(rounds_per_dispatch>1)``):
        between events, up to R communication rounds per cluster run as ONE
        scan-fused program over the flat parameter plane, with the buffered
        schedule's bank riding the scan carry.  MAR decisions are frozen
        while no event fires, so per-round telemetry within a block is equal
        by construction and per-round losses come back scan-stacked — the
        records are as exact as the legacy path's.  KD teachers refresh at
        ROUND granularity inside a block: the master block returns its
        per-round planes, and each slave block scans a per-round teacher
        stack at the schedule's cadence (``_teacher_planes``), so R=1 and
        R>1 are semantically interchangeable under both schedules."""
        fl, cfg, tr = self.fl, self.cfg, self.obs.tracer
        report = SimReport(scenario=self.trace.name,
                           mar_policy=cfg.mar_policy, schedule=cfg.schedule,
                           obs=self.obs if self.obs.on else None)
        self.report = report
        buffered = fl.cfg.aggregation == "buffered"
        # surface which member-forward the block programs compile: "tp"
        # (GSPMD-partitioned over the model axis), "gather" (2D mesh with
        # tp_forward off — transient plane all-gather + replicated forward),
        # or "replicated" (no model axis to shard over)
        fwd = ("tp" if fl._tp else
               "gather" if getattr(fl, "_mesh_m", 1) > 1 else "replicated")
        with tr.span("sim.run", cat="engine", mode="dispatch",
                     member_forward=fwd, rounds=cfg.rounds):
            with tr.span("init_params", cat="engine"):
                resumed = self._maybe_resume(report, plane_mode=True)
                if resumed is None:
                    r = 0
                    planes = {lvl: fl.plane_of(lvl, fl.family.init(
                        jax.random.PRNGKey(fl.cfg.seed + lvl), lvl))
                        for lvl in range(fl.m)}
                else:
                    r, planes = resumed
                tr.fence(planes)
            while r < cfg.rounds:
                with tr.span("round_block", cat="engine", round=r):
                    r = self._dispatch_block(r, planes, report, test,
                                             buffered)
                self._round_boundary(r, planes, report, plane_mode=True)
            with tr.span("terminal_flush", cat="engine"):
                self._terminal_flush(planes, cfg.rounds, report,
                                     merge=self._anchored_merge_plane)
            with tr.span("final_eval", cat="engine"):
                for lvl in range(fl.m):
                    if not fl.assignment.members.get(lvl):
                        continue
                    last = (report.rows[-1].clusters[lvl].acc
                            if report.rows else None)
                    report.final_acc[lvl] = (
                        last if last is not None
                        else fl.evaluate(lvl, fl.params_of(lvl, planes[lvl]),
                                         test))
                self.params = {lvl: fl.params_of(lvl, planes[lvl])
                               for lvl in range(fl.m)}
        return report

    def _dispatch_block(self, r: int, planes: dict, report: SimReport,
                        test, buffered: bool) -> int:
        """One fused block starting at round ``r``; returns the next round
        index (``r`` advanced by the realized block length)."""
        fl, cfg, tr = self.fl, self.cfg, self.obs.tracer
        with tr.span("mar_decisions", cat="engine", round=r):
            ev_log = self._apply_events(r)
            L = self._block_len(r)
            decisions = {}
            for lvl in range(fl.m):
                members = list(fl.assignment.members.get(lvl, []))
                if not members:
                    continue
                stats, masks, weights, t_cluster = self._mar_decisions(
                    lvl, members)
                ripe = [b for b in self._bank[lvl] if b["round"] < r]
                live = float(weights.sum()) > 0.0
                if not live and (ripe or stats.banked):
                    # anchored flush / bank-only edge round: keep it
                    # un-fused so the host-side anchor math applies
                    L = 1
                decisions[lvl] = (members, stats, masks, weights,
                                  t_cluster, ripe, live)
        kd = fl.m > 1 and fl.cfg.use_kd
        # pre-flush, pre-block master plane; copied because the master's
        # own dispatch DONATES planes[0] and the parallel-cadence teacher
        # stack still needs the block-start value afterwards (the
        # sequential cadence reads only post-round planes — no copy)
        master_start = (jnp.copy(planes[0])
                        if kd and cfg.schedule == "parallel" else None)
        master_hist = None                         # (L, D0) post-round
        rows = [[] for _ in range(L)]
        times = []
        for lvl in range(fl.m):
            if lvl not in decisions:
                for j in range(L):
                    rows[j].append(ClusterRoundStats(level=lvl, time=0.0))
                times.append(0.0)
                continue
            members, stats, masks, weights, t_cluster, ripe, live = \
                decisions[lvl]
            losses = None
            if live or stats.banked or ripe:
                if ripe:
                    self._bank[lvl] = [b for b in self._bank[lvl]
                                       if b["round"] >= r]
                    if not live:
                        with tr.span("bank_flush", cat="engine", level=lvl,
                                     entries=len(ripe)):
                            planes[lvl] = self._anchored_merge_plane(
                                planes[lvl], ripe, r, lvl)
                            tr.fence(planes[lvl])
                if live or stats.banked:
                    bank = (self._bank_carry(lvl, members,
                                             ripe if live else [],
                                             stats.banked, r)
                            if buffered else None)
                    kw = {}
                    if lvl == 0:
                        # per-round master planes feed the slaves'
                        # teacher stacks (only needed for fused blocks)
                        kw["want_history"] = kd and L > 1
                    elif kd:
                        with tr.span("kd_teacher", cat="engine",
                                     level=lvl):
                            kw["teacher_planes"] = self._teacher_planes(
                                L, master_start, master_hist, planes[0])
                    with tr.span("dispatch", cat="engine", level=lvl,
                                 round=r, block_len=L):
                        out = fl.dispatch_rounds(
                            lvl, members, planes[lvl], r, L,
                            step_masks=masks, weights=weights, bank=bank,
                            **kw)
                        tr.fence(out.plane)
                    planes[lvl] = out.plane
                    if lvl == 0 and kw.get("want_history"):
                        master_hist = out.history
                    losses = np.asarray(out.losses)
                    if stats.banked:
                        bank_rows = out.bank[0]
                        for pid in stats.banked:
                            i = members.index(pid)
                            self._bank[lvl].append({
                                "pid": pid, "round": r + L - 1,
                                "n_eff": fl.assignment.n_eff.get(pid, 1),
                                "plane": bank_rows[i]})
            contributing = weights > 0
            for j in range(L):
                s = self._clone_stats(stats)
                s.flushed = (len(ripe) if j == 0
                             else len(stats.banked) if live else 0)
                if losses is not None and contributing.any():
                    s.mean_loss = float(np.mean(losses[j][contributing]))
                rows[j].append(s)
            if (cfg.eval_every and (r + L) % cfg.eval_every == 0):
                with tr.span("eval", cat="engine", level=lvl):
                    rows[L - 1][-1].acc = fl.evaluate(
                        lvl, fl.params_of(lvl, planes[lvl]), test)
            times.append(t_cluster)
        # fault-injection point: the fused programs ran, nothing recorded —
        # a SIGKILL here loses the whole in-flight block and resume must
        # recompute it bit-identically from the last boundary checkpoint
        self.faults.mid_block(r, r + L)
        with tr.span("record_rounds", cat="engine", round=r, block_len=L):
            duration = (max(times, default=0.0)
                        if cfg.schedule == "parallel" else sum(times))
            for j in range(L):
                report.add(RoundRecord(round=r + j, t_start=self.clock.now,
                                       duration=duration, clusters=rows[j],
                                       events=ev_log if j == 0 else []))
                self.clock.advance(duration)
        return r + L

    def _teacher_planes(self, L: int, start, hist, cur):
        """Per-round KD teacher planes for a slave block, at the schedule's
        cadence.  Parallel (Eq. 9): the teacher for round r+j is the master
        BEFORE that round — the block-start plane, then the master's
        post-round planes shifted by one.  Sequential (Eq. 10): the teacher
        is the master AFTER round r+j (the legacy engine reads ``params[0]``
        once the master's round has run).  When the master ran no fused
        block (empty or flush-only master round — the engine forces L=1
        there — or a length-1 block), ``hist`` is None and the teacher
        degrades to the single appropriate plane, which IS the legacy
        per-round behaviour."""
        if hist is not None:
            if self.cfg.schedule == "parallel":
                return self.fl.place_plane_stack(
                    jnp.concatenate([start[None], hist[:-1]]))
            return hist
        t = start if self.cfg.schedule == "parallel" else cur
        return self.fl.place_plane_stack(jnp.broadcast_to(t, (L,) + t.shape))

    @staticmethod
    def _clone_stats(s: ClusterRoundStats) -> ClusterRoundStats:
        """Fresh per-round copy of a block's frozen MAR decision stats."""
        return replace(s, active=list(s.active), dropped=list(s.dropped),
                       offline=list(s.offline), masked=dict(s.masked),
                       violations=list(s.violations), banked=list(s.banked),
                       unselected=list(s.unselected),
                       flushed=0, mean_loss=float("nan"), acc=None)

    def _bank_carry(self, lvl: int, members: list[int], ripe: list,
                    banked_pids: list, r: int):
        """Build the scan-carry bank for one block: entering rows = the ripe
        host entries at their staleness-discounted weights; ``bank_gain`` =
        the weight each round's re-banked violator rows carry into the NEXT
        round's aggregate (n_eff · discount, age 1 inside a block)."""
        fl = self.fl
        cap = fl._capacity(len(members))
        dp = fl.plane_spec(lvl).d_pad
        us = aggregation.version_staleness_weights(
            [b["n_eff"] for b in ripe], [b["round"] for b in ripe], r,
            fl.cfg.staleness_discount)
        # membership may have shrunk below the banked backlog (event between
        # blocks): Σu-preserving compression fits it into the carry slots
        rows, us = aggregation.compress_bank_rows(
            [b["plane"] for b in ripe], us, cap, obs=self.obs)
        bank_plane = jnp.zeros((cap, dp), jnp.float32)
        bank_w = np.zeros(cap, np.float32)
        if rows:
            bank_plane = jnp.concatenate(
                [jnp.stack(rows),
                 jnp.zeros((cap - len(rows), dp), jnp.float32)])
            bank_w[:len(rows)] = us
        bank_gain = np.zeros(cap, np.float32)
        for pid in banked_pids:
            bank_gain[members.index(pid)] = (
                fl.assignment.n_eff.get(pid, 1) * fl.cfg.staleness_discount)
        return (fl.place_member_plane(bank_plane),
                fl.place_member_sharded(jnp.asarray(bank_w)),
                fl.place_member_sharded(jnp.asarray(bank_gain)))

    def _anchor_weights(self, entries: list, r: int, lvl: int):
        """Shared anchor math for flushes with no live contributors: the
        cluster's full live n_eff weight W anchors the convex combination,
        so discounted stale updates nudge — never replace — the model.
        Staleness is the server-version lag (== round age in sync mode);
        ``anchored_merge_weights`` carries the zero-total contract, so an
        emptied cluster flushing deeply-stale (underflowed) entries gets a
        zero delta rather than a NaN plane.
        Returns (anchor weight, normalized per-entry weights)."""
        fl = self.fl
        W = float(sum(fl.assignment.n_eff.get(pid, 1)
                      for pid in fl.assignment.members.get(lvl, [])))
        us = aggregation.version_staleness_weights(
            [b["n_eff"] for b in entries], [b["round"] for b in entries],
            r, fl.cfg.staleness_discount)
        return aggregation.anchored_merge_weights(W, us)

    def _anchored_merge(self, cur, entries: list, r: int, lvl: int):
        """Anchored flush over pytree params (legacy engine)."""
        wa, us = self._anchor_weights(entries, r, lvl)
        anchored = jax.tree.map(lambda x: wa * x, cur)
        return aggregation.merge_buffered(
            anchored, [b["params"] for b in entries], us, obs=self.obs)

    def _anchored_merge_plane(self, cur, entries: list, r: int, lvl: int):
        """Anchored flush over the flat parameter plane (dispatch engine).
        The result is re-committed to the plane's mesh sharding so the next
        dispatch block sees the one input signature it compiled for."""
        wa, us = self._anchor_weights(entries, r, lvl)
        return self.fl.place_plane(
            wa * cur + aggregation.aggregate_plane(
                jnp.stack([b["plane"] for b in entries]),
                jnp.asarray(us, jnp.float32)))

    # ------------------------------------------------------------ async
    def _run_async(self, test) -> SimReport:
        """Continuous-time asynchronous parameter server (ROADMAP item 3):
        every cluster runs on its own clock.  A dispatch pulls the cluster's
        current server state+version, runs its block eagerly, and registers
        a completion on a deterministic (time, priority, seq) queue; popping
        a completion COMMITS the block — a merge event: the server version
        advances by the block length, ledger staleness re-prices in server
        versions, the conservation invariant re-checks, and the cluster may
        dispatch again subject to ``max_staleness`` (committed-round lead
        over the slowest unfinished cluster; 0 degenerates to barrier
        sweeps that reproduce the sync buffered path bit-for-bit).
        Checkpoints and fault hooks re-anchor on merge events."""
        fl, cfg, tr = self.fl, self.cfg, self.obs.tracer
        plane = self._async_plane = fl.cfg.rounds_per_dispatch > 1
        report = SimReport(scenario=self.trace.name,
                           mar_policy=cfg.mar_policy, schedule=cfg.schedule,
                           obs=self.obs if self.obs.on else None)
        self.report = report
        self._aclk = {lvl: ClusterClock() for lvl in range(fl.m)}
        self._servers: dict[int, AsyncPlaneServer] = {}
        self._pending_blocks: dict[int, dict] = {}
        self._done_q = EventQueue()
        self._row_buf: dict[int, dict] = {}
        self._ev_buf: dict[int, list] = {}
        self._emitted = 0
        self._merge_step = 0
        self._master_block = None
        with tr.span("sim.run", cat="engine", mode="async",
                     rounds=cfg.rounds):
            with tr.span("init_params", cat="engine"):
                if self._maybe_resume_async(report) is None:
                    for lvl in range(fl.m):
                        init = fl.family.init(
                            jax.random.PRNGKey(fl.cfg.seed + lvl), lvl)
                        state = fl.plane_of(lvl, init) if plane else init
                        self._servers[lvl] = AsyncPlaneServer(
                            lvl, state, ledger=self._bank[lvl])
                tr.fence({l: s.state for l, s in self._servers.items()})
            while True:
                with tr.span("async_schedule", cat="engine",
                             step=self._merge_step):
                    self._async_schedule(report, test)
                nxt = self._done_q.pop()
                if nxt is None:
                    break
                t_done, ev = nxt
                with tr.span("merge_event", cat="engine", level=ev.level,
                             step=self._merge_step):
                    self._async_commit(ev.level, t_done, report)
                    self._async_emit_rows(report)
                self._merge_step += 1
                self._async_boundary(report)
            if self._row_buf:
                raise RuntimeError(
                    "async round assembly incomplete: rounds "
                    f"{sorted(self._row_buf)} missing cluster contributions")
            states = {lvl: self._servers[lvl].state for lvl in range(fl.m)}
            with tr.span("terminal_flush", cat="engine"):
                self._terminal_flush(
                    states, cfg.rounds, report,
                    merge=self._anchored_merge_plane if plane else None)
                for lvl in range(fl.m):
                    self._servers[lvl].state = states[lvl]
            with tr.span("final_eval", cat="engine"):
                for lvl in range(fl.m):
                    if not fl.assignment.members.get(lvl):
                        continue
                    last = (report.rows[-1].clusters[lvl].acc
                            if report.rows else None)
                    report.final_acc[lvl] = (
                        last if last is not None
                        else fl.evaluate(lvl, self._async_params(lvl), test))
                self.params = {lvl: self._async_params(lvl)
                               for lvl in range(fl.m)}
            report.registry.gauge("async/wall_clock_s").set(
                max((c.now for c in self._aclk.values()), default=0.0))
        return report

    def _async_params(self, lvl: int):
        s = self._servers[lvl].state
        return self.fl.params_of(lvl, s) if self._async_plane else s

    def _async_schedule(self, report: SimReport, test) -> None:
        """Dispatch every ready cluster.  Ready = unfinished, nothing in
        flight, and within ``max_staleness`` committed rounds of the slowest
        unfinished cluster (the frontier cluster is never stalled, so
        progress is guaranteed).  ``max_staleness=0`` degenerates to barrier
        sweeps: all clusters dispatch together at the shared round with a
        shared block length — the sync buffered path's exact structure."""
        fl, cfg = self.fl, self.cfg
        unfinished = [l for l in range(fl.m)
                      if self._servers[l].version < cfg.rounds]
        if not unfinished:
            return
        frontier = min(self._servers[l].version for l in unfinished)
        ready = [l for l in unfinished
                 if l not in self._pending_blocks
                 and (cfg.max_staleness is None
                      or self._servers[l].version - frontier
                      <= cfg.max_staleness)]
        if not ready:
            return
        reg = report.registry
        for lvl in ready:
            reg.gauge(f"async/version_lag/{lvl}").set(
                float(self._servers[lvl].version - frontier))
        if cfg.max_staleness == 0:
            if len(ready) < len(unfinished):
                return                    # barrier: wait for in-flight
            self._async_sweep(ready, report, test)
        else:
            for lvl in ready:
                self._async_dispatch_one(lvl, report, test)

    def _async_sweep(self, levels: list, report: SimReport, test) -> None:
        """Barrier sweep (``max_staleness=0``): all clusters at the same
        round, one global event pop and a shared block length — including
        the anchored-flush L=1 force — exactly as ``_dispatch_block``."""
        fl = self.fl
        r = self._servers[levels[0]].version
        ev_log = self._apply_events(r)
        if ev_log:
            self._ev_buf.setdefault(r, []).extend(ev_log)
        L = self._block_len(r)
        decisions = {}
        for lvl in levels:
            members = list(fl.assignment.members.get(lvl, []))
            if not members:
                continue
            stats, masks, weights, t_cluster = self._mar_decisions(
                lvl, members)
            ripe = self._servers[lvl].ripe()
            live = float(weights.sum()) > 0.0
            if not live and (ripe or stats.banked):
                L = 1
            decisions[lvl] = (members, stats, masks, weights, t_cluster,
                              ripe, live)
        for lvl in levels:
            self._async_exec(lvl, r, L, decisions.get(lvl), report, test)

    def _async_dispatch_one(self, lvl: int, report: SimReport, test) -> None:
        """Independent-clock dispatch: the cluster pops only its own
        participants' due events, freezes MAR decisions, and runs its block
        at its own round cursor with a per-cluster block length."""
        fl = self.fl
        r = self._servers[lvl].version
        ev_log = self._apply_events_for(lvl, r)
        if ev_log:
            self._ev_buf.setdefault(r, []).extend(ev_log)
        members = list(fl.assignment.members.get(lvl, []))
        L = self._block_len(r)
        dec = None
        if members:
            stats, masks, weights, t_cluster = self._mar_decisions(
                lvl, members)
            ripe = self._servers[lvl].ripe()
            live = float(weights.sum()) > 0.0
            if not live and (ripe or stats.banked):
                L = 1
            dec = (members, stats, masks, weights, t_cluster, ripe, live)
        self._async_exec(lvl, r, L, dec, report, test)

    def _async_exec(self, lvl: int, r: int, L: int, dec, report: SimReport,
                    test) -> None:
        """Eagerly run one cluster block [r, r+L): ripe-ledger flush, bank
        carry, the fused dispatch (or legacy per-round program), per-round
        row cloning and block-end eval — then register the pending commit
        at the cluster's own completion time on the completion queue."""
        fl, cfg, tr = self.fl, self.cfg, self.obs.tracer
        server = self._servers[lvl]
        buffered = fl.cfg.aggregation == "buffered"
        kd = fl.m > 1 and fl.cfg.use_kd
        mb_start = None
        if lvl == 0 and kd:
            # pre-flush, pre-block master state: the parallel-cadence KD
            # teacher anchor (copied in plane mode — the dispatch donates
            # its input buffer; legacy pytrees are rebuilt functionally)
            mb_start = (jnp.copy(server.state) if self._async_plane
                        else server.state)
        new_state, losses, hist, t_cluster = None, None, None, 0.0
        if dec is not None:
            members, stats, masks, weights, t_cluster, ripe, live = dec
            if live or stats.banked or ripe:
                state = server.state
                if ripe:
                    h = report.registry.histogram("async/staleness")
                    for b in ripe:
                        h.observe(float(server.lag_of(b)))
                    server.drop_ripe()
                if self._async_plane:
                    if ripe and not live:
                        with tr.span("bank_flush", cat="engine", level=lvl,
                                     entries=len(ripe)):
                            state = self._anchored_merge_plane(
                                state, ripe, r, lvl)
                            tr.fence(state)
                        new_state = state
                    if live or stats.banked:
                        bank = (self._bank_carry(lvl, members,
                                                 ripe if live else [],
                                                 stats.banked, r)
                                if buffered else None)
                        kw = {}
                        if lvl == 0:
                            kw["want_history"] = kd and L > 1
                        elif kd:
                            with tr.span("kd_teacher", cat="engine",
                                         level=lvl):
                                kw["teacher_planes"] = self._async_teacher(
                                    r, L)
                        with tr.span("dispatch", cat="engine", level=lvl,
                                     round=r, block_len=L):
                            # the input plane is donated; the server keeps
                            # its committed state until the commit event,
                            # so hand the program a copy
                            out = fl.dispatch_rounds(
                                lvl, members, jnp.copy(state), r, L,
                                step_masks=masks, weights=weights,
                                bank=bank, **kw)
                            tr.fence(out.plane)
                        new_state = out.plane
                        if lvl == 0 and kw.get("want_history"):
                            hist = out.history
                        losses = np.asarray(out.losses)
                        if stats.banked:
                            bank_rows = out.bank[0]
                            for pid in stats.banked:
                                i = members.index(pid)
                                server.ledger.append({
                                    "pid": pid, "round": r + L - 1,
                                    "n_eff": fl.assignment.n_eff.get(pid, 1),
                                    "plane": bank_rows[i]})
                else:
                    teacher = (self._async_teacher_legacy(r)
                               if kd and lvl > 0 else None)
                    contribs = None
                    if ripe and live:
                        us = aggregation.version_staleness_weights(
                            [b["n_eff"] for b in ripe],
                            [b["round"] for b in ripe], r,
                            fl.cfg.staleness_discount)
                        contribs = [(b["params"], u)
                                    for b, u in zip(ripe, us)]
                    elif ripe:
                        state = self._anchored_merge(state, ripe, r, lvl)
                        new_state = state
                    if live or stats.banked:
                        with tr.span("cluster_round", cat="engine",
                                     level=lvl, round=r):
                            out = fl.cluster_round(
                                lvl, members, state, r, teacher=teacher,
                                step_masks=masks, weights=weights,
                                buffered=contribs, return_stack=buffered)
                            tr.fence(out[0])
                        new_state = out[0]
                        losses = np.asarray(out[1])[None]
                        if stats.banked:
                            stack = out[2]
                            for pid in stats.banked:
                                i = members.index(pid)
                                server.ledger.append({
                                    "pid": pid, "round": r,
                                    "n_eff": fl.assignment.n_eff.get(pid, 1),
                                    "params": jax.tree.map(
                                        lambda x, i=i: x[i], stack)})
        if lvl == 0 and kd:
            self._master_block = MasterBlock(r, L, mb_start, hist)
        if dec is None:
            rows = [ClusterRoundStats(level=lvl, time=0.0)
                    for _ in range(L)]
        else:
            contributing = weights > 0
            rows = []
            for j in range(L):
                s = self._clone_stats(stats)
                s.flushed = (len(ripe) if j == 0
                             else len(stats.banked) if live else 0)
                if losses is not None and contributing.any():
                    s.mean_loss = float(np.mean(losses[j][contributing]))
                rows.append(s)
            if cfg.eval_every and (r + L) % cfg.eval_every == 0:
                state_now = new_state if new_state is not None else \
                    server.state
                with tr.span("eval", cat="engine", level=lvl):
                    rows[-1].acc = fl.evaluate(
                        lvl,
                        fl.params_of(lvl, state_now) if self._async_plane
                        else state_now, test)
        self.faults.mid_block(r, r + L)
        clk = self._aclk[lvl]
        self._pending_blocks[lvl] = {
            "r0": r, "L": L, "rows": rows, "t_round": float(t_cluster),
            "state": new_state,
            "members_n": len(members) if dec is not None else 0}
        self._done_q.push(clk.now + L * float(t_cluster),
                          ClusterDone(-1, level=lvl))

    def _async_teacher(self, r: int, L: int):
        """Per-round KD teacher stack for a slave block in async mode:
        round-aligned with the master's latest block → the exact
        parallel-cadence stack the sync schedule uses; misaligned (clusters
        drifted apart under unbounded staleness) → the master's latest
        committed plane broadcast — a stale teacher, the KD analogue of a
        stale gradient."""
        mb = self._master_block
        if mb is not None and mb.r0 == r and mb.length == L:
            return self._teacher_planes(L, mb.start, mb.hist,
                                        self._servers[0].state)
        t = self._servers[0].state
        return self.fl.place_plane_stack(
            jnp.broadcast_to(t, (L,) + t.shape))

    def _async_teacher_legacy(self, r: int):
        """Legacy-path teacher params: the master's pre-round state when
        round-aligned, else its latest committed state (stale teacher)."""
        mb = self._master_block
        if mb is not None and mb.r0 == r:
            return mb.start
        return self._servers[0].state

    def _async_commit(self, lvl: int, t_done: float,
                      report: SimReport) -> None:
        """Merge event: install the block's state at the server, advance
        version and cluster clock, verify conservation, and file the
        per-round rows into the global-round assembly buffer."""
        p = self._pending_blocks.pop(lvl)
        server = self._servers[lvl]
        server.commit(p["state"] if p["state"] is not None else server.state,
                      p["L"])
        clk = self._aclk[lvl]
        for j, s in enumerate(p["rows"]):
            self._check_conservation(s, p["members_n"], p["r0"] + j)
            self._row_buf.setdefault(p["r0"] + j, {})[lvl] = (
                s, clk.now + j * p["t_round"], p["t_round"])
        clk.advance(p["L"] * p["t_round"], rounds=p["L"])
        self.clock.now = max(self.clock.now, float(t_done))
        report.registry.counter("async/merges").inc()

    @staticmethod
    def _check_conservation(s: ClusterRoundStats, n: int, r: int) -> None:
        """Per-merge-event conservation invariant: every member at dispatch
        time lands in exactly one bucket (masked ⊂ active)."""
        got = (len(s.active) + len(s.dropped) + len(s.offline)
               + len(s.unselected) + len(s.banked))
        if got != n:
            raise RuntimeError(
                f"conservation violated at round {r} level {s.level}: "
                f"{got} bucketed of {n} members")

    def _async_emit_rows(self, report: SimReport) -> None:
        """Emit assembled ``RoundRecord``s in global round order once every
        cluster has contributed its row for that round.  t_start is the
        earliest per-cluster round start, duration the slowest cluster's
        per-round time — for a single cluster both collapse to the sync
        engine's values."""
        fl, cfg = self.fl, self.cfg
        while self._emitted < cfg.rounds:
            per = self._row_buf.get(self._emitted)
            if per is None or len(per) < fl.m:
                return
            del self._row_buf[self._emitted]
            t_start = min(t for _, t, _ in per.values())
            duration = max(d for _, _, d in per.values())
            report.add(RoundRecord(
                round=self._emitted, t_start=t_start, duration=duration,
                clusters=[per[lvl][0] for lvl in range(fl.m)],
                events=self._ev_buf.pop(self._emitted, [])))
            self._emitted += 1

    def _async_boundary(self, report: SimReport) -> None:
        """After each merge event: retain/write a checkpoint (step = the
        monotonic merge-event counter — async has no global round), then
        fire the boundary fault hook (``kill_at_round=k`` kills at the k-th
        merge event in async mode)."""
        step = self._merge_step
        if self.checkpoint is not None:
            meta, arrays = self._capture_state_async(report)
            self._pending_state = (step, meta, arrays)
            if self.checkpoint.due(step):
                self.checkpoint.save(step, self.KIND, meta, arrays)
        self.faults.round_boundary(step)

    def _capture_state_async(self, report: SimReport) -> tuple[dict, dict]:
        """Async snapshot = the sync capture at the frontier round (committed
        server states, ledger, participant/trace state, rows, metrics) plus
        the async section: per-cluster clocks, server version/merge
        counters, the completion queue, pending (in-flight) block outputs
        and the partial round-assembly buffers."""
        fl = self.fl
        plane = self._async_plane
        unfinished = [l for l in range(fl.m)
                      if self._servers[l].version < self.cfg.rounds]
        frontier = (min(self._servers[l].version for l in unfinished)
                    if unfinished else self.cfg.rounds)
        states = {lvl: self._servers[lvl].state for lvl in range(fl.m)}
        meta, arrays = self._capture_state(frontier, states, report, plane)
        meta["mode"] = "async"
        a = {
            "step": int(self._merge_step),
            "emitted": int(self._emitted),
            "plane_mode": bool(plane),
            "clocks": [[int(lvl), float(c.now), int(c.round)]
                       for lvl, c in sorted(self._aclk.items())],
            "servers": [[int(lvl), int(s.version), int(s.merges)]
                        for lvl, s in sorted(self._servers.items())],
            "done_q": self._done_q.encode(),
            "ev_buf": [[int(r), [str(e) for e in evs]]
                       for r, evs in sorted(self._ev_buf.items())],
            "row_buf": [[int(r),
                         [[int(lvl), encode_stats(s), float(t), float(d)]
                          for lvl, (s, t, d) in sorted(per.items())]]
                        for r, per in sorted(self._row_buf.items())],
            "pending": {str(lvl): {
                "r0": int(p["r0"]), "L": int(p["L"]),
                "t_round": float(p["t_round"]),
                "members_n": int(p["members_n"]),
                "has_state": p["state"] is not None,
                "rows": [encode_stats(s) for s in p["rows"]],
            } for lvl, p in sorted(self._pending_blocks.items())},
            "master_block": None,
        }
        for lvl, p in self._pending_blocks.items():
            if p["state"] is not None:
                row = p["state"] if plane else fl.plane_of(lvl, p["state"])
                arrays[f"async/pending/{lvl}/state"] = np.asarray(
                    row, np.float32)
        mb = self._master_block
        if mb is not None:
            a["master_block"] = {"r0": int(mb.r0), "L": int(mb.length),
                                 "has_hist": mb.hist is not None}
            row = mb.start if plane else fl.plane_of(0, mb.start)
            arrays["async/mb/start"] = np.asarray(row, np.float32)
            if mb.hist is not None:
                arrays["async/mb/hist"] = np.asarray(mb.hist, np.float32)
        meta["async"] = a
        return meta, arrays

    def _maybe_resume_async(self, report: SimReport):
        """Restore the full async state (servers, clocks, pending blocks,
        completion queue, assembly buffers) from the newest valid
        checkpoint; returns None to start fresh."""
        ck = self.checkpoint
        if ck is None or not ck.resume:
            return None
        got = ck.load_latest(self.KIND)
        if got is None:
            log.warning("resume requested but no valid checkpoint under "
                        "%s; starting from scratch", ck.manager.dir)
            return None
        step, meta, arrays = got
        return self._load_state_async(meta, arrays, report)

    def _load_state_async(self, meta: dict, arrays: dict,
                          report: SimReport) -> bool:
        fl = self.fl
        plane = self._async_plane
        a = meta.get("async")
        if a is not None and bool(a["plane_mode"]) != plane:
            raise CheckpointError(
                "async checkpoint was written with rounds_per_dispatch "
                f"{'> 1' if a['plane_mode'] else '== 1'}; the engine's "
                "pending-block representation does not translate")
        _, states = self._load_state(meta, arrays, report, plane,
                                     async_mode=True)
        for lvl in range(fl.m):
            self._servers[lvl] = AsyncPlaneServer(lvl, states[lvl],
                                                  ledger=self._bank[lvl])
        for lvl, ver, merges in a["servers"]:
            self._servers[int(lvl)].version = int(ver)
            self._servers[int(lvl)].merges = int(merges)
        self._aclk = {int(lvl): ClusterClock(float(now), int(rd))
                      for lvl, now, rd in a["clocks"]}
        self._done_q.load_encoded(a["done_q"])
        self._merge_step = int(a["step"])
        self._emitted = int(a["emitted"])
        self._ev_buf = {int(r): [str(e) for e in evs]
                        for r, evs in a["ev_buf"]}
        self._row_buf = {
            int(r): {int(lvl): (decode_stats(s), float(t), float(d))
                     for lvl, s, t, d in per}
            for r, per in a["row_buf"]}
        self._pending_blocks = {}
        for l_str, p in a["pending"].items():
            lvl = int(l_str)
            state = None
            if p["has_state"]:
                row = jnp.asarray(arrays[f"async/pending/{lvl}/state"])
                state = (fl.place_plane(row) if plane
                         else fl.params_of(lvl, row))
            self._pending_blocks[lvl] = {
                "r0": int(p["r0"]), "L": int(p["L"]),
                "t_round": float(p["t_round"]),
                "members_n": int(p["members_n"]), "state": state,
                "rows": [decode_stats(s) for s in p["rows"]]}
        mb = a.get("master_block")
        self._master_block = None
        if mb is not None:
            row = jnp.asarray(arrays["async/mb/start"])
            start = row if plane else fl.params_of(0, row)
            hist = (jnp.asarray(arrays["async/mb/hist"])
                    if mb["has_hist"] else None)
            self._master_block = MasterBlock(int(mb["r0"]), int(mb["L"]),
                                             start, hist)
        log.info("resumed async run at merge step %d from %s",
                 self._merge_step, self.checkpoint.manager.dir)
        return True

    # ------------------------------------------------------------ checkpoint
    def _round_boundary(self, r: int, params: dict, report: SimReport,
                        plane_mode: bool) -> None:
        """After ``r`` rounds completed: retain a host-side run-state
        snapshot (the graceful-shutdown payload), write it at the
        checkpointer's cadence, then fire the boundary fault hook."""
        if self.checkpoint is not None:
            meta, arrays = self._capture_state(r, params, report, plane_mode)
            self._pending_state = (r, meta, arrays)
            if self.checkpoint.due(r):
                self.checkpoint.save(r, self.KIND, meta, arrays)
        self.faults.round_boundary(r)

    def save_now(self):
        """Write the newest retained boundary snapshot immediately (the
        SIGTERM/SIGINT path).  Returns the step written, or None when no
        boundary was reached / checkpointing is off."""
        if self.checkpoint is None or self._pending_state is None:
            return None
        r, meta, arrays = self._pending_state
        self.checkpoint.save(r, self.KIND, meta, arrays)
        return r

    def _capture_state(self, r: int, params: dict, report: SimReport,
                       plane_mode: bool) -> tuple[dict, dict]:
        """Snapshot at the start of round ``r`` (events for round ``r`` not
        yet applied).  Model state is serialized uniformly as per-level
        (D_pad,) planes — exact for the fp32 families in both engines — so
        a checkpoint is mode-agnostic: a legacy run can resume a dispatch
        checkpoint and vice versa."""
        fl = self.fl
        asg = fl.assignment
        reg_meta, reg_arrays = report.registry.state()
        meta = {
            "mode": "dispatch" if plane_mode else "legacy",
            "round": int(r),
            "clock": float(self.clock.now),
            "sampler": {
                "seed": int(fl.cfg.seed), "round": int(r),
                "fingerprint": device_sampler.stream_fingerprint(
                    int(fl.cfg.seed), int(r))},
            "online": sorted(int(p) for p in self.online),
            "gone": sorted(int(p) for p in self._gone),
            "spikes": [[int(p), float(f), int(tok)]
                       for p, (f, tok) in sorted(self._spikes.items())],
            "spike_seq": int(self._spike_seq),
            "rejoin_token": [[int(p), int(t)]
                             for p, t in sorted(self._rejoin_token.items())],
            "queue": self.queue.encode(),
            "assignment": {
                "members": {str(l): [int(p) for p in v]
                            for l, v in asg.members.items()},
                "n_eff": [[int(p), int(v)]
                          for p, v in sorted(asg.n_eff.items())],
                "tau": [[int(p), int(v)]
                        for p, v in sorted(asg.tau.items())],
                "demotions": int(asg.demotions),
                "diagnostics": [[int(p), int(l), str(why)]
                                for p, l, why in asg.diagnostics],
            },
            "bank": {str(l): [{"pid": int(b["pid"]), "round": int(b["round"]),
                               "n_eff": int(b["n_eff"])} for b in entries]
                     for l, entries in self._bank.items()},
            "rows": encode_rows(report.rows),
            "final_acc": [[int(l), float(a)]
                          for l, a in sorted(report.final_acc.items())],
            "obs": reg_meta,
        }
        arrays = {}
        for lvl in range(fl.m):
            plane = (params[lvl] if plane_mode
                     else fl.plane_of(lvl, params[lvl]))
            arrays[f"plane/{lvl}"] = np.asarray(plane, np.float32)
        for lvl, entries in self._bank.items():
            for i, b in enumerate(entries):
                row = (b["plane"] if plane_mode
                       else fl.plane_of(lvl, b["params"]))
                arrays[f"bank/{lvl}/{i}"] = np.asarray(row, np.float32)
        arrays["parts/V"] = np.array([[p.s, p.r, p.a] for p in fl.parts],
                                     np.float64)
        arrays["parts/n_data"] = np.array([p.n_data for p in fl.parts],
                                          np.int64)
        for k, v in reg_arrays.items():
            arrays[f"obs/{k}"] = v
        return meta, arrays

    def _maybe_resume(self, report: SimReport, plane_mode: bool):
        """(r0, params-or-planes) from the newest valid checkpoint, or None
        to start from scratch (resume off, or no checkpoint validates —
        graceful degradation, never a crash)."""
        ck = self.checkpoint
        if ck is None or not ck.resume:
            return None
        got = ck.load_latest(self.KIND)
        if got is None:
            log.warning("resume requested but no valid checkpoint under "
                        "%s; starting from round 0", ck.manager.dir)
            return None
        step, meta, arrays = got
        return self._load_state(meta, arrays, report, plane_mode)

    def _load_state(self, meta: dict, arrays: dict, report: SimReport,
                    plane_mode: bool, async_mode: bool = False):
        """Overlay a captured run state onto this (freshly constructed)
        engine.  The engine/FedRAC must have been built from the same seed
        and config — everything ``setup()`` derives deterministically
        (data, clustering, specs) is rebuilt, only the mutated state is
        restored.  Returns (r0, params-or-planes)."""
        if bool(meta.get("async")) != bool(async_mode):
            # sync engines cannot honour pending async blocks (they would be
            # silently dropped) and async engines cannot synthesize
            # per-cluster clocks from a global round cursor
            raise CheckpointError(
                "checkpoint mode mismatch: {}-mode checkpoint cannot "
                "resume a {}-mode run".format(
                    "async" if meta.get("async") else "sync",
                    "async" if async_mode else "sync"))
        fl = self.fl
        r0 = int(meta["round"])
        samp = meta["sampler"]
        if int(samp["seed"]) != int(fl.cfg.seed):
            raise CheckpointError(
                f"checkpoint sampler seed {samp['seed']} != configured "
                f"seed {fl.cfg.seed}")
        fp = device_sampler.stream_fingerprint(int(samp["seed"]),
                                               int(samp["round"]))
        if fp != int(samp["fingerprint"]):
            raise CheckpointError(
                "sampler stream fingerprint mismatch — the (absolute "
                "round, global slot) stream diverged since this checkpoint "
                "was written; resuming would not be bit-identical")
        # participant resources (drift events mutate them in place)
        V = arrays["parts/V"]
        nd = arrays["parts/n_data"]
        if len(V) != len(fl.parts):
            raise CheckpointError(
                f"checkpoint has {len(V)} participants, engine has "
                f"{len(fl.parts)}")
        if fl.fleet is not None:
            fl.fleet.V[:] = V
            fl.fleet.n_data[:] = nd
        else:
            for p, row, n in zip(fl.parts, V, nd):
                p.s, p.r, p.a = float(row[0]), float(row[1]), float(row[2])
                p.n_data = int(n)
        am = meta["assignment"]
        asg = fl.assignment
        asg.members = {int(l): [int(p) for p in v]
                       for l, v in am["members"].items()}
        asg.n_eff = {int(p): int(v) for p, v in am["n_eff"]}
        asg.tau = {int(p): int(v) for p, v in am["tau"]}
        asg.demotions = int(am["demotions"])
        asg.diagnostics = [(int(p), int(l), str(w))
                           for p, l, w in am["diagnostics"]]
        self.online = {int(p) for p in meta["online"]}
        self._gone = {int(p) for p in meta["gone"]}
        self._spikes = {int(p): (float(f), int(tok))
                        for p, f, tok in meta["spikes"]}
        self._spike_seq = int(meta["spike_seq"])
        self._rejoin_token = {int(p): int(t) for p, t in meta["rejoin_token"]}
        self.queue.load_encoded(meta["queue"])
        self.clock.now = float(meta["clock"])
        self._bank = {lvl: [] for lvl in range(fl.m)}
        for l_str, entries in meta["bank"].items():
            lvl = int(l_str)
            for i, b in enumerate(entries):
                row = jnp.asarray(arrays[f"bank/{lvl}/{i}"])
                entry = {"pid": int(b["pid"]), "round": int(b["round"]),
                         "n_eff": int(b["n_eff"])}
                if plane_mode:
                    entry["plane"] = row
                else:
                    entry["params"] = fl.params_of(lvl, row)
                self._bank[lvl].append(entry)
        report.rows = decode_rows(meta["rows"])
        report.final_acc = {int(l): float(a) for l, a in meta["final_acc"]}
        report.registry.load_state(
            meta["obs"], {k[len("obs/"):]: v for k, v in arrays.items()
                          if k.startswith("obs/")})
        params = {}
        for lvl in range(fl.m):
            plane = jnp.asarray(arrays[f"plane/{lvl}"])
            if plane.shape != (fl.plane_spec(lvl).d_pad,):
                raise CheckpointError(
                    f"level {lvl} plane shape {plane.shape} != "
                    f"({fl.plane_spec(lvl).d_pad},) — model family/mesh "
                    "changed since the checkpoint")
            params[lvl] = (fl.place_plane(plane) if plane_mode
                           else fl.params_of(lvl, plane))
        log.info("resumed %s run at round %d from %s", meta["mode"], r0,
                 self.checkpoint.manager.dir)
        return r0, params

    def _terminal_flush(self, params: dict, rounds: int, report,
                        merge=None) -> None:
        """Merge updates still sitting in the bank when the sim ends (banked
        in the last round, or in a cluster that never ran again) — so 'no
        work is thrown away' holds for the last round too.  ``merge``
        selects the representation (defaults to the pytree path; the
        dispatch engine passes ``_anchored_merge_plane``)."""
        merge = merge or self._anchored_merge
        for lvl, entries in self._bank.items():
            if not entries:
                continue
            params[lvl] = merge(params[lvl], entries, rounds, lvl)
            report.bump_flushed(lvl, len(entries))
            self._bank[lvl] = []
