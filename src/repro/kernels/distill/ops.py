"""jit'd public wrapper for the fused KD loss.

Accepts (B, S, V) or (N, V) logits; pads N to the row-block multiple and V to
the vocab-block multiple with a finite large-negative value (-3e4: exp
underflows to exactly 0, sums stay exact — see kernel.py docstring).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.distill.kernel import kd_loss_rows

PAD = -3.0e4


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("T", "alpha", "block_n", "block_v",
                                   "interpret"))
def kd_loss(student_logits, labels, teacher_logits, *, T: float = 2.0,
            alpha: float = 0.3, block_n: int = 128, block_v: int = 512,
            interpret: bool | None = None):
    """Mean KD loss (Hinton) over all rows; see core/distill.py for the jnp path."""
    interpret = _interpret_default() if interpret is None else interpret
    s = student_logits.reshape(-1, student_logits.shape[-1])
    t = teacher_logits.reshape(-1, teacher_logits.shape[-1])
    lbl = labels.reshape(-1).astype(jnp.int32)
    N, V = s.shape
    bn = min(block_n, max(8, N))
    bv = min(block_v, V)
    pad_n = (-N) % bn
    pad_v = (-V) % bv
    if pad_v:
        s = jnp.pad(s, ((0, 0), (0, pad_v)), constant_values=PAD)
        t = jnp.pad(t, ((0, 0), (0, pad_v)), constant_values=PAD)
    if pad_n:
        s = jnp.pad(s, ((0, pad_n), (0, 0)), constant_values=PAD)
        t = jnp.pad(t, ((0, pad_n), (0, 0)), constant_values=PAD)
        lbl = jnp.pad(lbl, (0, pad_n))
    rows = kd_loss_rows(s, t, lbl, T=T, alpha=alpha, block_n=bn, block_v=bv,
                        interpret=interpret)
    return jnp.sum(rows[:N]) / N
