"""Pure-jnp oracle for the fused KD loss kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kd_loss_rows(student, teacher, labels, *, T: float = 2.0,
                 alpha: float = 0.3):
    s = student.astype(jnp.float32)
    t = teacher.astype(jnp.float32)
    sT, tT = s / T, t / T
    t_lse = jax.nn.logsumexp(tT, axis=-1, keepdims=True)
    s_lse = jax.nn.logsumexp(sT, axis=-1, keepdims=True)
    p_t = jnp.exp(tT - t_lse)
    kl = jnp.sum(p_t * ((tT - t_lse) - (sT - s_lse)), axis=-1)
    lse1 = jax.nn.logsumexp(s, axis=-1)
    picked = jnp.take_along_axis(s, labels[:, None], axis=-1)[:, 0]
    ce = lse1 - picked
    return alpha * ce + (1.0 - alpha) * (T ** 2) * kl
