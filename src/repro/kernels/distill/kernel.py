"""Fused master-slave KD loss kernel (Pallas, TPU target).

Computes, per row, in ONE streaming sweep over vocab blocks (never
materializing a (N, V) softmax — V is 151936 for the Qwen archs):

  loss = α·CE(student, label) + (1-α)·T²·KL(softmax(t/T) ‖ softmax(s/T))

Online-rescaled running statistics per row (all VMEM scratch, fp32):
  teacher-T:  running max m_t, denom l_t, A = Σp·(t/T), B = Σp·(s/T)
  student-T:  m_sT, l_sT (logsumexp)
  student-1:  m_s1, l_s1, picked-label logit
so  KL = A/l_t - (m_t+log l_t) + (m_sT+log l_sT) - B/l_t
    CE = (m_s1+log l_s1) - picked.

Inputs may be padded along V with a large-negative FINITE value (e.g. -3e4):
exp underflows to exactly 0 and 0·finite = 0, keeping the sums exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kd_kernel(s_ref, t_ref, lbl_ref, o_ref, st, *, T: float, alpha: float,
               block_n: int, block_v: int, n_v: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        st[...] = jnp.zeros_like(st)
        st[0, :] = jnp.full((block_n,), -1e30)   # m_t
        st[4, :] = jnp.full((block_n,), -1e30)   # m_sT
        st[6, :] = jnp.full((block_n,), -1e30)   # m_s1

    s = s_ref[...].astype(jnp.float32)           # (bn, bv)
    t = t_ref[...].astype(jnp.float32)
    sT, tT = s / T, t / T
    v_idx = j * block_v + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_n, block_v), 1)
    lbl = lbl_ref[...]                           # (bn,)

    # --- teacher-temperature statistics (for the KL) -----------------------
    m_t, l_t, A, B = st[0, :], st[1, :], st[2, :], st[3, :]
    m_t_new = jnp.maximum(m_t, jnp.max(tT, axis=1))
    sc = jnp.exp(m_t - m_t_new)
    p = jnp.exp(tT - m_t_new[:, None])
    st[0, :] = m_t_new
    st[1, :] = l_t * sc + jnp.sum(p, axis=1)
    st[2, :] = A * sc + jnp.sum(p * tT, axis=1)
    st[3, :] = B * sc + jnp.sum(p * sT, axis=1)

    # --- student logsumexp at temperature T --------------------------------
    m_sT, l_sT = st[4, :], st[5, :]
    m_sT_new = jnp.maximum(m_sT, jnp.max(sT, axis=1))
    st[4, :] = m_sT_new
    st[5, :] = l_sT * jnp.exp(m_sT - m_sT_new) + jnp.sum(
        jnp.exp(sT - m_sT_new[:, None]), axis=1)

    # --- student logsumexp at T=1 + picked label logit (for the CE) --------
    m1, l1 = st[6, :], st[7, :]
    m1_new = jnp.maximum(m1, jnp.max(s, axis=1))
    st[6, :] = m1_new
    st[7, :] = l1 * jnp.exp(m1 - m1_new) + jnp.sum(
        jnp.exp(s - m1_new[:, None]), axis=1)
    st[8, :] = st[8, :] + jnp.sum(
        jnp.where(v_idx == lbl[:, None], s, 0.0), axis=1)

    @pl.when(j == n_v - 1)
    def _final():
        z_t = st[0, :] + jnp.log(st[1, :])
        z_sT = st[4, :] + jnp.log(st[5, :])
        z_s1 = st[6, :] + jnp.log(st[7, :])
        kl = st[2, :] / st[1, :] - z_t + z_sT - st[3, :] / st[1, :]
        ce = z_s1 - st[8, :]
        o_ref[...] = (alpha * ce + (1.0 - alpha) * (T ** 2) * kl).astype(
            o_ref.dtype)


def kd_loss_rows(student, teacher, labels, *, T: float = 2.0,
                 alpha: float = 0.3, block_n: int = 128, block_v: int = 512,
                 interpret: bool = True):
    """student/teacher: (N, V); labels: (N,) int32 → per-row loss (N,)."""
    N, V = student.shape
    block_n = min(block_n, N)
    block_v = min(block_v, V)
    assert N % block_n == 0 and V % block_v == 0, (N, V, block_n, block_v)
    kern = functools.partial(_kd_kernel, T=T, alpha=alpha, block_n=block_n,
                             block_v=block_v, n_v=V // block_v)
    return pl.pallas_call(
        kern,
        grid=(N // block_n, V // block_v),
        in_specs=[
            pl.BlockSpec((block_n, block_v), lambda i, j: (i, j)),
            pl.BlockSpec((block_n, block_v), lambda i, j: (i, j)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((9, block_n), jnp.float32)],
        interpret=interpret,
    )(student, teacher, labels)
