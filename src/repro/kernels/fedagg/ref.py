"""Pure-jnp oracle for the fedagg kernel."""
import jax.numpy as jnp


def weighted_aggregate(stack, weights):
    return jnp.einsum("c,cd->d", weights.astype(jnp.float32),
                      stack.astype(jnp.float32)).astype(stack.dtype)
