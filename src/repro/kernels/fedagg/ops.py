"""jit'd pytree wrapper for the fedagg kernel: ravel → kernel → unravel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fedagg.kernel import weighted_aggregate


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pick_block(D: int, block_d: int) -> int:
    """Largest power-of-two block ≤ ``block_d`` that divides D (always
    terminates: every D divides by 1)."""
    bd = min(block_d, D)
    while D % bd:
        bd //= 2
    return bd


def aggregate_plane(plane, weights, *, block_d: int = 2048,
                    interpret: bool | None = None):
    """Weighted aggregate straight on a flat parameter plane (C, D) → (D,).

    The plane path of the dispatch pipeline: D is already padded to a
    multiple of ``core.plane.PLANE_ALIGN`` at spec time, so — unlike
    ``aggregate_tree`` — there is no per-call flatten/concatenate/pad; the
    kernel grid tiles D at the largest power-of-two block ≤ ``block_d``
    that divides it.

    Under ``shard_map`` this is the PER-DEVICE inner loop of the sharded
    plane aggregation (``aggregation.aggregate_plane_sharded`` and the
    mesh-sharded dispatch program): C is then the device's LOCAL member-row
    count — the zero-weight padding rows that make C divisible by the mesh
    axis contract to nothing — and one psum over ``data`` outside completes
    the all-reduce.  On a 2D (data × model) mesh D is the device's LOCAL
    column slice (``core.plane.make_plane_spec(model_size=…)`` pads the
    global plane to a multiple of ``model_size × PLANE_ALIGN`` precisely so
    this per-device grid stays block-divisible); column slices never need
    reducing, so no collective is added."""
    interpret = _interpret_default() if interpret is None else interpret
    bd = _pick_block(plane.shape[1], block_d)
    return weighted_aggregate(plane.astype(jnp.float32),
                              weights.astype(jnp.float32), block_d=bd,
                              interpret=interpret)


def aggregate_tree(params_stack, weights, *, block_d: int = 2048,
                   interpret: bool | None = None):
    """params_stack: pytree with leading client axis C → aggregated pytree."""
    interpret = _interpret_default() if interpret is None else interpret
    leaves, treedef = jax.tree_util.tree_flatten(params_stack)
    C = leaves[0].shape[0]
    flats = [l.reshape(C, -1) for l in leaves]
    sizes = [f.shape[1] for f in flats]
    cat = jnp.concatenate(flats, axis=1).astype(jnp.float32)
    D = cat.shape[1]
    bd = min(block_d, D)
    pad = (-D) % bd
    if pad:
        cat = jnp.pad(cat, ((0, 0), (0, pad)))
    out = weighted_aggregate(cat, weights.astype(jnp.float32), block_d=bd,
                             interpret=interpret)[:D]
    parts = []
    pos = 0
    for leaf, sz in zip(leaves, sizes):
        parts.append(out[pos:pos + sz].reshape(leaf.shape[1:]).astype(leaf.dtype))
        pos += sz
    return jax.tree_util.tree_unflatten(treedef, parts)
