"""Weighted client-stack reduction kernel (the FedAvg server step).

Input: client-stacked flat parameters (C, D) and normalized weights (C,);
output the n_i-weighted average (D,).  The grid tiles D; each step loads the
full (C, block_d) column panel into VMEM and contracts against the weight
vector on the MXU.  This is the per-device inner loop of the shard_map psum
aggregation (core/aggregation.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _agg_kernel(w_ref, x_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)             # (C,)
    x = x_ref[...].astype(jnp.float32)             # (C, bd)
    o_ref[...] = jax.lax.dot_general(
        w[None], x, (((1,), (0,)), ((), ())))[0].astype(o_ref.dtype)


def weighted_aggregate(stack, weights, *, block_d: int = 2048,
                       interpret: bool = True):
    """stack: (C, D); weights: (C,) → (D,)."""
    C, D = stack.shape
    block_d = min(block_d, D)
    assert D % block_d == 0, (D, block_d)
    return pl.pallas_call(
        _agg_kernel,
        grid=(D // block_d,),
        in_specs=[
            pl.BlockSpec((C,), lambda i: (0,)),
            pl.BlockSpec((C, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((D,), stack.dtype),
        interpret=interpret,
    )(weights, stack)
