"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -2.0 ** 30


def attention_bh(q, k, v, *, causal: bool = True, window: int = 0,
                 softcap: float = 0.0, sm_scale: float | None = None):
    """q: (BH,Sq,hd); k,v: (BH,Sk,hd). fp32 softmax, same masking semantics."""
    hd = q.shape[-1]
    sm_scale = hd ** -0.5 if sm_scale is None else sm_scale
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    Sq, Sk = q.shape[1], k.shape[1]
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= ki <= qi
    if window > 0:
        mask &= (qi - ki) < window
    s = jnp.where(mask[None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
