"""Block-wise flash attention (Pallas, TPU target).

Grid (bh, nq, nk) with nk innermost; online-softmax state (running max m,
denominator l, and the output accumulator) lives in VMEM scratch across the
nk sweep.  BlockSpecs tile Q as (block_q, hd) and K/V as (block_k, hd) —
with block 128 and hd ≤ 256 the working set is ≤ ~0.5 MB, comfortably within
the ~16 MB v5e VMEM, and the matmul dims are MXU-aligned (128 multiples).

Supports causal masking, sliding windows (gemma2 local layers), and logit
softcap.  Validated on CPU with interpret=True against kernels/flash/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale: float, causal: bool, window: int, softcap: float,
                  block_q: int, block_k: int, n_k: int):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)                     # (bq, hd)
    k = k_ref[...].astype(jnp.float32)                     # (bk, hd)
    v = v_ref[...].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    q_idx = pl.program_id(1) * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_idx = kv_i * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_idx <= q_idx
    if window > 0:
        mask &= (q_idx - k_idx) < window
    s = jnp.where(mask, s, NEG)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kv_i == n_k - 1)
    def _final():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bh(q, k, v, *, causal: bool = True, window: int = 0,
                       softcap: float = 0.0, block_q: int = 128,
                       block_k: int = 128, sm_scale: float | None = None,
                       interpret: bool = True, heads: int | None = None):
    """q: (B·H, Sq, hd); k, v: (B·KV, Sk, hd) — head-flattened attention.

    With ``heads`` (= H, the per-batch query-head count) and KV < H
    (grouped-query attention), each query-head grid row reads its group's
    KV row straight out of the compact (B·KV, …) tensors through the
    BlockSpec index map — the kernel never materializes the G×-repeated
    K/V the old ``jnp.repeat`` expansion built.  ``heads=None`` (or
    KV == H) keeps the identity row mapping."""
    BH, Sq, hd = q.shape
    BKV = k.shape[0]
    Sk = k.shape[1]
    if heads is None or BKV == BH:
        def kv_map(b, i, j):
            return (b, j, 0)
    else:
        H = heads
        assert BH % H == 0 and (BKV * H) % BH == 0, (BH, BKV, H)
        KV = (BKV * H) // BH          # kv heads per batch
        G = H // KV                   # query heads per kv head

        def kv_map(b, i, j):
            return ((b // H) * KV + (b % H) // G, j, 0)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    n_q, n_k = Sq // block_q, Sk // block_k
    sm_scale = hd ** -0.5 if sm_scale is None else sm_scale

    kern = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, n_k=n_k)
    return pl.pallas_call(
        kern,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((None, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, block_k, hd), kv_map),
            pl.BlockSpec((None, block_k, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
