"""jit'd public wrapper: GQA-aware flash attention.

Accepts model-layout tensors q:(B,S,H,hd), k/v:(B,T,KV,hd); expands grouped
KV heads, flattens (B,H), and calls the Pallas kernel.  On CPU backends the
kernel runs in interpret mode (Python execution of the kernel body); on TPU
it lowers to Mosaic.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash.kernel import flash_attention_bh


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "block_q",
                                   "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kb = k.transpose(0, 2, 1, 3).reshape(B * H, k.shape[1], hd)
    vb = v.transpose(0, 2, 1, 3).reshape(B * H, v.shape[1], hd)
    ob = flash_attention_bh(qb, kb, vb, causal=causal, window=window,
                            softcap=softcap, block_q=block_q, block_k=block_k,
                            interpret=interpret)
    return ob.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
