"""jit'd public wrapper: GQA-aware flash attention.

Accepts model-layout tensors q:(B,S,H,hd), k/v:(B,T,KV,hd); flattens the
(batch, head) axes and calls the Pallas kernel, which maps each grouped
query head to its KV head inside the grid (K/V stay compact — no G×
repeat).  On CPU backends the kernel runs in interpret mode (Python
execution of the kernel body); on TPU it lowers to Mosaic.

Differentiable: a ``custom_vjp`` pairs the kernel forward with a backward
that recomputes attention through the pure-jnp grouped reference and
transposes that — the standard flash pattern (save q/k/v, not the S×T
probabilities), which is what lets ``attn_impl="pallas"`` serve the
member-training forward of the FL dispatch path, not just prefill.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash.kernel import flash_attention_bh

NEG = -2.0 ** 30


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _ref_gqa(q, k, v, causal: bool, window: int, softcap: float):
    """Grouped-query attention in model layout, pure jnp — the backward
    recompute (same masking/softcap semantics as the kernel)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qr = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bkgst", qr, k.astype(jnp.float32))
    s = s * (hd ** -0.5)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= ki <= qi
    if window > 0:
        mask &= (qi - ki) < window
    s = jnp.where(mask[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, softcap, block_q, block_k, interpret):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    # GQA rides the kernel's grid→KV-row index map: K/V stay compact
    # (B·KV, Sk, hd), no G× repeat materialization before the call
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kb = k.transpose(0, 2, 1, 3).reshape(B * KV, k.shape[1], hd)
    vb = v.transpose(0, 2, 1, 3).reshape(B * KV, v.shape[1], hd)
    ob = flash_attention_bh(qb, kb, vb, causal=causal, window=window,
                            softcap=softcap, block_q=block_q, block_k=block_k,
                            interpret=interpret, heads=H)
    return ob.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


def _flash_fwd(q, k, v, causal, window, softcap, block_q, block_k, interpret):
    out = _flash(q, k, v, causal, window, softcap, block_q, block_k, interpret)
    return out, (q, k, v)


def _flash_bwd(causal, window, softcap, block_q, block_k, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: _ref_gqa(q, k, v, causal, window, softcap), q, k, v)
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "block_q",
                                   "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    return _flash(q, k, v, causal, window, softcap, block_q, block_k,
                  interpret)
