"""Quickstart: Fed-RAC in ~60 lines on the public API.

Clusters the paper's 40 real participants by resources (Procedure 1),
compacts, assigns (Procedure 2), trains the master cluster by FedAvg and the
slaves under master KD, then prints per-cluster accuracy.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import server as srv
from repro.core.families import cnn_family
from repro.core.resources import TABLE_III, participants_from_matrix
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_classification, train_test_split


def main():
    # 1. synthetic federated dataset, non-iid across 40 participants
    ds = make_classification("synth-mnist", 2400, seed=0)
    train, test = train_test_split(ds)
    idx = dirichlet_partition(train.y, 40, alpha=1.0, seed=0)
    parts = participants_from_matrix(TABLE_III, n_data=[len(p) for p in idx])
    client_data = [{"x": train.x[p], "y": train.y[p]} for p in idx]

    # 2. the model family: the paper's CNN, α-compressed per cluster level
    family = cnn_family(classes=10, in_channels=1)

    # 3. Fed-RAC end to end
    cfg = srv.FLConfig(rounds=8, compact_to=4, seed=3)
    engine = srv.FedRAC(parts, client_data, family, cfg, classes=10).setup()
    print(f"optimal k = {engine.k_optimal} (Dunn indices: "
          f"{ {k: round(v, 3) for k, v in engine.di_values.items()} })")
    print(f"compacted to m = {engine.m} clusters; members: "
          f"{ {l: len(v) for l, v in engine.assignment.members.items()} }")

    result = engine.train({"x": jnp.asarray(test.x), "y": jnp.asarray(test.y)})
    for lvl in range(engine.m):
        print(f"  cluster C{lvl + 1}: acc = "
              f"{result.final_acc.get(lvl, float('nan')):.3f}")
    print(f"global accuracy = {result.global_acc:.3f}")


if __name__ == "__main__":
    main()
