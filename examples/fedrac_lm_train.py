"""End-to-end LM training driver: train an olmo-family model for a few
hundred steps with the WSD schedule, checkpointing, and Fed-RAC cluster
compression — the (b) deliverable's end-to-end driver.

Default runs a ~7M-param reduced model in a few minutes on this CPU
container; ``--full-100m`` selects a ~100M config (same code path — run it
on real hardware or leave it grinding):

  PYTHONPATH=src python examples/fedrac_lm_train.py --steps 300
  PYTHONPATH=src python examples/fedrac_lm_train.py --full-100m --steps 300
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint
from repro.configs import get_config
from repro.core.scaling import compress_config, param_count
from repro.data.synthetic import lm_batches, make_lm_corpus
from repro.launch.train import build_step
from repro.models import registry
from repro.optim import optimizers, schedules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--cluster-level", type=int, default=0,
                    help="train the α-compressed slave config instead")
    ap.add_argument("--ckpt-dir", default="/tmp/fedrac_lm_ckpt")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config("olmo-1b", smoke=True)
    if args.full_100m:
        cfg = cfg.replace(n_layers=8, d_model=512, n_heads=8, n_kv_heads=8,
                          head_dim=64, d_ff=2048, vocab_size=50304)
    else:
        cfg = cfg.replace(n_layers=4, d_model=256, vocab_size=2048)
    cfg = compress_config(cfg, 0.5, args.cluster_level)
    print(f"config: {cfg.name} L={cfg.n_layers} d={cfg.d_model} "
          f"params~{param_count(cfg) / 1e6:.1f}M")

    key = jax.random.PRNGKey(args.seed)
    params = registry.init_params(cfg, key)
    opt = optimizers.adamw()
    opt_state = opt.init(params)
    sched = schedules.wsd(args.lr, args.steps)           # MiniCPM WSD
    step_fn = jax.jit(build_step(cfg, opt, sched), donate_argnums=(0, 1))
    corpus = make_lm_corpus(cfg.vocab_size, 300_000, seed=args.seed)

    losses, t0 = [], time.time()
    for step in range(args.steps):
        toks = lm_batches(corpus, args.batch, args.seq, 1,
                          seed=args.seed + step)[0]
        params, opt_state, ce = step_fn(params, opt_state,
                                        {"tokens": jnp.asarray(toks)},
                                        jnp.asarray(step))
        losses.append(float(ce))
        if (step + 1) % 50 == 0:
            tput = args.batch * args.seq * 50 / (time.time() - t0)
            print(f"step {step + 1:4d} ce={np.mean(losses[-50:]):.4f} "
                  f"tok/s={tput:,.0f}", flush=True)
            t0 = time.time()
    path = checkpoint.save_step(args.ckpt_dir, args.steps, {"params": params})
    print(f"ce: start={np.mean(losses[:20]):.4f} "
          f"end={np.mean(losses[-20:]):.4f}  ckpt={path}")
    assert np.mean(losses[-20:]) < np.mean(losses[:20])


if __name__ == "__main__":
    main()
