"""Fed-RAC under realistic participant churn — two scenarios side by side.

  PYTHONPATH=src python examples/fedrac_sim.py

1. **dropout-heavy**: a fifth of the fleet blinks offline every round (flaky
   radios); the MAR `drop` policy excludes deadline violators and partial
   aggregation renormalizes the survivors.
2. **resource-drift**: device speeds/bandwidths random-walk; Procedure-2
   reassignment migrates participants between clusters mid-training (drift is
   *observed* by the server, so re-placement keeps devices inside the MAR).
3. **straggler spikes**: transient slowdowns the server cannot re-plan for —
   they surface as MAR violations, and the `mask` policy lets the straggler
   contribute only the local steps that still fit the deadline.
4. **buffered async**: the same spiky fleet under the `buffer` policy —
   violators train their full τ steps, miss the synchronous aggregate, and
   their banked update joins the NEXT round's FedAvg at a staleness-
   discounted weight (`FLConfig(aggregation="buffered")`): the round stays
   bounded by the on-time members and no work is thrown away.

All print the per-round timeline: wall-clock, per-cluster active/dropped/
masked/banked counts, MAR violations, bytes on the wire, and the applied
events.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.launch import sim_run  # noqa: E402

COMMON = ["--participants", "14", "--samples", "1200", "--rounds", "6",
          "--base-width", "0.125", "--compact-to", "3", "--eval-every", "3"]

print("=" * 72)
print("scenario 1: dropout-heavy fleet, MAR policy = drop")
print("=" * 72)
sim_run.main(["--trace", "dropout", "--dropout-rate", "0.2",
              "--mar-policy", "drop", *COMMON])

print()
print("=" * 72)
print("scenario 2: resource drift, MAR policy = mask")
print("=" * 72)
sim_run.main(["--trace", "drift", "--drift-rate", "0.25",
              "--mar-policy", "mask", "--schedule", "sequential", *COMMON])

print()
print("=" * 72)
print("scenario 3: transient straggler spikes, MAR policy = mask")
print("=" * 72)
sim_run.main(["--trace", "straggler", "--spike-rate", "0.3",
              "--mar-policy", "mask", *COMMON])

print()
print("=" * 72)
print("scenario 4: straggler spikes, MAR policy = buffer (async banked "
      "updates)")
print("=" * 72)
sim_run.main(["--trace", "straggler", "--spike-rate", "0.3",
              "--mar-policy", "buffer", "--staleness-discount", "0.6",
              *COMMON])
