"""Paper-experiment driver: Fed-RAC vs all four baselines on a synthetic
dataset, reproducing the Fig. 2 comparison at CPU scale.

  PYTHONPATH=src python examples/fedrac_cnn_full.py [--dataset synth-har]
      [--rounds 12]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import baselines as bl
from repro.core import server as srv
from repro.core.families import cnn_family
from repro.core.resources import TABLE_III, participants_from_matrix
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import SPECS, make_classification, train_test_split
from repro.models import cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="synth-mnist", choices=list(SPECS))
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--samples", type=int, default=2400)
    ap.add_argument("--seed", type=int, default=3)
    args = ap.parse_args()

    shape, classes = SPECS[args.dataset]
    ds = make_classification(args.dataset, args.samples, seed=args.seed)
    train, test = train_test_split(ds)
    idx = dirichlet_partition(train.y, 40, alpha=1.0, seed=args.seed)
    parts = participants_from_matrix(TABLE_III, n_data=[len(p) for p in idx])
    cdata = [{"x": train.x[p], "y": train.y[p]} for p in idx]
    testb = {"x": jnp.asarray(test.x), "y": jnp.asarray(test.y)}

    fam = cnn_family(classes=classes, in_channels=shape[-1],
                     input_hw=shape[0])
    cfg = srv.FLConfig(rounds=args.rounds, compact_to=4, seed=args.seed)
    eng = srv.FedRAC(parts, cdata, fam, cfg, classes=classes).setup()
    res = eng.train(testb)
    print(f"Fed-RAC: global={res.global_acc:.4f} per-cluster="
          f"{ {l: round(a, 3) for l, a in res.final_acc.items()} }")

    def loss_fn(params, batch):
        logits = cnn.forward(params, batch["x"])
        lse = jax.nn.logsumexp(logits, -1)
        picked = jnp.take_along_axis(logits, batch["y"][:, None], -1)[:, 0]
        return jnp.mean(lse - picked), logits

    bcfg = bl.BaselineConfig(rounds=args.rounds, seed=args.seed, lr=0.08,
                             steps_per_round=4)
    # baselines deploy the smallest slave model so all 40 devices participate
    init = cnn.init_params(jax.random.PRNGKey(0), in_channels=shape[-1],
                           classes=classes, base_width=0.25 * 0.125)
    for name, fn in (("FedAvg", bl.fedavg), ("FedProx", bl.fedprox)):
        _, hist = fn(loss_fn, init, parts, cdata, testb, bcfg)
        print(f"{name}: final={hist[-1]:.4f} curve={[round(a,3) for a in hist]}")
    _, hist = bl.oort(loss_fn, init, parts, cdata, testb, bcfg,
                      flops_per_sample=1e6, model_bytes=2e5)
    print(f"Oort: final={hist[-1]:.4f}")
    levels = {p.pid: min(2, 3 * i // len(parts)) for i, p in enumerate(parts)}
    _, hist = bl.heterofl(parts, cdata, levels, testb, bcfg,
                          in_channels=shape[-1], classes=classes, levels=3,
                          base_width=0.25)
    print(f"HeteroFL: final={hist[-1]:.4f}")


if __name__ == "__main__":
    main()
