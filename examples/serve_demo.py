"""Fed-RAC serving demo: one server process holds the α-compressed model
FAMILY; batched requests are routed to the model level matching each
requester's resource cluster (§IV-A2 at inference time).

  PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import clustering
from repro.core.resources import (LAMBDA_PAPER, TABLE_III,
                                  participants_from_matrix, resource_matrix,
                                  unit_normalize)
from repro.core.scaling import compress_config, param_count
from repro.launch.serve import generate
from repro.models import registry


def main():
    base = get_config("olmo-1b", smoke=True).replace(vocab_size=1024)
    # resource-aware clustering of the requesting devices
    res = clustering.optimal_clusters(TABLE_III, LAMBDA_PAPER, seed=3,
                                      restarts=1)
    labels = clustering.order_clusters_by_resources(res.normalized, res.labels,
                                                    LAMBDA_PAPER)
    m = min(3, len(np.unique(labels)))
    labels = np.clip(labels, 0, m - 1)
    print(f"requesters clustered into {m} service tiers "
          f"(k-optimal was {res.k})")

    key = jax.random.PRNGKey(0)
    family, params = [], []
    for lvl in range(m):
        cfg = compress_config(base, 0.5, lvl)
        family.append(cfg)
        params.append(registry.init_params(cfg, jax.random.fold_in(key, lvl)))
        print(f"  tier {lvl}: {param_count(cfg) / 1e6:.2f}M params")

    # serve one batch per tier
    rng = np.random.default_rng(0)
    for lvl in range(m):
        n_req = int((labels == lvl).sum())
        batch = min(4, max(1, n_req))
        prompts = jax.numpy.asarray(
            rng.integers(0, base.vocab_size, (batch, 16)), dtype="int32")
        t0 = time.time()
        toks = generate(family[lvl], params[lvl], prompts, gen_len=16)
        dt = time.time() - t0
        print(f"  tier {lvl}: served {n_req} requesters "
              f"(batch {batch}) — {batch * 16 / dt:.1f} tok/s, "
              f"sample={toks[0, :8]}")


if __name__ == "__main__":
    main()
